"""L1 Pallas kernel: per-output-channel weight fake-quant with an
AdaRound/LoRA-Rounding offset rho (Eq. 8/11 of the paper).

Grid tiles the output-channel (N) dimension: each program owns a (K, TN)
weight panel plus its (TN,) scale slice and (K, TN) rho slice — on TPU the
per-channel scale is a lane broadcast across the panel, and the whole
quantize-dequantize is a VPU elementwise pass (no MXU involvement), so this
kernel is bandwidth-bound and fuses cleanly ahead of the matmul.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_TN = 64


def _kernel(w_ref, s_ref, rho_ref, qmax_ref, w_en_ref, o_ref):
    w = w_ref[...]                      # (K, TN)
    s = jnp.maximum(s_ref[...], ref.EPS)[None, :]
    rho = rho_ref[...]
    qmax = qmax_ref[0]
    w_en = w_en_ref[0]
    q = jnp.clip(jnp.floor(w / s) + rho, -qmax - 1.0, qmax) * s
    o_ref[...] = w + w_en * (q - w)


@functools.partial(jax.jit, static_argnames=("tn",))
def quant_weight(w, s_w, rho, qmax, w_en, tn=DEFAULT_TN):
    """w: [K, N], s_w: [N], rho: [K, N] in [0,1], qmax/w_en: [1] f32."""
    from .quant_matmul import pick_tile

    k, n = w.shape
    tn = pick_tile(n, tn)
    grid = (n // tn,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, tn), lambda j: (0, j)),
            pl.BlockSpec((tn,), lambda j: (j,)),
            pl.BlockSpec((k, tn), lambda j: (0, j)),
            pl.BlockSpec((1,), lambda j: (0,)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((k, tn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.float32),
        interpret=True,
    )(w, s_w, rho, qmax, w_en)
