"""L1 Pallas kernel: RMSNorm over the hidden dimension.

Row-tiled like quant_matmul; the mean-square reduction is a single VPU pass
per tile. Kept as a kernel (rather than leaving it to XLA fusion) because it
is the producer of every quantized linear's input — on TPU the norm output
stays resident in VMEM for the fused quant-matmul that follows.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TM = 64
EPS = 1e-5


def _kernel(x_ref, g_ref, o_ref):
    x = x_ref[...]
    g = g_ref[...]
    r = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS)
    o_ref[...] = x * r * g[None, :]


@functools.partial(jax.jit, static_argnames=("tm",))
def rmsnorm(x, g, tm=DEFAULT_TM):
    """x: [M, D], g: [D] -> [M, D]."""
    from .quant_matmul import pick_tile

    m, d = x.shape
    tm = pick_tile(m, tm)
    return pl.pallas_call(
        _kernel,
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(x, g)
