"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

pytest (python/tests/) asserts each Pallas kernel (interpret mode) matches
these to tight tolerance over hypothesis-swept shapes/values; the Rust side
inherits correctness transitively because the AOT graphs are built from the
same functions.

Quantization semantics (DESIGN.md §Quantization semantics):
  * weights: symmetric per-output-channel, learnable step s_w, AdaRound-style
    rounding offset rho in [0,1]:  q = clip(floor(W/s_w) + rho, -qmax-1, qmax)
  * activations: per-token dynamic symmetric with learnable clip alpha:
    s = alpha * max|x_token| / qmax
  * enable flags blend quantized/raw (x + en*(fq(x)-x)) so one graph serves
    all bit settings including the FP path.
"""

import jax.numpy as jnp

EPS = 1e-8


def rmsnorm(x, g, eps=1e-5):
    """x: [M, D], g: [D]."""
    r = jnp.reciprocal(jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps))
    return x * r * g


def act_scale(x, alpha, qmax):
    """Per-token (row) step size. x: [M, K] -> [M, 1]."""
    m = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    return jnp.maximum(alpha * m / qmax, EPS)


def fake_quant_act(x, alpha, qmax):
    s = act_scale(x, alpha, qmax)
    q = jnp.clip(jnp.round(x / s), -qmax - 1.0, qmax)
    return q * s


def blend_act(x, alpha, qmax, a_en):
    return x + a_en * (fake_quant_act(x, alpha, qmax) - x)


def fake_quant_weight(w, s_w, rho, qmax):
    """w: [K, N], s_w: [N] per-output-channel, rho: [K, N] in [0, 1]."""
    s = jnp.maximum(s_w, EPS)[None, :]
    q = jnp.clip(jnp.floor(w / s) + rho, -qmax - 1.0, qmax)
    return q * s


def blend_weight(w, s_w, rho, qmax, w_en):
    return w + w_en * (fake_quant_weight(w, s_w, rho, qmax) - w)


def quant_matmul(x, w_hat, alpha, qmax, a_en):
    """The fused hot-spot: per-token activation fake-quant + matmul.
    x: [M, K], w_hat: [K, N] (already weight-fake-quantized)."""
    return blend_act(x, alpha, qmax, a_en) @ w_hat


def round_ste_rho(w, s_w):
    """Nearest-rounding offset (the rho used when LoRA-Rounding is off):
    rho = 1 if frac(W/s) >= 0.5 else 0."""
    s = jnp.maximum(s_w, EPS)[None, :]
    wn = w / s
    return (wn - jnp.floor(wn) >= 0.5).astype(w.dtype)
