"""L1 Pallas kernel: fused per-token activation fake-quant + matmul.

This is the paper-system's compute hot-spot: every quantized linear in the
transformer runs through it (W4A4/W4A8 inference and every reconstruction
forward during CBQ optimization).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles rows of the
activation matrix; each program stages an (TM, K) activation tile and the
full (K, N) fake-quantized weight panel into VMEM, computes the per-token
scale with one VPU pass over the tile, quantize-dequantizes in registers and
feeds the MXU with an f32-accumulated matmul. K, N <= 384 for all shipped
configs, so the weight panel fits VMEM comfortably (see EXPERIMENTS.md §Perf
for the footprint table); for larger models the index_map generalizes to an
(i, j) grid with a K-loop.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs under the Rust runtime while preserving the block structure
we estimate TPU performance from.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_TM = 64


def pick_tile(dim: int, want: int) -> int:
    """Largest divisor of `dim` that is <= `want` (grid must cover exactly)."""
    t = min(want, dim)
    while dim % t != 0:
        t -= 1
    return t


def _kernel(x_ref, w_ref, alpha_ref, qmax_ref, a_en_ref, o_ref):
    x = x_ref[...]                      # (TM, K) activation tile in VMEM
    w = w_ref[...]                      # (K, N) fake-quantized weight panel
    alpha = alpha_ref[0]
    qmax = qmax_ref[0]
    a_en = a_en_ref[0]
    # per-token (row) dynamic scale with learnable clip alpha
    m = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.maximum(alpha * m / qmax, ref.EPS)
    q = jnp.clip(jnp.round(x / s), -qmax - 1.0, qmax) * s
    x_eff = x + a_en * (q - x)          # enable-blend: a_en=0 -> FP path
    o_ref[...] = jnp.dot(x_eff, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm",))
def quant_matmul(x, w_hat, alpha, qmax, a_en, tm=DEFAULT_TM):
    """x: [M, K] f32, w_hat: [K, N] f32 (weight fake-quant already applied),
    alpha/qmax/a_en: [1] f32. Returns [M, N] f32.

    M must be divisible by the row tile; callers pad (model.py shapes are
    B*S = multiples of 32)."""
    m, k = x.shape
    n = w_hat.shape[1]
    tm = pick_tile(m, tm)
    grid = (m // tm,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w_hat, alpha, qmax, a_en)
