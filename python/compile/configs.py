"""Model configurations shared between the Python compile path and the Rust
coordinator (mirrored via artifacts/manifest.json).

Three sizes stand in for the paper's OPT/LLAMA families (see DESIGN.md
§Substitutions): quantization-error *dynamics* need trained weights with real
curvature, not the 7B parameter count.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ffn: int
    vocab: int
    seq: int
    # calibration / eval batch (paper uses minibatch 1; we keep a small batch
    # so one executable call covers several calibration sequences)
    batch: int
    # LoRA-Rounding padded rank: artifacts are exported at this rank and the
    # Rust coordinator projects to the requested effective rank r <= rank_pad
    # after every optimizer step (this is how Table 12's rank sweep runs
    # against a single artifact).
    rank_pad: int
    # pretraining
    pretrain_steps: int
    pretrain_batch: int
    pretrain_lr: float
    # function-preserving activation-outlier injection (DESIGN.md)
    outlier_channels: int
    outlier_gain: float

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_dict(self):
        d = asdict(self)
        d["head_dim"] = self.head_dim
        return d


CONFIGS = {
    "t": ModelConfig(
        name="t", d_model=64, n_layers=4, n_heads=4, d_ffn=128,
        vocab=256, seq=96, batch=4, rank_pad=8,
        pretrain_steps=400, pretrain_batch=16, pretrain_lr=1e-3,
        outlier_channels=4, outlier_gain=8.0,
    ),
    "s": ModelConfig(
        name="s", d_model=128, n_layers=8, n_heads=4, d_ffn=256,
        vocab=256, seq=96, batch=4, rank_pad=8,
        pretrain_steps=700, pretrain_batch=16, pretrain_lr=1e-3,
        outlier_channels=6, outlier_gain=10.0,
    ),
    "m": ModelConfig(
        name="m", d_model=192, n_layers=12, n_heads=6, d_ffn=384,
        vocab=256, seq=96, batch=4, rank_pad=8,
        pretrain_steps=700, pretrain_batch=16, pretrain_lr=8e-4,
        outlier_channels=8, outlier_gain=10.0,
    ),
}

# Linear layers quantized inside one transformer block, in forward order.
# Attention internals (QK^T, PV) stay FP like the paper's per-linear scheme.
LINEAR_NAMES = ("wq", "wk", "wv", "wo", "wgate", "wup", "wdown")

# Window sizes exported per config. "s" additionally gets w=8 (= whole model,
# the largest point in the paper's Table 7 CBD-scaling study).
WINDOWS = {"t": (1, 2, 4), "s": (1, 2, 4, 8), "m": (1, 2, 4)}

# AdaRound stretch parameters (Eq. 8) — fixed by the paper.
ZETA = 1.1
GAMMA = -0.1
