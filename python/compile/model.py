"""L2: LLaMA-style transformer (RMSNorm + causal MHA with RoPE + SwiGLU),
in two flavours:

  * `fp_forward`   — pure-jnp full-model forward used by build-time
                     pretraining (fast on CPU, no Pallas indirection);
  * quantized window graphs — the CBQ compute graphs built from the L1
    Pallas kernels through the STE custom_vjp seams (ste.py). These are what
    aot.py lowers to HLO text for the Rust coordinator:
      - window_forward:   T_{i,k} fake-quant forward + reconstruction loss
      - window_loss_grads: value-and-grad wrt (s_w, alpha, A1, A2) (Eq. 9)
      - block_capture:    per-linear input capture (GPTQ / SmoothQuant / CFP
                          activation statistics)
      - lm_eval:          final-norm + LM-head masked NLL (perplexity and
                          choice-task scoring)

Every graph takes *enable flags* and qmax values as runtime scalars so a
single artifact family serves W2..W8 x A4..A16, the FP path, and CBQ*'s
per-layer mixed precision (see DESIGN.md).

Parameter pytrees are flattened to an explicitly-ordered flat list by
`flatten_spec` — aot.py records the ordering in artifacts/manifest.json and
the Rust runtime binds inputs by those names.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import ste
from .configs import LINEAR_NAMES, ModelConfig

# attention-projection linears read the post-norm hidden; gate/up read the
# mlp post-norm; o reads the attention mixer output; down reads the SwiGLU.
CAPTURE_SOURCES = {
    "wq": "attn_in", "wk": "attn_in", "wv": "attn_in", "wo": "attn_mix",
    "wgate": "mlp_in", "wup": "mlp_in", "wdown": "mlp_act",
}


# ---------------------------------------------------------------------------
# pytree flattening contract (shared with aot.py / the Rust runtime)
# ---------------------------------------------------------------------------

def flatten_spec(tree, prefix=""):
    """Deterministic (name, leaf) flattening: dicts sorted by key, lists by
    index. The manifest records these names; Rust binds by them."""
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(flatten_spec(tree[k], f"{prefix}{k}."))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.extend(flatten_spec(v, f"{prefix}{i}."))
    else:
        out.append((prefix[:-1], tree))
    return out


def unflatten_like(tree, leaves):
    """Rebuild `tree`'s structure from an iterable of leaves (flatten_spec
    order)."""
    it = iter(leaves)

    def rec(t):
        if isinstance(t, dict):
            return {k: rec(t[k]) for k in sorted(t)}
        if isinstance(t, (list, tuple)):
            return [rec(v) for v in t]
        return next(it)

    return rec(tree)


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------

def linear_shapes(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ffn
    return {
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "wgate": (d, f), "wup": (d, f), "wdown": (f, d),
    }


def init_params(cfg: ModelConfig, key):
    """FP model parameters (pretraining starting point)."""
    shapes = linear_shapes(cfg)
    keys = jax.random.split(key, cfg.n_layers * len(LINEAR_NAMES) + 2)
    ki = iter(range(len(keys)))
    blocks = []
    for _ in range(cfg.n_layers):
        b = {"attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
             "mlp_norm": jnp.ones((cfg.d_model,), jnp.float32)}
        for name in LINEAR_NAMES:
            fan_in, fan_out = shapes[name]
            w = jax.random.normal(keys[next(ki)], (fan_in, fan_out)) / np.sqrt(fan_in)
            b[name] = w.astype(jnp.float32)
        blocks.append(b)
    embed = jax.random.normal(keys[next(ki)], (cfg.vocab, cfg.d_model)) * 0.02
    head = jax.random.normal(keys[next(ki)], (cfg.d_model, cfg.vocab)) / np.sqrt(cfg.d_model)
    return {
        "embed": embed.astype(jnp.float32),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "head": head.astype(jnp.float32),
        "blocks": blocks,
    }


def _v0_init(w, s_w):
    """V0 with rectified-sigmoid(V0) == frac(W/s_w): zero soft-quant error
    at the start of optimization (AdaRound Sec. 4 initialization)."""
    from .configs import ZETA, GAMMA
    frac = w / np.maximum(s_w, 1e-8)[None, :]
    frac = frac - np.floor(frac)
    p = np.clip((frac - GAMMA) / (ZETA - GAMMA), 1e-4, 1.0 - 1e-4)
    return np.log(p / (1.0 - p))


def init_qparams_block(cfg: ModelConfig, block_params, bits_w=4, bits_a=16,
                       w_en=1.0, a_en=0.0):
    """Per-linear quantization parameters with paper initialization:
    s_w = max|W_col| / qmax (per output channel), alpha = 1, A1 gaussian,
    A2 zero (Sec. 3.2: rho starts uniform ~0.55, i.e. near-round)."""
    qp = {}
    rng = np.random.default_rng(17)
    for name in LINEAR_NAMES:
        w = np.asarray(block_params[name])
        fan_in, fan_out = w.shape
        qmax_w = float(2 ** (bits_w - 1) - 1)
        qmax_a = float(2 ** (bits_a - 1) - 1)
        s_w = np.maximum(np.abs(w).max(axis=0) / qmax_w, 1e-6)
        qp[name] = {
            "s_w": jnp.asarray(s_w, jnp.float32),
            "alpha": jnp.asarray(1.0, jnp.float32),
            "a1": jnp.asarray(rng.normal(size=(fan_in, cfg.rank_pad)) * 0.01,
                              jnp.float32),
            "a2": jnp.zeros((cfg.rank_pad, fan_out), jnp.float32),
            # AdaRound warm-start offset, rho(init) = frac(W/s_w)
            "v0": jnp.asarray(_v0_init(w, s_w), jnp.float32),
            "qmax_w": jnp.asarray(qmax_w, jnp.float32),
            "qmax_a": jnp.asarray(qmax_a, jnp.float32),
            "w_en": jnp.asarray(w_en, jnp.float32),
            "a_en": jnp.asarray(a_en, jnp.float32),
        }
    return qp


def default_globals():
    return {
        "use_lora": jnp.asarray(1.0, jnp.float32),
        "beta": jnp.asarray(20.0, jnp.float32),
        "gamma_c": jnp.asarray(0.01, jnp.float32),
        "l2_w": jnp.asarray(1.0, jnp.float32),
        "kld_w": jnp.asarray(1.0, jnp.float32),
    }


# ---------------------------------------------------------------------------
# RoPE + attention (shared by FP and quantized paths)
# ---------------------------------------------------------------------------

def rope_tables(seq, head_dim):
    pos = np.arange(seq)[:, None]
    freqs = 10000.0 ** (-np.arange(0, head_dim, 2) / head_dim)[None, :]
    ang = pos * freqs
    return (jnp.asarray(np.cos(ang), jnp.float32),
            jnp.asarray(np.sin(ang), jnp.float32))


def apply_rope(x, cos, sin):
    """x: [B, S, H, hd]."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c, s = cos[None, :, None, :], sin[None, :, None, :]
    y1 = x1 * c - x2 * s
    y2 = x1 * s + x2 * c
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape)


def attention(q, k, v, cfg: ModelConfig):
    """q/k/v: [B, S, d] -> [B, S, d]; causal, RoPE."""
    b, s, d = q.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, h, hd)
    v = v.reshape(b, s, h, hd)
    cos, sin = rope_tables(s, hd)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    scores = jnp.where(mask[None, None] > 0, scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.reshape(b, s, d)


# ---------------------------------------------------------------------------
# FP forward (pretraining path, pure jnp)
# ---------------------------------------------------------------------------

def _fp_rmsnorm(x, g, eps=1e-5):
    r = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x * r * g


def fp_block(b, h, cfg: ModelConfig):
    a = _fp_rmsnorm(h, b["attn_norm"])
    att = attention(a @ b["wq"], a @ b["wk"], a @ b["wv"], cfg)
    h = h + att @ b["wo"]
    m = _fp_rmsnorm(h, b["mlp_norm"])
    h = h + (jax.nn.silu(m @ b["wgate"]) * (m @ b["wup"])) @ b["wdown"]
    return h


def fp_forward(params, tokens, cfg: ModelConfig):
    """tokens: [B, S] int32 -> logits [B, S, V]."""
    h = params["embed"][tokens]
    for b in params["blocks"]:
        h = fp_block(b, h, cfg)
    h = _fp_rmsnorm(h, params["final_norm"])
    return h @ params["head"]


def xent(logits, targets):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# quantized path (Pallas kernels through STE seams)
# ---------------------------------------------------------------------------

def _round_rho(w, s_w):
    s = jnp.maximum(s_w, 1e-8)[None, :]
    wn = w / s
    return (wn - jnp.floor(wn) >= 0.5).astype(w.dtype)


def _rho(lin_q, w, glob):
    """Rounding offset. soft path: rho = h(V0 + A1 @ A2) where V0 is the
    AdaRound warm-start constant chosen by the coordinator so that
    h(V0) = frac(W/s) at init (soft-quantized weights == FP weights, the
    standard AdaRound initialization); the LoRA product learns a low-rank
    *delta* on top. The paper's A2 = 0 init makes the product zero, so V0
    fully determines the starting point."""
    soft = ste.lora_rho_offset(lin_q["v0"], lin_q["a1"], lin_q["a2"])
    hard = jax.lax.stop_gradient(_round_rho(w, lin_q["s_w"]))
    return glob["use_lora"] * soft + (1.0 - glob["use_lora"]) * hard


def qlinear(x2d, w, lin_q, glob):
    rho = _rho(lin_q, w, glob)
    w_hat = ste.qweight(w, lin_q["s_w"], rho, lin_q["qmax_w"], lin_q["w_en"])
    return ste.qmatmul(x2d, w_hat, lin_q["alpha"], lin_q["qmax_a"],
                       lin_q["a_en"])


def quant_block(b, qb, h, cfg: ModelConfig, glob, capture=None):
    bsz, s, d = h.shape
    h2 = h.reshape(bsz * s, d)
    a = ste.rmsnorm(h2, b["attn_norm"])
    if capture is not None:
        capture["attn_in"] = a
    q = qlinear(a, b["wq"], qb["wq"], glob).reshape(bsz, s, d)
    k = qlinear(a, b["wk"], qb["wk"], glob).reshape(bsz, s, d)
    v = qlinear(a, b["wv"], qb["wv"], glob).reshape(bsz, s, d)
    mix = attention(q, k, v, cfg).reshape(bsz * s, d)
    if capture is not None:
        capture["attn_mix"] = mix
    h2 = h2 + qlinear(mix, b["wo"], qb["wo"], glob)
    m = ste.rmsnorm(h2, b["mlp_norm"])
    if capture is not None:
        capture["mlp_in"] = m
    gate = qlinear(m, b["wgate"], qb["wgate"], glob)
    up = qlinear(m, b["wup"], qb["wup"], glob)
    act = jax.nn.silu(gate) * up
    if capture is not None:
        capture["mlp_act"] = act
    h2 = h2 + qlinear(act, b["wdown"], qb["wdown"], glob)
    return h2.reshape(bsz, s, d)


def recon_loss(h_q, target, glob):
    """Eq. 7: L2 + KLD over softmax of hidden states."""
    mse = jnp.mean((h_q - target) ** 2)
    logp = jax.nn.log_softmax(target, axis=-1)
    logq = jax.nn.log_softmax(h_q, axis=-1)
    kld = jnp.mean(jnp.sum(jnp.exp(logp) * (logp - logq), axis=-1))
    return glob["l2_w"] * mse + glob["kld_w"] * kld, mse, kld


def com_loss(qblocks, glob):
    """Eq. 12, mean-normalized per linear for cross-layer scale stability."""
    total = 0.0
    for qb in qblocks:
        for name in LINEAR_NAMES:
            rho = ste.lora_rho_offset(qb[name]["v0"], qb[name]["a1"],
                                      qb[name]["a2"])
            total = total + jnp.mean(
                1.0 - jnp.abs(2.0 * rho - 1.0) ** glob["beta"])
    return total


def window_forward(inputs, cfg: ModelConfig):
    """inputs: {h_in, target, blocks: [...], qblocks: [...], globals}.
    Quantized T_{i,k} forward + reconstruction loss (Eq. 6/7)."""
    h = inputs["h_in"]
    glob = inputs["globals"]
    for b, qb in zip(inputs["blocks"], inputs["qblocks"]):
        h = quant_block(b, qb, h, cfg, glob)
    rec, mse, kld = recon_loss(h, inputs["target"], glob)
    return {"h_out": h, "loss": rec, "mse": mse, "kld": kld}


def window_loss_grads(inputs, cfg: ModelConfig):
    """value-and-grad of L_total = L_rec + gamma_c*L_com (Eq. 13) wrt the
    learnable quant params (s_w, alpha, a1, a2) of every window linear."""
    learn = [{n: {k: qb[n][k] for k in ("s_w", "alpha", "a1", "a2")}
              for n in LINEAR_NAMES} for qb in inputs["qblocks"]]

    def loss_fn(learnable):
        qblocks = []
        for qb, lb in zip(inputs["qblocks"], learnable):
            nqb = {n: dict(qb[n]) for n in LINEAR_NAMES}
            for n in LINEAR_NAMES:
                nqb[n].update(lb[n])
            qblocks.append(nqb)
        h = inputs["h_in"]
        glob = inputs["globals"]
        for b, qb in zip(inputs["blocks"], qblocks):
            h = quant_block(b, qb, h, cfg, glob)
        rec, mse, kld = recon_loss(h, inputs["target"], glob)
        com = com_loss(qblocks, glob)
        return rec + glob["gamma_c"] * com, (mse, kld, com)

    (loss, (mse, kld, com)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(learn)
    return {"loss": loss, "mse": mse, "kld": kld, "com": com, "grads": grads}


def window_loss_grads_dense(inputs, cfg: ModelConfig):
    """Dense-AdaRound variant (paper Table 3b baseline): the rounding matrix
    V is a full [fan_in, fan_out] learnable per linear instead of A1 @ A2.
    qblocks carry key "v" instead of ("a1", "a2")."""
    learn = [{n: {k: qb[n][k] for k in ("s_w", "alpha", "v")}
              for n in LINEAR_NAMES} for qb in inputs["qblocks"]]

    def loss_fn(learnable):
        qblocks = []
        for qb, lb in zip(inputs["qblocks"], learnable):
            nqb = {n: dict(qb[n]) for n in LINEAR_NAMES}
            for n in LINEAR_NAMES:
                nqb[n].update(lb[n])
            qblocks.append(nqb)
        h = inputs["h_in"]
        glob = inputs["globals"]
        for b, qb in zip(inputs["blocks"], qblocks):
            h = quant_block_dense(b, qb, h, cfg, glob)
        rec, mse, kld = recon_loss(h, inputs["target"], glob)
        com = 0.0
        for qb in qblocks:
            for n in LINEAR_NAMES:
                rho = ste.dense_rho(qb[n]["v0"] + qb[n]["v"])
                com = com + jnp.mean(
                    1.0 - jnp.abs(2.0 * rho - 1.0) ** glob["beta"])
        return rec + glob["gamma_c"] * com, (mse, kld, com)

    (loss, (mse, kld, com)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(learn)
    return {"loss": loss, "mse": mse, "kld": kld, "com": com, "grads": grads}


def quant_block_dense(b, qb, h, cfg: ModelConfig, glob):
    """quant_block with dense-V rounding offsets."""
    def qlin(x2d, w, lin_q):
        rho = (glob["use_lora"] * ste.dense_rho(lin_q["v0"] + lin_q["v"])
               + (1.0 - glob["use_lora"])
               * jax.lax.stop_gradient(_round_rho(w, lin_q["s_w"])))
        w_hat = ste.qweight(w, lin_q["s_w"], rho, lin_q["qmax_w"],
                            lin_q["w_en"])
        return ste.qmatmul(x2d, w_hat, lin_q["alpha"], lin_q["qmax_a"],
                           lin_q["a_en"])

    bsz, s, d = h.shape
    h2 = h.reshape(bsz * s, d)
    a = ste.rmsnorm(h2, b["attn_norm"])
    q = qlin(a, b["wq"], qb["wq"]).reshape(bsz, s, d)
    k = qlin(a, b["wk"], qb["wk"]).reshape(bsz, s, d)
    v = qlin(a, b["wv"], qb["wv"]).reshape(bsz, s, d)
    mix = attention(q, k, v, cfg).reshape(bsz * s, d)
    h2 = h2 + qlin(mix, b["wo"], qb["wo"])
    m = ste.rmsnorm(h2, b["mlp_norm"])
    act = jax.nn.silu(qlin(m, b["wgate"], qb["wgate"])) * qlin(
        m, b["wup"], qb["wup"])
    h2 = h2 + qlin(act, b["wdown"], qb["wdown"])
    return h2.reshape(bsz, s, d)


def init_qparams_block_dense(cfg: ModelConfig, block_params, bits_w=4,
                             bits_a=16, w_en=1.0, a_en=0.0):
    """Dense-V counterpart of init_qparams_block."""
    qp = init_qparams_block(cfg, block_params, bits_w, bits_a, w_en, a_en)
    for name in LINEAR_NAMES:
        fan_in, fan_out = np.asarray(block_params[name]).shape
        del qp[name]["a1"], qp[name]["a2"]
        qp[name]["v"] = jnp.zeros((fan_in, fan_out), jnp.float32)  # keeps v0
    return qp


def block_capture(inputs, cfg: ModelConfig):
    """Single-block quantized forward that also returns every linear's raw
    input matrix (pre activation-quant) — the statistics feed for GPTQ,
    SmoothQuant/OS and CFP-activation."""
    cap = {}
    h = quant_block(inputs["blocks"][0], inputs["qblocks"][0], inputs["h_in"],
                    cfg, inputs["globals"], capture=cap)
    return {"h_out": h,
            "captures": {n: cap[CAPTURE_SOURCES[n]] for n in LINEAR_NAMES}}


def lm_eval(inputs, cfg: ModelConfig):
    """inputs: {h: [B,S,d], final_norm, head, targets int32 [B,S],
    mask f32 [B,S]} -> per-sequence masked NLL sums + token counts."""
    b, s, d = inputs["h"].shape
    h2 = ste.rmsnorm(inputs["h"].reshape(b * s, d), inputs["final_norm"])
    logits = (h2 @ inputs["head"]).reshape(b, s, -1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, inputs["targets"][..., None], axis=-1)[..., 0]
    nll = nll * inputs["mask"]
    return {"nll": jnp.sum(nll, axis=-1),
            "count": jnp.sum(inputs["mask"], axis=-1)}
