"""Straight-through-estimator seams between the L1 Pallas kernels and the
L2 gradient graphs.

round/floor have zero gradient, so the reconstruction optimization (Eq. 5/9)
needs custom gradient rules regardless of the kernel backend — that makes
`jax.custom_vjp` the natural interface: the *forward* runs the Pallas kernel
(interpret mode, same code path the Rust runtime executes), the *backward*
implements the LSQ-style step-size gradients and STE pass-through in jnp.

Gradient rules (v = x/s, in-range mask Z = [lo <= round(v) <= hi]):
  activations (learnable clip alpha, per-token s = alpha*max|x|/qmax):
     dL/dx     = g_x * (1 - a_en + a_en * Z)            (STE, clip cuts flow)
     dL/ds_tok = sum_k g_x * (round(v)-v) [in-range] or clip bound [clipped]
     dL/dalpha = sum_tok dL/ds_tok * max|x_tok| / qmax
  weights (per-channel s_w, rounding offset rho):
     dL/ds_w  = sum_K g_w * (q - v) [in-range] or q [clipped]   (LSQ)
     dL/drho  = g_w * s_w * Z   (flows into V = A1 @ A2 outside)
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.quant_matmul import quant_matmul as _pl_quant_matmul
from .kernels.quant_weight import quant_weight as _pl_quant_weight
from .kernels.rmsnorm import rmsnorm as _pl_rmsnorm

_one = lambda v: jnp.reshape(v, (1,)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# fused activation-quant matmul
# ---------------------------------------------------------------------------

@jax.custom_vjp
def qmatmul(x, w_hat, alpha, qmax, a_en):
    """x: [M,K] @ w_hat: [K,N] with per-token activation fake-quant.
    alpha/qmax/a_en are scalars (0-d arrays)."""
    return _pl_quant_matmul(x, w_hat, _one(alpha), _one(qmax), _one(a_en))


def _qmatmul_fwd(x, w_hat, alpha, qmax, a_en):
    y = qmatmul(x, w_hat, alpha, qmax, a_en)
    return y, (x, w_hat, alpha, qmax, a_en)


def _qmatmul_bwd(res, g):
    x, w_hat, alpha, qmax, a_en = res
    s = ref.act_scale(x, alpha, qmax)                 # [M,1]
    v = x / s
    r = jnp.round(v)
    lo, hi = -qmax - 1.0, qmax
    z = ((r >= lo) & (r <= hi)).astype(x.dtype)       # in-range mask
    rc = jnp.clip(r, lo, hi)
    x_q = rc * s
    x_eff = x + a_en * (x_q - x)

    dxe = g @ w_hat.T                                  # grad wrt x_eff
    dw_hat = x_eff.T @ g
    # STE through round; clipped activations stop gradient on the quant path
    dx = dxe * (1.0 - a_en + a_en * z)
    # LSQ step-size gradient, chained to alpha through s = alpha*max|x|/qmax
    dq_ds = jnp.where(z > 0, rc - v, rc)               # d x_q / d s
    ds_tok = jnp.sum(dxe * a_en * dq_ds, axis=-1, keepdims=True)
    m = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    dalpha = jnp.sum(ds_tok * m / qmax)
    return dx, dw_hat, jnp.reshape(dalpha, jnp.shape(alpha)), None, None


qmatmul.defvjp(_qmatmul_fwd, _qmatmul_bwd)


# ---------------------------------------------------------------------------
# weight fake-quant with rounding offset
# ---------------------------------------------------------------------------

@jax.custom_vjp
def qweight(w, s_w, rho, qmax, w_en):
    """w: [K,N], s_w: [N], rho: [K,N]; qmax/w_en scalars."""
    return _pl_quant_weight(w, s_w, rho, _one(qmax), _one(w_en))


def _qweight_fwd(w, s_w, rho, qmax, w_en):
    return qweight(w, s_w, rho, qmax, w_en), (w, s_w, rho, qmax, w_en)


def _qweight_bwd(res, g):
    w, s_w, rho, qmax, w_en = res
    s = jnp.maximum(s_w, ref.EPS)[None, :]
    v = w / s
    q_unc = jnp.floor(v) + rho
    lo, hi = -qmax - 1.0, qmax
    z = ((q_unc >= lo) & (q_unc <= hi)).astype(w.dtype)
    q = jnp.clip(q_unc, lo, hi)

    # w_hat = w + w_en * (q*s - w)
    dw = g * (1.0 - w_en + w_en * z)                   # STE pass-through
    dq_ds = jnp.where(z > 0, q - v, q)                 # LSQ per-channel
    ds_w = jnp.sum(g * w_en * dq_ds, axis=0)
    drho = g * w_en * s * z
    return dw, ds_w, drho, None, None


qweight.defvjp(_qweight_fwd, _qweight_bwd)


# ---------------------------------------------------------------------------
# rmsnorm (analytic backward; forward runs the Pallas kernel)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def rmsnorm(x, g):
    return _pl_rmsnorm(x, g)


def _rmsnorm_fwd(x, g):
    return rmsnorm(x, g), (x, g)


def _rmsnorm_bwd(res, gy):
    x, g = res
    d = x.shape[-1]
    ms = jnp.mean(x * x, axis=-1, keepdims=True) + 1e-5
    r = jax.lax.rsqrt(ms)
    gg = gy * g[None, :]
    dx = r * gg - x * (r ** 3) * jnp.mean(x * gg, axis=-1, keepdims=True)
    dgamma = jnp.sum(gy * x * r, axis=0)
    return dx, dgamma


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def lora_rho(a1, a2):
    """rho = rectified-sigmoid(V), V = A1 @ A2 (Eq. 8 + 11).
    zeta/gamma fixed to the paper's 1.1 / -0.1."""
    from .configs import ZETA, GAMMA
    v = a1 @ a2
    return jnp.clip(jax.nn.sigmoid(v) * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)


def lora_rho_offset(v0, a1, a2):
    """rho = rectified-sigmoid(V0 + A1 @ A2): AdaRound warm-start constant
    V0 plus the learnable low-rank delta (see model._rho)."""
    from .configs import ZETA, GAMMA
    v = v0 + a1 @ a2
    return jnp.clip(jax.nn.sigmoid(v) * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)


def dense_rho(v):
    """Dense-AdaRound rho = rectified-sigmoid(V) with a full V matrix."""
    from .configs import ZETA, GAMMA
    return jnp.clip(jax.nn.sigmoid(v) * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)


def rho_regularizer(rho, beta):
    """L_com = sum 1 - |2*rho - 1|^beta (Eq. 12), annealed via beta."""
    return jnp.sum(1.0 - jnp.abs(2.0 * rho - 1.0) ** beta)
