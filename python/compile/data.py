"""Synthetic corpus generator, bit-exact mirrored in rust/src/calib/corpus.rs.

Stand-in for C4 / WikiText2 (DESIGN.md §Substitutions): two corpus *styles*
with different statistics over a shared 256-token vocabulary, generated from
an xorshift64* PRNG using only integer ops so Python (pretraining, build time)
and Rust (calibration + eval, run time) produce identical streams.

Structure (what makes it learnable by a small transformer):
  * each SEGMENT_LEN-token segment opens with a topic-marker token
    (TOPIC_BASE + topic), then tokens follow a per-topic mixture of
      - a deterministic affine map  next = (a_t * cur + b_t) mod CONTENT_V
      - a "counting" continuation   next = cur + 1 mod CONTENT_V
      - a zipf-ish random draw (min of two uniforms biases low ids)
    so the model must infer the topic from context — an in-context task whose
    logits are sharp enough for quantization error to be measurable.
  * style "wiki" interleaves a rigid template (header tokens every 8
    positions) with lower-entropy content — a second, distinct distribution.
"""

SEGMENT_LEN = 32
CONTENT_V = 240      # content tokens are 0..CONTENT_V-1
TOPIC_BASE = 240     # topic markers are TOPIC_BASE..TOPIC_BASE+N_TOPICS-1
N_TOPICS = 8
HEADER_TOK = 250     # style-"wiki" template tokens
SEP_TOK = 251

MASK64 = (1 << 64) - 1

STYLE_C4 = "c4"
STYLE_WIKI = "wiki"


class XorShift64Star:
    """xorshift64* — trivially portable; mirrored in Rust."""

    def __init__(self, seed: int):
        self.state = (seed | 1) & MASK64

    def next_u64(self) -> int:
        x = self.state
        x ^= (x >> 12)
        x ^= (x << 25) & MASK64
        x ^= (x >> 27)
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & MASK64

    def next_below(self, n: int) -> int:
        return self.next_u64() % n


def _topic_params(topic: int):
    # multiplier must be coprime with CONTENT_V=240 (avoid factors 2,3,5)
    a = (7 * topic + 11) % CONTENT_V
    while a % 2 == 0 or a % 3 == 0 or a % 5 == 0:
        a = (a + 1) % CONTENT_V
    b = (13 * topic + 3) % CONTENT_V
    return a, b


def _zipfish(rng: XorShift64Star) -> int:
    r = rng.next_u64()
    t1 = r & 0xFF
    t2 = (r >> 8) & 0xFF
    return min(t1, t2) % CONTENT_V


def generate(style: str, seed: int, n_tokens: int) -> list:
    """Generate `n_tokens` tokens of the given style. Deterministic in
    (style, seed); mirrored bit-for-bit by rust/src/calib/corpus.rs."""
    rng = XorShift64Star(seed if style == STYLE_C4 else seed ^ 0x9E3779B97F4A7C15)
    out = []
    cur = 0
    topic = 0
    pos_in_seg = SEGMENT_LEN  # force topic draw at position 0
    while len(out) < n_tokens:
        if pos_in_seg >= SEGMENT_LEN:
            pos_in_seg = 0
            topic = rng.next_below(N_TOPICS)
            out.append(TOPIC_BASE + topic)
            cur = rng.next_below(CONTENT_V)
            pos_in_seg += 1
            continue
        if style == STYLE_WIKI and pos_in_seg % 8 == 0:
            out.append(HEADER_TOK if (pos_in_seg // 8) % 2 == 0 else SEP_TOK)
            pos_in_seg += 1
            continue
        a, b = _topic_params(topic)
        r = rng.next_below(100)
        # style-dependent mixture: wiki content is lower-entropy
        det_p, cnt_p = (55, 25) if style == STYLE_C4 else (70, 20)
        if r < det_p:
            cur = (a * cur + b) % CONTENT_V
        elif r < det_p + cnt_p:
            cur = (cur + 1) % CONTENT_V
        else:
            cur = _zipfish(rng)
        out.append(cur)
        pos_in_seg += 1
    return out[:n_tokens]


def batches(style: str, seed: int, n_batches: int, batch: int, seq: int):
    """Yield (n_batches, batch, seq+1) int token arrays (input + next-token
    target via shift), as nested lists."""
    toks = generate(style, seed, n_batches * batch * (seq + 1))
    it = iter(toks)
    for _ in range(n_batches):
        yield [[next(it) for _ in range(seq + 1)] for _ in range(batch)]
