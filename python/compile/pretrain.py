"""Build-time pretraining of the tiny-LLM substrate + function-preserving
outlier injection (DESIGN.md §Substitutions).

Why pretrain at all: CBQ's phenomena (inter/intra-layer Hessian dependencies,
rounding-loss landscape, outlier channels) only exist on *trained* weights.
This runs once inside `make artifacts`; Python never executes at
quantization/serving time.

Outlier injection (both transforms are exactly function-preserving):
  * activation outliers — scale selected channels of each RMSNorm weight by
    `gain` and the matching input rows of the consuming linears by 1/gain.
    This is the inverse of the SmoothQuant/OS+ equivalent transform, i.e. it
    plants exactly the per-channel activation outliers those methods (and
    CFP-activation) are designed to remove.
  * weight outliers — scale selected wv columns by `gain` and the matching
    wo rows by 1/gain (v-channels pass linearly through attention mixing),
    and likewise wup columns / wdown rows through the SwiGLU's linear `up`
    path. This plants large-magnitude weight columns (CFP-weight targets).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .configs import LINEAR_NAMES, ModelConfig
from .model import fp_forward, init_params, xent

PRETRAIN_SEED = 42
CORPUS_SEED = 42


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.asarray(0, jnp.int32)}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    mc = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vc = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps), params, mc, vc)
    return new, {"m": m, "v": v, "t": t}


def pretrain(cfg: ModelConfig, log=print):
    params = init_params(cfg, jax.random.PRNGKey(PRETRAIN_SEED))

    @jax.jit
    def step(params, state, x, y):
        def loss_fn(p):
            return xent(fp_forward(p, x, cfg), y)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = adam_step(params, grads, state, cfg.pretrain_lr)
        return params, state, loss

    state = adam_init(params)
    # alternate corpus styles so the model learns both eval distributions
    gens = {
        s: data.batches(s, CORPUS_SEED, cfg.pretrain_steps // 2 + 1,
                        cfg.pretrain_batch, cfg.seq)
        for s in (data.STYLE_C4, data.STYLE_WIKI)
    }
    t0 = time.time()
    loss = None
    for i in range(cfg.pretrain_steps):
        style = data.STYLE_C4 if i % 2 == 0 else data.STYLE_WIKI
        batch = np.asarray(next(gens[style]), dtype=np.int32)
        x, y = batch[:, :-1], batch[:, 1:]
        params, state, loss = step(params, state, x, y)
        if i % 100 == 0 or i == cfg.pretrain_steps - 1:
            log(f"  [{cfg.name}] step {i:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.0f}s)")
    return params, float(loss)


def inject_outliers(cfg: ModelConfig, params):
    """Function-preserving activation + weight outlier injection. Returns a
    new params tree; channel indices are deterministic per (layer, seed)."""
    rng = np.random.default_rng(1234)
    p = jax.tree_util.tree_map(np.asarray, params)
    d, f = cfg.d_model, cfg.d_ffn
    g = cfg.outlier_gain
    for li, b in enumerate(p["blocks"]):
        # activation outliers: attn path
        ch = rng.choice(d, size=cfg.outlier_channels, replace=False)
        b["attn_norm"] = b["attn_norm"].copy()
        b["attn_norm"][ch] *= g
        for name in ("wq", "wk", "wv"):
            b[name] = b[name].copy()
            b[name][ch, :] /= g
        # activation outliers: mlp path
        ch2 = rng.choice(d, size=cfg.outlier_channels, replace=False)
        b["mlp_norm"] = b["mlp_norm"].copy()
        b["mlp_norm"][ch2] *= g
        for name in ("wgate", "wup"):
            b[name] = b[name].copy()
            b[name][ch2, :] /= g
        # weight outliers: v-channel pairs + up-channel pairs
        vc = rng.choice(d, size=max(1, cfg.outlier_channels // 2), replace=False)
        b["wv"] = b["wv"].copy(); b["wo"] = b["wo"].copy()
        b["wv"][:, vc] *= g
        b["wo"][vc, :] /= g
        uc = rng.choice(f, size=max(1, cfg.outlier_channels // 2), replace=False)
        b["wup"] = b["wup"].copy(); b["wdown"] = b["wdown"].copy()
        b["wup"][:, uc] *= g
        b["wdown"][uc, :] /= g
    return p


def params_to_tensors(params) -> dict:
    out = {"embed": np.asarray(params["embed"]),
           "final_norm": np.asarray(params["final_norm"]),
           "head": np.asarray(params["head"])}
    for i, b in enumerate(params["blocks"]):
        out[f"blocks.{i}.attn_norm"] = np.asarray(b["attn_norm"])
        out[f"blocks.{i}.mlp_norm"] = np.asarray(b["mlp_norm"])
        for name in LINEAR_NAMES:
            out[f"blocks.{i}.{name}"] = np.asarray(b[name])
    return out


def tensors_to_params(tensors, cfg: ModelConfig):
    blocks = []
    for i in range(cfg.n_layers):
        b = {"attn_norm": jnp.asarray(tensors[f"blocks.{i}.attn_norm"]),
             "mlp_norm": jnp.asarray(tensors[f"blocks.{i}.mlp_norm"])}
        for name in LINEAR_NAMES:
            b[name] = jnp.asarray(tensors[f"blocks.{i}.{name}"])
        blocks.append(b)
    return {"embed": jnp.asarray(tensors["embed"]),
            "final_norm": jnp.asarray(tensors["final_norm"]),
            "head": jnp.asarray(tensors["head"]),
            "blocks": blocks}
