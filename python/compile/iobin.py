"""CBQW binary tensor container — written here, read by rust/src/tensor/io.rs.

Layout (little-endian):
  magic  b"CBQW" | u32 version=1 | u32 n_tensors
  per tensor: u32 name_len | name utf-8 | u8 dtype (0=f32, 1=i32)
              | u8 ndim | u32 dims[ndim] | raw row-major data
"""

import struct

import numpy as np

MAGIC = b"CBQW"
VERSION = 1
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_tensors(path: str, tensors: dict):
    """tensors: {name: np.ndarray (f32 or i32)}."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name])
            if arr.dtype not in DTYPES:
                arr = arr.astype(np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_tensors(path: str) -> dict:
    """Python-side reader (round-trip tests)."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC
        version, n = struct.unpack("<II", f.read(8))
        assert version == VERSION
        for _ in range(n):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = np.float32 if dt == 0 else np.int32
            count = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(count * 4), dtype=dtype)
            out[name] = data.reshape(dims)
    return out
