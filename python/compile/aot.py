"""AOT export: lower the L2 graphs to HLO **text** + write the manifest the
Rust runtime binds against. Runs once inside `make artifacts`.

Interchange is HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Per model config this exports:
  win_fwd_w{K}   quantized T_{i,k} forward + reconstruction loss
  win_grad_w{K}  value-and-grad wrt (s_w, alpha, A1, A2)        (Eq. 9/13)
  capture        single-block forward + per-linear input capture
  lm_eval        final-norm + LM-head masked NLL
plus weights_{cfg}.bin (pretrained + outlier-injected weights) and
corpus_ref.json (cross-language PRNG parity vectors for the Rust tests).
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, iobin, model, pretrain
from .configs import CONFIGS, LINEAR_NAMES, WINDOWS, ModelConfig


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True: the default ELIDES big constant arrays as
    # `constant({...})`, which xla_extension 0.5.1's text parser silently
    # mis-reads (RoPE tables became garbage). Positional bool = that flag.
    return comp.as_hlo_text(True)


def _spec_of(leaf):
    arr = jnp.asarray(leaf)
    return jax.ShapeDtypeStruct(arr.shape, arr.dtype)


def export_graph(name, graph_fn, cfg, example_inputs, out_dir, manifest):
    """Lower graph_fn(inputs, cfg) with the flatten_spec contract and record
    input/output names, shapes and dtypes in the manifest."""
    flat = model.flatten_spec(example_inputs)
    in_names = [n for n, _ in flat]
    in_specs = [_spec_of(l) for _, l in flat]

    def wrapped(*leaves):
        inputs = model.unflatten_like(example_inputs, leaves)
        out = graph_fn(inputs, cfg)
        return tuple(l for _, l in model.flatten_spec(out))

    t0 = time.time()
    lowered = jax.jit(wrapped, keep_unused=True).lower(*in_specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)

    # output spec via eval_shape (no execution)
    out_shapes = jax.eval_shape(wrapped, *in_specs)
    out_example = graph_fn(example_inputs, cfg)  # names from the dict
    out_names = [n for n, _ in model.flatten_spec(out_example)]

    def spec_list(names, specs):
        return [{"name": n,
                 "shape": [int(d) for d in s.shape],
                 "dtype": str(np.dtype(s.dtype))}
                for n, s in zip(names, specs)]

    manifest["executables"][name] = {
        "file": fname,
        "inputs": spec_list(in_names, in_specs),
        "outputs": spec_list(out_names, list(out_shapes)),
    }
    print(f"  exported {name}: {len(in_names)} inputs, "
          f"{len(out_names)} outputs, {len(text) // 1024}KiB "
          f"({time.time() - t0:.1f}s)")


def example_window_inputs(cfg: ModelConfig, params, w: int):
    blocks = params["blocks"][:w]
    qblocks = [model.init_qparams_block(cfg, b) for b in blocks]
    shape = (cfg.batch, cfg.seq, cfg.d_model)
    return {
        "h_in": jnp.zeros(shape, jnp.float32),
        "target": jnp.zeros(shape, jnp.float32),
        "blocks": blocks,
        "qblocks": qblocks,
        "globals": model.default_globals(),
    }


def export_config(cfg: ModelConfig, out_dir: str, manifest: dict,
                  skip_pretrain: bool):
    print(f"config {cfg.name}: d={cfg.d_model} L={cfg.n_layers}")
    wpath = os.path.join(out_dir, f"weights_{cfg.name}.bin")
    if skip_pretrain and os.path.exists(wpath):
        tensors = iobin.read_tensors(wpath)
        params = pretrain.tensors_to_params(tensors, cfg)
        print("  reusing existing weights")
    else:
        params, final_loss = pretrain.pretrain(cfg)
        params = pretrain.inject_outliers(cfg, params)
        params = jax.tree_util.tree_map(jnp.asarray, params)
        iobin.write_tensors(wpath, pretrain.params_to_tensors(params))
        manifest["pretrain_loss"][cfg.name] = final_loss

    for w in WINDOWS[cfg.name]:
        ex = example_window_inputs(cfg, params, w)
        export_graph(f"win_fwd_w{w}_{cfg.name}", model.window_forward,
                     cfg, ex, out_dir, manifest)
        export_graph(f"win_grad_w{w}_{cfg.name}", model.window_loss_grads,
                     cfg, ex, out_dir, manifest)

    # dense-AdaRound grad variant (Table 3b memory/speed baseline), w=2
    ex_d = example_window_inputs(cfg, params, 2)
    ex_d["qblocks"] = [
        model.init_qparams_block_dense(cfg, b) for b in ex_d["blocks"]
    ]
    export_graph(f"win_grad_dense_w2_{cfg.name}", model.window_loss_grads_dense,
                 cfg, ex_d, out_dir, manifest)

    ex1 = example_window_inputs(cfg, params, 1)
    export_graph(f"capture_{cfg.name}", model.block_capture, cfg, ex1,
                 out_dir, manifest)

    lm_ex = {
        "h": jnp.zeros((cfg.batch, cfg.seq, cfg.d_model), jnp.float32),
        "final_norm": params["final_norm"],
        "head": params["head"],
        "targets": jnp.zeros((cfg.batch, cfg.seq), jnp.int32),
        "mask": jnp.ones((cfg.batch, cfg.seq), jnp.float32),
    }
    export_graph(f"lm_eval_{cfg.name}", model.lm_eval, cfg, lm_ex,
                 out_dir, manifest)


def test_reference(cfg: ModelConfig, out_dir: str):
    """Cross-language parity tensors for rust/tests/integration.rs: tokens,
    embedding, FP hidden states and per-sequence NLL on the eval stream."""
    EVAL_SEED = 2002  # mirrors rust calib::EVAL_SEED
    tensors = iobin.read_tensors(os.path.join(out_dir, f"weights_{cfg.name}.bin"))
    params = pretrain.tensors_to_params(tensors, cfg)
    toks = data.generate(data.STYLE_C4, EVAL_SEED, cfg.batch * (cfg.seq + 1))
    rows = np.array(toks, dtype=np.int32).reshape(cfg.batch, cfg.seq + 1)
    x, y = rows[:, :-1], rows[:, 1:]
    h = params["embed"][jnp.asarray(x)]
    ref = {"tokens_x": x, "tokens_y": y,
           "h_embed": np.asarray(h, np.float32)}
    for i, b in enumerate(params["blocks"]):
        h = model.fp_block(b, h, cfg)
        if i < 2:
            ref[f"h_block{i}"] = np.asarray(h, np.float32)
    ref["h_final"] = np.asarray(h, np.float32)
    hn = model._fp_rmsnorm(h, params["final_norm"])
    logits = hn @ params["head"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -np.take_along_axis(np.asarray(logp), y[..., None], axis=-1)[..., 0]
    ref["nll_per_seq"] = nll.sum(axis=1).astype(np.float32)
    iobin.write_tensors(os.path.join(out_dir, f"test_ref_{cfg.name}.bin"), ref)
    print(f"  test reference for {cfg.name} written")


def corpus_reference():
    """Cross-language parity vectors for rust/src/calib/corpus.rs tests."""
    return {
        style: data.generate(style, pretrain.CORPUS_SEED, 2048)
        for style in (data.STYLE_C4, data.STYLE_WIKI)
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts directory (manifest.json written here)")
    ap.add_argument("--configs", default="t,s,m")
    ap.add_argument("--skip-pretrain", action="store_true",
                    help="reuse existing weights_*.bin if present")
    args = ap.parse_args()
    out_dir = args.out if os.path.isabs(args.out) else os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "version": 1,
        "configs": {},
        "executables": {},
        "pretrain_loss": {},
        "linears": list(LINEAR_NAMES),
        "windows": {},
        "capture_sources": model.CAPTURE_SOURCES,
    }
    for name in args.configs.split(","):
        cfg = CONFIGS[name]
        manifest["configs"][name] = cfg.to_dict()
        manifest["windows"][name] = list(WINDOWS[name])
        export_config(cfg, out_dir, manifest, args.skip_pretrain)
        if name == "t":
            test_reference(cfg, out_dir)

    with open(os.path.join(out_dir, "corpus_ref.json"), "w") as f:
        json.dump(corpus_reference(), f)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['executables'])} executables")


if __name__ == "__main__":
    main()
