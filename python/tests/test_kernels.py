"""L1 correctness: every Pallas kernel (interpret mode) vs its pure-jnp
oracle in kernels/ref.py, with hypothesis sweeping shapes and value ranges.
This is the core correctness signal for the whole stack — the AOT graphs are
built from exactly these kernels.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.quant_matmul import quant_matmul
from compile.kernels.quant_weight import quant_weight
from compile.kernels.rmsnorm import rmsnorm

one = lambda v: jnp.asarray([v], dtype=jnp.float32)


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


dims = st.sampled_from([32, 64, 96, 128])
qmaxes = st.sampled_from([1.0, 3.0, 7.0, 31.0, 127.0])
seeds = st.integers(0, 2 ** 31 - 1)


class TestQuantMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=dims, k=dims, n=dims, qmax=qmaxes, seed=seeds,
           alpha=st.floats(0.3, 1.5), a_en=st.sampled_from([0.0, 1.0]))
    def test_matches_ref(self, m, k, n, qmax, seed, alpha, a_en):
        rng = np.random.default_rng(seed)
        x = rand(rng, m, k)
        w = rand(rng, k, n)
        got = quant_matmul(x, w, one(alpha), one(qmax), one(a_en))
        want = ref.quant_matmul(x, w, alpha, qmax, a_en)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_fp_path_is_exact_matmul(self):
        rng = np.random.default_rng(0)
        x, w = rand(rng, 64, 32), rand(rng, 32, 64)
        got = quant_matmul(x, w, one(1.0), one(7.0), one(0.0))
        np.testing.assert_allclose(got, x @ w, rtol=1e-5, atol=1e-6)

    def test_tile_size_invariance(self):
        rng = np.random.default_rng(1)
        x, w = rand(rng, 128, 64), rand(rng, 64, 64)
        a = quant_matmul(x, w, one(0.9), one(7.0), one(1.0), tm=32)
        b = quant_matmul(x, w, one(0.9), one(7.0), one(1.0), tm=128)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    def test_quantized_output_changes(self):
        """W4-A2-style quantization must actually perturb the output."""
        rng = np.random.default_rng(2)
        x, w = rand(rng, 64, 64), rand(rng, 64, 64)
        fp = quant_matmul(x, w, one(1.0), one(7.0), one(0.0))
        q = quant_matmul(x, w, one(1.0), one(1.0), one(1.0))
        assert float(jnp.max(jnp.abs(fp - q))) > 1e-3

    def test_zero_input_safe(self):
        x = jnp.zeros((32, 32), jnp.float32)
        w = jnp.ones((32, 32), jnp.float32)
        got = quant_matmul(x, w, one(1.0), one(7.0), one(1.0))
        assert bool(jnp.all(jnp.isfinite(got)))
        np.testing.assert_allclose(got, 0.0, atol=1e-6)


class TestQuantWeight:
    @settings(max_examples=25, deadline=None)
    @given(k=dims, n=dims, qmax=qmaxes, seed=seeds,
           w_en=st.sampled_from([0.0, 1.0]))
    def test_matches_ref(self, k, n, qmax, seed, w_en):
        rng = np.random.default_rng(seed)
        w = rand(rng, k, n)
        s = jnp.asarray(np.abs(rng.normal(size=n)).astype(np.float32)
                        * 0.05 + 0.02)
        rho = jnp.asarray(rng.uniform(size=(k, n)).astype(np.float32))
        got = quant_weight(w, s, rho, one(qmax), one(w_en))
        want = ref.blend_weight(w, s, rho, qmax, w_en)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_grid_levels(self):
        """Quantized weights with integer rho land on the integer grid."""
        rng = np.random.default_rng(3)
        w = rand(rng, 32, 32)
        s = jnp.full((32,), 0.1, jnp.float32)
        rho = ref.round_ste_rho(w, s)
        q = quant_weight(w, s, rho, one(7.0), one(1.0))
        lev = np.asarray(q) / 0.1
        np.testing.assert_allclose(lev, np.round(lev), atol=1e-4)
        assert lev.min() >= -8.0 - 1e-4 and lev.max() <= 7.0 + 1e-4

    def test_rho_moves_rounding(self):
        """rho=0 floors, rho=1 ceils: differ by exactly one step where the
        value is fractional."""
        w = jnp.asarray([[0.149, -0.151]], jnp.float32)
        s = jnp.asarray([0.1, 0.1], jnp.float32)
        lo = quant_weight(w, s, jnp.zeros((1, 2)), one(7.0), one(1.0))
        hi = quant_weight(w, s, jnp.ones((1, 2)), one(7.0), one(1.0))
        np.testing.assert_allclose(np.asarray(hi - lo), 0.1, atol=1e-6)

    def test_disable_is_identity(self):
        rng = np.random.default_rng(4)
        w = rand(rng, 64, 32)
        s = jnp.full((32,), 0.07, jnp.float32)
        rho = jnp.full((64, 32), 0.5, jnp.float32)
        got = quant_weight(w, s, rho, one(7.0), one(0.0))
        np.testing.assert_allclose(got, w, atol=0)


class TestRmsNorm:
    @settings(max_examples=25, deadline=None)
    @given(m=dims, d=dims, seed=seeds, scale=st.floats(0.1, 10.0))
    def test_matches_ref(self, m, d, seed, scale):
        rng = np.random.default_rng(seed)
        x = rand(rng, m, d, scale=scale)
        g = rand(rng, d)
        got = rmsnorm(x, g)
        want = ref.rmsnorm(x, g)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_unit_rms(self):
        rng = np.random.default_rng(5)
        x = rand(rng, 64, 128, scale=3.0)
        y = rmsnorm(x, jnp.ones((128,), jnp.float32))
        rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
        np.testing.assert_allclose(rms, 1.0, atol=1e-2)
