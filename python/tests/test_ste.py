"""Gradient-rule correctness for the STE custom_vjp seams (ste.py).

The analytic LSQ/STE backward rules are validated against finite differences
of the *smooth surrogate* where one exists (loss through fake-quant is
piecewise-smooth; we test away from rounding boundaries), and against known
closed forms (rmsnorm).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import ste
from compile.kernels import ref


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


def fd_grad(f, x, eps=1e-3):
    """Central finite differences on a scalar function of one array."""
    x = np.asarray(x, np.float64)
    g = np.zeros_like(x)
    it = np.nditer(x, flags=["multi_index"])
    while not it.finished:
        idx = it.multi_index
        xp, xm = x.copy(), x.copy()
        xp[idx] += eps
        xm[idx] -= eps
        g[idx] = (f(jnp.asarray(xp, jnp.float32))
                  - f(jnp.asarray(xm, jnp.float32))) / (2 * eps)
        it.iternext()
    return g


class TestQmatmulGrads:
    def test_w_hat_grad_exact(self):
        """d/dw_hat is exact (no STE involved): x_eff^T @ g."""
        rng = np.random.default_rng(0)
        x, w = rand(rng, 8, 4), rand(rng, 4, 6)

        def loss(w_):
            return jnp.sum(ste.qmatmul(x, w_, jnp.asarray(0.9),
                                       jnp.asarray(7.0), jnp.asarray(1.0)))

        g = jax.grad(loss)(w)
        x_eff = ref.blend_act(x, 0.9, 7.0, 1.0)
        want = x_eff.T @ jnp.ones((8, 6))
        np.testing.assert_allclose(g, want, rtol=1e-5, atol=1e-5)

    def test_fp_path_grads_are_plain_matmul(self):
        rng = np.random.default_rng(1)
        x, w = rand(rng, 8, 4), rand(rng, 4, 6)

        def loss(x_):
            return jnp.sum(ste.qmatmul(x_, w, jnp.asarray(1.0),
                                       jnp.asarray(7.0), jnp.asarray(0.0)))

        g = jax.grad(loss)(x)
        np.testing.assert_allclose(g, jnp.ones((8, 6)) @ w.T,
                                   rtol=1e-5, atol=1e-5)

    def test_alpha_grad_sign_reduces_loss(self):
        """Following -grad(alpha) on a pure reconstruction loss must reduce
        it (sanity for the LSQ chain rule through the per-token scale)."""
        rng = np.random.default_rng(2)
        x, w = rand(rng, 32, 16), rand(rng, 16, 16)
        y_fp = x @ w

        def loss(alpha):
            y = ste.qmatmul(x, w, alpha, jnp.asarray(1.0), jnp.asarray(1.0))
            return jnp.mean((y - y_fp) ** 2)

        a0 = jnp.asarray(1.0)
        l0 = loss(a0)
        g = jax.grad(loss)(a0)
        a1 = a0 - 0.05 * jnp.sign(g)
        assert float(loss(a1)) < float(l0) + 1e-6

    def test_alpha_grad_nonzero_when_quantizing(self):
        rng = np.random.default_rng(3)
        x, w = rand(rng, 16, 8), rand(rng, 8, 8)

        def loss(alpha):
            return jnp.sum(ste.qmatmul(x, w, alpha, jnp.asarray(3.0),
                                       jnp.asarray(1.0)) ** 2)

        assert abs(float(jax.grad(loss)(jnp.asarray(0.8)))) > 0.0


class TestQweightGrads:
    def test_rho_grad_matches_fd(self):
        """rho enters w_hat linearly (in-range): analytic grad = s_w * g."""
        rng = np.random.default_rng(4)
        w = rand(rng, 6, 4, scale=0.3)
        s = jnp.full((4,), 0.11, jnp.float32)
        rho = jnp.asarray(rng.uniform(0.2, 0.8, size=(6, 4)), jnp.float32)

        def loss(r):
            return jnp.sum(ste.qweight(w, s, r, jnp.asarray(7.0),
                                       jnp.asarray(1.0)) ** 2) * 0.5

        g = jax.grad(loss)(rho)
        w_hat = ref.fake_quant_weight(w, s, rho, 7.0)
        fd = fd_grad(lambda r: float(loss(r)), rho, eps=1e-3)
        np.testing.assert_allclose(g, fd, rtol=2e-2, atol=2e-3)
        # in-range entries: d w_hat / d rho = s
        np.testing.assert_allclose(g, np.asarray(w_hat) * 0.11, rtol=1e-4,
                                   atol=1e-5)

    def test_s_w_grad_direction(self):
        """Minimizing ||fq(W)-W||^2 over s_w via the LSQ gradient must make
        progress from a deliberately-wrong init."""
        rng = np.random.default_rng(5)
        w = rand(rng, 32, 16, scale=0.5)
        rho = ref.round_ste_rho(w, jnp.full((16,), 0.2, jnp.float32))

        def loss(s):
            r = ref.round_ste_rho(w, s)
            return jnp.mean((ste.qweight(w, s, r, jnp.asarray(7.0),
                                         jnp.asarray(1.0)) - w) ** 2)

        s = jnp.full((16,), 0.2, jnp.float32)  # too coarse
        l0 = float(loss(s))
        for _ in range(50):
            g = jax.grad(loss)(s)
            s = s - 0.01 * g
        assert float(loss(s)) < l0

    def test_disabled_weight_quant_passes_grad_through(self):
        rng = np.random.default_rng(6)
        w = rand(rng, 8, 8)
        s = jnp.full((8,), 0.1, jnp.float32)
        rho = jnp.full((8, 8), 0.5, jnp.float32)

        def loss(w_):
            return jnp.sum(ste.qweight(w_, s, rho, jnp.asarray(7.0),
                                       jnp.asarray(0.0)))

        np.testing.assert_allclose(jax.grad(loss)(w), 1.0, atol=0)


class TestRmsnormGrads:
    def test_matches_jax_autodiff(self):
        rng = np.random.default_rng(7)
        x = rand(rng, 16, 8)
        g = rand(rng, 8)

        def ours(x_, g_):
            return jnp.sum(jnp.sin(ste.rmsnorm(x_, g_)))

        def theirs(x_, g_):
            return jnp.sum(jnp.sin(ref.rmsnorm(x_, g_)))

        gx1, gg1 = jax.grad(ours, argnums=(0, 1))(x, g)
        gx2, gg2 = jax.grad(theirs, argnums=(0, 1))(x, g)
        np.testing.assert_allclose(gx1, gx2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(gg1, gg2, rtol=1e-4, atol=1e-5)


class TestLoraRho:
    def test_range_and_regularizer(self):
        rng = np.random.default_rng(8)
        a1 = rand(rng, 16, 4, scale=2.0)
        a2 = rand(rng, 4, 8, scale=2.0)
        rho = ste.lora_rho(a1, a2)
        assert float(jnp.min(rho)) >= 0.0 and float(jnp.max(rho)) <= 1.0
        # regularizer: zero iff rho is exactly binary
        binary = jnp.asarray([[0.0, 1.0], [1.0, 0.0]])
        assert float(ste.rho_regularizer(binary, 2.0)) < 1e-6
        mid = jnp.full((2, 2), 0.5)
        assert float(ste.rho_regularizer(mid, 2.0)) > 3.9

    def test_zero_a2_gives_near_round_init(self):
        """A2=0 => V=0 => rho ~ 0.55: the paper's zero-offset init."""
        a1 = jnp.ones((4, 2))
        a2 = jnp.zeros((2, 4))
        rho = ste.lora_rho(a1, a2)
        np.testing.assert_allclose(rho, 0.5, atol=0.06)
