"""L2 model-level correctness: FP/quant path parity, loss properties,
window semantics, capture wiring, lm_eval, and the flatten contract that the
Rust runtime depends on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model
from compile.configs import CONFIGS, LINEAR_NAMES

CFG = CONFIGS["t"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


def window_inputs(params, w, bits_w=4, bits_a=16, w_en=1.0, a_en=0.0,
                  seed=0, use_lora=1.0):
    rng = np.random.default_rng(seed)
    shape = (CFG.batch, CFG.seq, CFG.d_model)
    blocks = params["blocks"][:w]
    glob = model.default_globals()
    # use_lora=0 selects the nearest-rounding rho path; with the AdaRound
    # warm-start (V0), the soft path is near-lossless at init by design.
    glob["use_lora"] = jnp.asarray(use_lora, jnp.float32)
    return {
        "h_in": jnp.asarray(rng.normal(size=shape).astype(np.float32)),
        "target": jnp.asarray(rng.normal(size=shape).astype(np.float32)),
        "blocks": blocks,
        "qblocks": [model.init_qparams_block(CFG, b, bits_w, bits_a,
                                             w_en, a_en) for b in blocks],
        "globals": glob,
    }


class TestFlattenContract:
    def test_roundtrip(self, params):
        ins = window_inputs(params, 2)
        flat = model.flatten_spec(ins)
        rebuilt = model.unflatten_like(ins, [l for _, l in flat])
        flat2 = model.flatten_spec(rebuilt)
        assert [n for n, _ in flat] == [n for n, _ in flat2]
        for (_, a), (_, b) in zip(flat, flat2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_names_deterministic_and_unique(self, params):
        ins = window_inputs(params, 2)
        names = [n for n, _ in model.flatten_spec(ins)]
        assert names == sorted(set(names), key=names.index)
        assert len(set(names)) == len(names)
        assert "blocks.0.wq" in names
        assert "qblocks.1.wdown.s_w" in names
        assert "globals.use_lora" in names


class TestQuantFpParity:
    def test_disabled_quant_matches_fp_block(self, params):
        """w_en=a_en=0 through the Pallas/STE path must equal the pure-jnp
        FP block — the contract that lets one artifact serve the FP path."""
        ins = window_inputs(params, 2, w_en=0.0, a_en=0.0)
        out = model.window_forward(ins, CFG)
        h = ins["h_in"]
        for b in ins["blocks"]:
            h = model.fp_block(b, h, CFG)
        np.testing.assert_allclose(np.asarray(out["h_out"]), np.asarray(h),
                                   rtol=2e-4, atol=2e-4)

    def test_quant_perturbs_output(self, params):
        fp = model.window_forward(window_inputs(params, 1, w_en=0.0), CFG)
        q2 = model.window_forward(
            window_inputs(params, 1, bits_w=2, w_en=1.0, use_lora=0.0), CFG)
        delta = float(jnp.mean(jnp.abs(fp["h_out"] - q2["h_out"])))
        assert delta > 1e-3

    def test_more_bits_less_error(self, params):
        """W8 reconstruction error << W2 error vs the FP output."""
        fp = model.window_forward(window_inputs(params, 1, w_en=0.0), CFG)
        errs = {}
        for bits in (2, 8):
            q = model.window_forward(
                window_inputs(params, 1, bits_w=bits, w_en=1.0,
                              use_lora=0.0), CFG)
            errs[bits] = float(jnp.mean((q["h_out"] - fp["h_out"]) ** 2))
        assert errs[8] < errs[2] * 0.05


class TestLosses:
    def test_recon_loss_zero_at_target(self):
        glob = model.default_globals()
        h = jnp.ones((2, 4, 8))
        loss, mse, kld = model.recon_loss(h, h, glob)
        assert float(loss) < 1e-6

    def test_kld_nonnegative(self):
        rng = np.random.default_rng(0)
        glob = model.default_globals()
        a = jnp.asarray(rng.normal(size=(2, 4, 8)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(2, 4, 8)).astype(np.float32))
        _, _, kld = model.recon_loss(a, b, glob)
        assert float(kld) >= 0.0

    def test_loss_weights_gate_terms(self):
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.normal(size=(2, 4, 8)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(2, 4, 8)).astype(np.float32))
        g = model.default_globals()
        g_l2 = dict(g, l2_w=jnp.asarray(1.0), kld_w=jnp.asarray(0.0))
        g_kl = dict(g, l2_w=jnp.asarray(0.0), kld_w=jnp.asarray(1.0))
        l2only, mse, _ = model.recon_loss(a, b, g_l2)
        klonly, _, kld = model.recon_loss(a, b, g_kl)
        np.testing.assert_allclose(float(l2only), float(mse), rtol=1e-6)
        np.testing.assert_allclose(float(klonly), float(kld), rtol=1e-6)


class TestWindowGrads:
    def test_grad_shapes_and_nonzero(self, params):
        ins = window_inputs(params, 2, bits_w=4, w_en=1.0, a_en=1.0,
                            bits_a=8)
        out = model.window_loss_grads(ins, CFG)
        assert np.isfinite(float(out["loss"]))
        g0 = out["grads"][0]["wq"]
        assert g0["s_w"].shape == (CFG.d_model,)
        assert g0["a1"].shape == (CFG.d_model, CFG.rank_pad)
        total = sum(float(jnp.sum(jnp.abs(g[n][k])))
                    for g in out["grads"] for n in LINEAR_NAMES
                    for k in ("s_w", "alpha", "a1", "a2"))
        assert total > 0.0

    def test_adam_on_quant_params_reduces_loss(self, params):
        """Adam steps on (s_w, alpha) under the nearest-rounding path must
        reduce the window reconstruction loss — the LSQ scale-learning
        mechanic the Rust coordinator implements. (The LoRA path starts
        near-lossless by the V0 warm-start, so its loss has no room to
        fall; rounding learning is validated end-to-end in rust/tests.)"""
        ins = window_inputs(params, 1, bits_w=3, w_en=1.0, a_en=0.0,
                            use_lora=0.0)
        fp = model.window_forward(window_inputs(params, 1, w_en=0.0), CFG)
        ins["target"] = fp["h_out"]
        ins["h_in"] = window_inputs(params, 1)["h_in"]

        gfn = jax.jit(lambda i: model.window_loss_grads(i, CFG))
        mom = {}

        def adam(key, p, g, lr, t):
            m, v = mom.get(key, (jnp.zeros_like(p), jnp.zeros_like(p)))
            m = 0.9 * m + 0.1 * g
            v = 0.999 * v + 0.001 * g * g
            mom[key] = (m, v)
            mh, vh = m / (1 - 0.9 ** t), v / (1 - 0.999 ** t)
            return p - lr * mh / (jnp.sqrt(vh) + 1e-8)

        lrs = {"s_w": 1e-4, "alpha": 1e-3}
        losses = []
        for step in range(30):
            out = gfn(ins)
            losses.append(float(out["loss"]))
            for bi, gb in enumerate(out["grads"]):
                for n in LINEAR_NAMES:
                    for k in ("s_w", "alpha"):
                        ins["qblocks"][bi][n][k] = adam(
                            (bi, n, k), ins["qblocks"][bi][n][k],
                            gb[n][k], lrs[k], step + 1)
        assert losses[-1] < losses[0]

    def test_lora_warm_start_is_near_lossless(self, params):
        """With the V0 warm-start (soft rho == frac(W/s) at init), the soft
        quantized forward matches FP closely even at 3 bits — the property
        that makes short calibration schedules viable."""
        soft = model.window_forward(
            window_inputs(params, 1, bits_w=3, w_en=1.0, use_lora=1.0), CFG)
        hard = model.window_forward(
            window_inputs(params, 1, bits_w=3, w_en=1.0, use_lora=0.0), CFG)
        fp = model.window_forward(window_inputs(params, 1, w_en=0.0), CFG)
        err_soft = float(jnp.mean((soft["h_out"] - fp["h_out"]) ** 2))
        err_hard = float(jnp.mean((hard["h_out"] - fp["h_out"]) ** 2))
        assert err_soft < err_hard * 0.05, (err_soft, err_hard)


class TestCapture:
    def test_capture_shapes_and_consistency(self, params):
        ins = window_inputs(params, 1)
        out = model.block_capture(ins, CFG)
        m = CFG.batch * CFG.seq
        for n in LINEAR_NAMES:
            fan_in = model.linear_shapes(CFG)[n][0]
            assert out["captures"][n].shape == (m, fan_in)
        fwd = model.window_forward(ins, CFG)
        np.testing.assert_allclose(np.asarray(out["h_out"]),
                                   np.asarray(fwd["h_out"]),
                                   rtol=1e-5, atol=1e-5)


class TestLmEval:
    def test_nll_matches_xent(self, params):
        rng = np.random.default_rng(2)
        h = jnp.asarray(rng.normal(
            size=(CFG.batch, CFG.seq, CFG.d_model)).astype(np.float32))
        tgt = jnp.asarray(rng.integers(
            0, CFG.vocab, size=(CFG.batch, CFG.seq)), jnp.int32)
        ins = {"h": h, "final_norm": params["final_norm"],
               "head": params["head"], "targets": tgt,
               "mask": jnp.ones((CFG.batch, CFG.seq), jnp.float32)}
        out = model.lm_eval(ins, CFG)
        logits = model._fp_rmsnorm(h, params["final_norm"]) @ params["head"]
        want = model.xent(logits, tgt) * CFG.seq
        np.testing.assert_allclose(float(jnp.mean(out["nll"])), float(want),
                                   rtol=1e-4)

    def test_mask_gates_positions(self, params):
        rng = np.random.default_rng(3)
        h = jnp.asarray(rng.normal(
            size=(CFG.batch, CFG.seq, CFG.d_model)).astype(np.float32))
        tgt = jnp.zeros((CFG.batch, CFG.seq), jnp.int32)
        half = jnp.concatenate([
            jnp.zeros((CFG.batch, CFG.seq // 2)),
            jnp.ones((CFG.batch, CFG.seq - CFG.seq // 2))], axis=1
        ).astype(jnp.float32)
        ins = {"h": h, "final_norm": params["final_norm"],
               "head": params["head"], "targets": tgt, "mask": half}
        out = model.lm_eval(ins, CFG)
        np.testing.assert_allclose(np.asarray(out["count"]),
                                   CFG.seq - CFG.seq // 2)


class TestCorpus:
    def test_deterministic(self):
        a = data.generate(data.STYLE_C4, 7, 512)
        b = data.generate(data.STYLE_C4, 7, 512)
        assert a == b

    def test_styles_differ(self):
        a = data.generate(data.STYLE_C4, 7, 512)
        b = data.generate(data.STYLE_WIKI, 7, 512)
        assert a != b

    def test_token_range_and_structure(self):
        toks = data.generate(data.STYLE_WIKI, 11, 1024)
        assert all(0 <= t < 256 for t in toks)
        # every segment opens with a topic marker
        for i in range(0, 1024, data.SEGMENT_LEN):
            assert data.TOPIC_BASE <= toks[i] < data.TOPIC_BASE + data.N_TOPICS

    def test_learnable_structure(self):
        """The affine-map component makes bigram entropy well below uniform."""
        toks = data.generate(data.STYLE_C4, 5, 20000)
        from collections import Counter
        big = Counter(zip(toks, toks[1:]))
        uni = Counter(toks)
        h = 0.0
        for (a, b), c in big.items():
            p = c / uni[a]
            h -= c * np.log2(p)
        h /= len(toks) - 1
        assert h < 5.0  # uniform over 240 would be ~7.9 bits
