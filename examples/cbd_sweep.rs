//! Cross-block-dependency sweep (paper Sec. 5.3 / Appendix D): vary the
//! sliding-window size and overlap and watch reconstruction quality improve
//! — the paper's central ablation, live.
//!
//!     cargo run --release --example cbd_sweep [model] [w4a4|w2a16]

use cbq::calib::corpus::Style;
use cbq::config::{BitSpec, QuantJob};
use cbq::coordinator::Pipeline;
use cbq::report::{fmt_f, Table};
use cbq::runtime::{self, Artifacts};

fn main() -> anyhow::Result<()> {
    let setting = std::env::args().nth(2).unwrap_or_else(|| "w4a4".to_string());
    let bits = match setting.as_str() {
        "w2a16" => BitSpec::w2a16(),
        _ => BitSpec::w4a4(),
    };
    let art = Artifacts::discover()?;
    let model =
        std::env::args().nth(1).unwrap_or_else(|| art.model_or_default("t").to_string());
    let rt = runtime::create_selected(&art, None)?;
    let mut pipe = Pipeline::new(&art, rt.as_ref(), &model)?;
    let windows = art.manifest.windows[&model].clone();

    let mut table = Table::new(
        format!("CBD sweep, {} on `{model}`", bits.label()),
        &["#blocks", "overlap", "ppl c4", "ppl wiki", "quant s", "state KiB"],
    );
    for &w in &windows {
        if w > pipe.cfg.n_layers {
            continue;
        }
        // overlap points per the paper's Table 7 grid
        let overlaps: Vec<usize> = match w {
            1 => vec![0],
            2 => vec![0, 1],
            4 => vec![0, 2],
            _ => vec![0, w / 2, w - 1],
        };
        for ov in overlaps {
            let mut job = QuantJob::cbq(bits.clone());
            job.window = w;
            job.overlap = ov;
            job.calib_sequences = 24;
            job.epochs = 6;
            let (m, summary) = pipe.run(&job)?;
            table.row(&[
                w.to_string(),
                ov.to_string(),
                fmt_f(pipe.perplexity(&m, Style::C4, 8)?, 3),
                fmt_f(pipe.perplexity(&m, Style::Wiki, 8)?, 3),
                fmt_f(summary.quant_seconds, 1),
                (summary.state_bytes / 1024).to_string(),
            ]);
            println!("w={w} overlap={ov} done");
        }
    }
    table.print();
    println!("expected shape: ppl improves with window size, and with overlap at fixed window");
    Ok(())
}
