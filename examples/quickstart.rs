//! Quickstart: quantize the tiny model to W4A16 with CBQ defaults and
//! compare perplexity against the FP baseline.
//!
//!     cargo run --release -- synth   # or: make artifacts
//!     cargo run --release --example quickstart

use cbq::calib::corpus::Style;
use cbq::config::{BitSpec, QuantJob};
use cbq::coordinator::Pipeline;
use cbq::report::{fmt_f, Table};
use cbq::runtime::{self, Artifacts, Backend as _};

fn main() -> anyhow::Result<()> {
    let art = Artifacts::discover()?;
    let rt = runtime::create_selected(&art, None)?;
    let model = art.model_or_default("t");
    let mut pipe = Pipeline::new(&art, rt.as_ref(), model)?;

    // paper-default CBQ: 2-block sliding windows with overlap 1, CFP
    // pre-processing, LoRA-Rounding rank 5, 3 epochs per window
    let mut job = QuantJob::cbq(BitSpec::w4a16());
    job.calib_sequences = 16; // keep the quickstart quick

    println!("quantizing model `{model}` to {} on the {} backend ...", job.bits.label(), rt.name());
    let (quantized, summary) = pipe.run(&job)?;
    let fp = pipe.fp_model();

    let mut table = Table::new(
        format!("quickstart ({:.1}s quantization)", summary.quant_seconds),
        &["model", "ppl synth-c4", "ppl synth-wiki"],
    );
    for (label, m) in [("FP", &fp), ("CBQ W4A16", &quantized)] {
        let c4 = pipe.perplexity(m, Style::C4, 8)?;
        let wiki = pipe.perplexity(m, Style::Wiki, 8)?;
        table.row(&[label.into(), fmt_f(c4, 3), fmt_f(wiki, 3)]);
    }
    table.print();
    println!("window reconstruction losses: {:?}", summary.window_losses);
    Ok(())
}
