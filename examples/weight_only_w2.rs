//! Ultra-low-bit weight-only quantization (the paper's hardest weight-only
//! setting): W2A16 with plain CBQ and with CBQ* mixed precision (FC2 of the
//! first and last block promoted to 4 bits), against RTN and GPTQ.
//!
//!     cargo run --release --example weight_only_w2 [model]

use cbq::calib::corpus::Style;
use cbq::config::{BitSpec, QuantJob};
use cbq::coordinator::Pipeline;
use cbq::report::{fmt_f, Table};
use cbq::runtime::{self, Artifacts};

fn main() -> anyhow::Result<()> {
    let art = Artifacts::discover()?;
    let model =
        std::env::args().nth(1).unwrap_or_else(|| art.model_or_default("t").to_string());
    let rt = runtime::create_selected(&art, None)?;
    let mut pipe = Pipeline::new(&art, rt.as_ref(), &model)?;
    let n_layers = pipe.cfg.n_layers;

    let mut jobs = vec![
        ("RTN", QuantJob::rtn(BitSpec::w2a16())),
        ("GPTQ", QuantJob::gptq(BitSpec::w2a16())),
        ("CBQ", QuantJob::cbq(BitSpec::w2a16())),
        ("CBQ*", QuantJob::cbq(BitSpec::w2a16_star(n_layers))),
    ];
    for (_, j) in jobs.iter_mut() {
        j.calib_sequences = 24;
        j.epochs = 8;
    }

    let mut table = Table::new(
        format!("W2A16 weight-only on model `{model}`"),
        &["method", "ppl synth-c4", "ppl synth-wiki", "quant s"],
    );
    let fp = pipe.fp_model();
    table.row(&[
        "FP".into(),
        fmt_f(pipe.perplexity(&fp, Style::C4, 8)?, 3),
        fmt_f(pipe.perplexity(&fp, Style::Wiki, 8)?, 3),
        "-".into(),
    ]);
    for (name, job) in &jobs {
        let (m, summary) = pipe.run(job)?;
        table.row(&[
            (*name).into(),
            fmt_f(pipe.perplexity(&m, Style::C4, 8)?, 3),
            fmt_f(pipe.perplexity(&m, Style::Wiki, 8)?, 3),
            fmt_f(summary.quant_seconds, 1),
        ]);
        println!("{name} done");
    }
    table.print();
    Ok(())
}
