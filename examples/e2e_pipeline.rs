//! END-TO-END DRIVER (DESIGN.md §End-to-end validation): exercises every
//! layer of the stack on a real small workload and reports the paper's
//! headline metrics.
//!
//! Pipeline: load the build-time-pretrained model -> calibrate on 128-style
//! corpus segments -> CFP outlier pre-processing -> CBD sliding-window
//! reconstruction with LoRA-Rounding (W4A4, the paper's hardest joint
//! setting) -> evaluate perplexity on both corpora + the zero-shot task
//! suite, against FP / RTN / GPTQ baselines.
//!
//!     cargo run --release --example e2e_pipeline [model] [calib_seqs]
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use cbq::calib::corpus::Style;
use cbq::config::{BitSpec, QuantJob};
use cbq::coordinator::Pipeline;
use cbq::report::{fmt_f, Table};
use cbq::runtime::{self, Artifacts, Backend as _};

fn main() -> anyhow::Result<()> {
    let calib: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(32);
    let art = Artifacts::discover()?;
    let model = std::env::args().nth(1).unwrap_or_else(|| art.default_model().to_string());
    let rt = runtime::create_selected(&art, None)?;
    let rt = rt.as_ref();
    let mut pipe = Pipeline::new(&art, rt, &model)?;
    println!(
        "model `{model}`: d={} layers={} ({} quantizable params), calib={calib} sequences",
        pipe.cfg.d_model,
        pipe.cfg.n_layers,
        pipe.cfg.quant_params(),
    );

    let bits = BitSpec::w4a4();
    let mut jobs = vec![
        ("RTN", QuantJob::rtn(bits.clone())),
        ("GPTQ", QuantJob::gptq(bits.clone())),
        ("CBQ (CFP+CBD+LoRA)", QuantJob::cbq(bits.clone())),
    ];
    for (_, j) in jobs.iter_mut() {
        j.calib_sequences = calib;
    }

    let mut ppl_table = Table::new(
        format!("e2e: {} on `{model}`", bits.label()),
        &["method", "ppl c4", "ppl wiki", "quant s", "CFP trunc", "CFP ch"],
    );
    let mut task_table = Table::new(
        "zero-shot accuracy (%) + Mutual MRR/R@1/R@2",
        &["method", "TopicMatch", "CountRun", "Perturbed", "Shifted", "Mutual"],
    );

    let fp = pipe.fp_model();
    let fp_tasks = pipe.zero_shot(&fp, 24)?;
    ppl_table.row(&[
        "FP".into(),
        fmt_f(pipe.perplexity(&fp, Style::C4, 12)?, 3),
        fmt_f(pipe.perplexity(&fp, Style::Wiki, 12)?, 3),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    task_table.row(&[
        "FP".into(),
        fmt_f(fp_tasks.accuracy["TopicMatch"] * 100.0, 1),
        fmt_f(fp_tasks.accuracy["CountRun"] * 100.0, 1),
        fmt_f(fp_tasks.accuracy["Perturbed"] * 100.0, 1),
        fmt_f(fp_tasks.accuracy["Shifted"] * 100.0, 1),
        format!(
            "{}/{}/{}",
            fmt_f(fp_tasks.mrr * 100.0, 1),
            fmt_f(fp_tasks.recall1 * 100.0, 1),
            fmt_f(fp_tasks.recall2 * 100.0, 1)
        ),
    ]);

    for (name, job) in &jobs {
        let t0 = std::time::Instant::now();
        let (m, summary) = pipe.run(job)?;
        println!("{name}: quantized in {:.1}s", t0.elapsed().as_secs_f64());
        ppl_table.row(&[
            (*name).into(),
            fmt_f(pipe.perplexity(&m, Style::C4, 12)?, 3),
            fmt_f(pipe.perplexity(&m, Style::Wiki, 12)?, 3),
            fmt_f(summary.quant_seconds, 1),
            summary.preproc_weights_truncated.to_string(),
            summary.preproc_channels_scaled.to_string(),
        ]);
        let tasks = pipe.zero_shot(&m, 24)?;
        task_table.row(&[
            (*name).into(),
            fmt_f(tasks.accuracy["TopicMatch"] * 100.0, 1),
            fmt_f(tasks.accuracy["CountRun"] * 100.0, 1),
            fmt_f(tasks.accuracy["Perturbed"] * 100.0, 1),
            fmt_f(tasks.accuracy["Shifted"] * 100.0, 1),
            format!(
                "{}/{}/{}",
                fmt_f(tasks.mrr * 100.0, 1),
                fmt_f(tasks.recall1 * 100.0, 1),
                fmt_f(tasks.recall2 * 100.0, 1)
            ),
        ]);
    }
    ppl_table.print();
    task_table.print();

    let stats = rt.stats();
    println!(
        "\nruntime totals: {} executions, {:.1}s execute, {:.1}s compile, {:.1} MiB uploaded",
        stats.executions,
        stats.execute_ms / 1e3,
        stats.compile_ms / 1e3,
        stats.upload_bytes as f64 / (1024.0 * 1024.0)
    );
    Ok(())
}
