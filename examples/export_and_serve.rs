//! Quantize-once / serve-forever: quantize the tiny model, export it as a
//! CBQS snapshot, reload it (bit-exact), and serve a mixed request queue
//! through the batched engine — comparing coalesced vs one-by-one dispatch.
//!
//!     cargo run --release -- synth   # or: make artifacts
//!     cargo run --release --example export_and_serve

use cbq::calib::corpus::Style;
use cbq::config::{BitSpec, QuantJob};
use cbq::coordinator::Pipeline;
use cbq::report::{fmt_bytes, fmt_f, Table};
use cbq::runtime::{self, Artifacts, Backend as _};
use cbq::serve::{
    batcher, Batcher, EngineOptions, LoadMode, ModelRegistry, RowExecutor, ServeEngine,
};
use cbq::snapshot;

fn main() -> anyhow::Result<()> {
    let art = Artifacts::discover()?;
    let rt = runtime::create_selected(&art, None)?;
    let rt = rt.as_ref();
    let model = art.model_or_default("t");
    let mut pipe = Pipeline::new(&art, rt, model)?;

    // --- quantize once ----------------------------------------------------
    let mut job = QuantJob::cbq(BitSpec::w4a16());
    job.calib_sequences = 16;
    println!("quantizing model `{model}` to {} on {} ...", job.bits.label(), rt.name());
    let (quantized, summary) = pipe.run(&job)?;
    let ppl_mem = pipe.perplexity(&quantized, Style::C4, 4)?;

    // --- export the deliverable -------------------------------------------
    let path = std::env::temp_dir().join("t_w4a16.cbqs");
    let report = snapshot::save(&path, &pipe.cfg, &quantized)?;
    println!(
        "exported {:?}: {} ({:.1}% of the {} f32 representation)",
        path,
        fmt_bytes(report.file_bytes),
        report.compression_ratio() * 100.0,
        fmt_bytes(report.f32_equiv_bytes),
    );

    // --- reload: bit-exact ------------------------------------------------
    let mut registry = ModelRegistry::new();
    let snap = registry.load("t-w4a16", &path)?;
    let ppl_disk = pipe.perplexity(snap.model.expect_eager()?, Style::C4, 4)?;
    println!("ppl(c4): in-memory {ppl_mem:.6} vs snapshot {ppl_disk:.6}");
    assert_eq!(ppl_mem, ppl_disk, "snapshot round-trip must be bit-exact");

    // --- serve forever ----------------------------------------------------
    let engine = ServeEngine::new(rt, &art, snap.clone())?;
    let requests = batcher::standard_mix(snap.meta.cfg.seq, 16, 4, 4);
    engine.execute(&requests[0].rows[..1])?; // warm-up

    let (_, batched) = Batcher::coalescing(&engine).run(&engine, &requests)?;
    let (_, concurrent) =
        Batcher::coalescing(&engine).with_dispatch(4).run(&engine, &requests)?;
    let (_, oneby) = Batcher::sequential().run(&engine, &requests)?;

    let mut t = Table::new(
        format!("serving {} requests (quantized in {:.1}s)", requests.len(), summary.quant_seconds),
        &["mode", "dispatches", "occupancy", "tok/s"],
    );
    for (mode, s) in
        [("batched", &batched), ("batched x4", &concurrent), ("one-by-one", &oneby)]
    {
        t.row(&[
            mode.into(),
            s.dispatches.to_string(),
            format!("{:.1}%", s.occupancy() * 100.0),
            fmt_f(s.tokens_per_s(), 0),
        ]);
    }
    t.print();
    println!(
        "batched speedup: {:.2}x tokens/s",
        batched.tokens_per_s() / oneby.tokens_per_s().max(1e-12)
    );

    // --- larger-than-RAM mode: mmap + bounded window residency ------------
    // the same snapshot, opened as a memory-mapped lazy view: windows are
    // unpacked on first touch and at most one stays resident; responses are
    // bitwise-identical to the eager engine's
    let mmap_snap = registry.load_with("t-w4a16-mmap", &path, LoadMode::Mmap)?;
    let opts = EngineOptions { resident_windows: Some(1), resident_bytes: None };
    let lazy_engine = ServeEngine::with_options(rt, &art, mmap_snap, opts)?;
    let (resp_lazy, _) = Batcher::coalescing(&lazy_engine).run(&lazy_engine, &requests)?;
    let (resp_eager, _) = Batcher::coalescing(&engine).run(&engine, &requests)?;
    assert_eq!(resp_lazy, resp_eager, "mmap serving must be bitwise-identical");
    let res = lazy_engine.residency();
    println!(
        "mmap serving: identical responses with {} window(s) resident \
         (peak {} KiB unpacked, {} faults / {} hits / {} evictions)",
        res.resident_windows,
        res.peak_bytes / 1024,
        res.faults,
        res.hits,
        res.evictions,
    );
    std::fs::remove_file(&path).ok();
    Ok(())
}
