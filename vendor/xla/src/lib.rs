//! Compile-time stub of the `xla` PJRT binding surface used by
//! `cbq::runtime`.
//!
//! The offline build environment vendors no PJRT plugin or XLA shared
//! library, so this crate supplies the exact API shape the coordinator
//! compiles against — `Literal` is fully functional host-side (it is plain
//! data), while anything that would require a real device backend
//! ([`PjRtClient::cpu`]) returns a descriptive [`Error`]. Swapping in a real
//! `xla` binding (same crate name, path patched in the workspace manifest)
//! re-enables execution of the AOT HLO artifacts without touching the
//! coordinator.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable — this build uses the in-tree xla stub \
         (vendor/xla); link a real xla binding to execute HLO artifacts"
    ))
}

// ---------------------------------------------------------------------------
// literals (host-side data: fully functional)
// ---------------------------------------------------------------------------

#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host literal: shaped typed data. This part of the stub is real — the
/// runtime builds literals before upload, and tests exercise them.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    payload: Payload,
}

/// Element types the coordinator moves across the boundary.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Payload;
    fn extract(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Payload {
        Payload::F32(data)
    }
    fn extract(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Payload {
        Payload::I32(data)
    }
    fn extract(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: vec![], payload: T::wrap(vec![v]) }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], payload: T::wrap(v.to_vec()) }
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![], payload: Payload::Tuple(parts) }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        let len = match &self.payload {
            Payload::F32(v) => v.len() as i64,
            Payload::I32(v) => v.len() as i64,
            Payload::Tuple(_) => return Err(Error("cannot reshape a tuple".into())),
        };
        if count != len {
            return Err(Error(format!("reshape {dims:?} does not match {len} elements")));
        }
        Ok(Literal { dims: dims.to_vec(), payload: self.payload.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(&self.payload).ok_or_else(|| Error("literal dtype mismatch".into()))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(parts) => Ok(parts),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// ---------------------------------------------------------------------------
// HLO + PJRT surface (stubbed: constructors work, execution is unavailable)
// ---------------------------------------------------------------------------

/// Parsed HLO module text. The stub validates the file is readable and
/// retains the text; compilation requires a real backend.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(Self { text })
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation {
    pub text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { text: proto.text.clone() }
    }
}

/// Device client. [`PjRtClient::cpu`] fails in the stub, so no value of this
/// type is ever constructed; the methods exist purely so callers typecheck.
pub struct PjRtClient(());

#[derive(Debug)]
pub struct PjRtBuffer(());

pub struct PjRtLoadedExecutable(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_literal"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(Literal::vec1(&[1i32]).reshape(&[7]).is_err());
    }

    #[test]
    fn tuple_literals() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2i32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(0.0f32).to_tuple().is_err());
    }

    #[test]
    fn client_is_unavailable_with_clear_message() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
