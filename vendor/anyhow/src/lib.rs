//! Minimal API-compatible reimplementation of the `anyhow` crate surface
//! this repository uses: `Error`, `Result`, the `anyhow!` / `bail!` /
//! `ensure!` macros and the `Context` extension trait.
//!
//! The build environment is offline (no crates.io registry), so the real
//! crate cannot be fetched; this stand-in keeps the semantics the callers
//! rely on:
//!
//! * `Error` is a cheap, `Send + Sync` error value carrying a context chain;
//! * `Display` prints the outermost message, `{:#}` prints the full chain
//!   joined by `": "` (the integration tests grep `format!("{err:#}")`);
//! * `From<E: std::error::Error>` enables `?` on std errors;
//! * `Context::context` / `with_context` wrap both std errors and `Error`
//!   itself (the same blanket-plus-concrete impl pattern the real crate
//!   uses).

use std::fmt;

/// Error value: an outermost message plus the chain of underlying causes
/// (most recent context first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { chain: vec![m.to_string()] }
    }

    /// Push a new outermost context layer.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Root (innermost) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        for cause in self.chain.iter().skip(1) {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Conversion used by [`Context`]: implemented for std errors (blanket) and
/// for [`Error`] itself (concrete). `Error` deliberately does not implement
/// `std::error::Error`, so the impls do not overlap — the same coherence
/// pattern the real anyhow uses.
pub trait IntoError {
    fn into_err(self) -> Error;
}

impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
    fn into_err(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_err(self) -> Error {
        self
    }
}

/// `.context(...)` / `.with_context(...)` on `Result`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into_err().context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_err().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading weights");
        assert_eq!(format!("{e}"), "reading weights");
        assert_eq!(format!("{e:#}"), "reading weights: disk on fire");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", f(200).unwrap_err()), "too big: 200");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.root_cause(), "plain 7");
    }

    #[test]
    fn context_on_results_and_options() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: disk on fire");

        let r2: Result<()> = Err(anyhow!("inner"));
        let e2 = r2.with_context(|| format!("layer {}", 2)).unwrap_err();
        assert_eq!(format!("{e2:#}"), "layer 2: inner");

        let o: Option<u8> = None;
        assert!(o.context("missing").is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
