//! Minimal read-only memory-mapped file support for the CBQS lazy loading
//! path, vendored because the offline build environment has no crates.io
//! (the real-world equivalent is `memmap2`).
//!
//! Two primitives:
//!
//! * [`Mmap`] — a whole-file read-only mapping. On Unix this is a real
//!   `mmap(2)` (`PROT_READ`, `MAP_PRIVATE`) over raw `extern "C"`
//!   declarations — pages fault in on demand, so a file larger than RAM can
//!   be walked window-by-window. On other platforms (or when the syscall
//!   fails, or `CBQ_NO_MMAP=1` forces it) the constructor reports
//!   unavailability instead of silently buffering: callers choose the
//!   [`ReadAtFile`] fallback explicitly so the memory behavior is never a
//!   surprise.
//! * [`ReadAtFile`] — the pure-Rust positional-read fallback: byte ranges
//!   are read on demand into caller-owned buffers (`pread(2)` semantics on
//!   Unix, a seek-lock elsewhere). Not zero-copy, but still lazy: only the
//!   ranges actually touched are ever resident.
//!
//! Both types are `Send + Sync`: the mapping is immutable and the fallback
//! serializes seeks behind a mutex. Nothing here interprets bytes — dtype,
//! alignment and checksum policy live in the caller (`cbq::snapshot`).

use std::fs::File;
use std::io;
use std::path::Path;
use std::sync::Mutex;

#[cfg(unix)]
mod sys {
    //! Raw `mmap(2)` / `munmap(2)` bindings. Declared by hand because the
    //! offline image vendors no `libc` crate; the symbols come from the
    //! platform libc that `std` already links.
    use std::os::raw::{c_int, c_void};

    /// `off_t`: 64-bit on every LP64 Unix this repo targets.
    pub type OffT = i64;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: OffT,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
        pub fn getpagesize() -> c_int;
    }

    /// `PROT_READ` (identical on Linux and the BSD family).
    pub const PROT_READ: c_int = 1;
    /// `MAP_PRIVATE` (identical on Linux and the BSD family).
    pub const MAP_PRIVATE: c_int = 2;
    /// `MADV_SEQUENTIAL` (identical on Linux and the BSD family).
    pub const MADV_SEQUENTIAL: c_int = 2;
    /// `MADV_WILLNEED` (identical on Linux and the BSD family).
    pub const MADV_WILLNEED: c_int = 3;
    /// `MADV_DONTNEED` (identical on Linux and the BSD family).
    pub const MADV_DONTNEED: c_int = 4;

    /// `MAP_FAILED` is `(void*)-1`.
    pub fn map_failed() -> *mut c_void {
        usize::MAX as *mut c_void
    }
}

/// Access-pattern hints forwarded to `madvise(2)`.
///
/// Hints are best-effort on every path: on non-Unix targets (and on the
/// [`ReadAtFile`] fallback, which has no mapping to advise) they are
/// silently accepted as no-ops, and a failing syscall is reported but never
/// fatal — correctness must not depend on the kernel honouring a hint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Advice {
    /// `MADV_SEQUENTIAL`: the range will be walked front to back soon
    /// (warmup readahead).
    Sequential,
    /// `MADV_WILLNEED`: the range will be needed soon — start readahead
    /// now (background window prefetch).
    WillNeed,
    /// `MADV_DONTNEED`: the range's pages can be dropped; a later touch
    /// re-faults them from the file (window eviction).
    DontNeed,
}

#[cfg(unix)]
impl Advice {
    fn raw(self) -> std::os::raw::c_int {
        match self {
            Advice::Sequential => sys::MADV_SEQUENTIAL,
            Advice::WillNeed => sys::MADV_WILLNEED,
            Advice::DontNeed => sys::MADV_DONTNEED,
        }
    }
}

/// Is real memory mapping available on this build/host?
///
/// `false` on non-Unix targets and when the operator set `CBQ_NO_MMAP=1`
/// (useful for exercising the [`ReadAtFile`] fallback on a Unix CI host).
pub fn mmap_supported() -> bool {
    if std::env::var("CBQ_NO_MMAP").map(|v| v == "1").unwrap_or(false) {
        return false;
    }
    cfg!(unix)
}

/// A read-only memory mapping of an entire file.
///
/// Dereferences to `&[u8]`. The base pointer is page-aligned (4 KiB or
/// more on every supported platform), so any file offset that is N-byte
/// aligned for N ≤ page size yields an N-byte-aligned pointer into the map.
pub struct Mmap {
    inner: Inner,
}

enum Inner {
    /// A live `mmap(2)` region (Unix only). `len > 0`.
    #[cfg(unix)]
    Map { ptr: *const u8, len: usize },
    /// Empty files map to an empty slice without a syscall (`mmap` rejects
    /// zero-length mappings).
    Empty,
}

// SAFETY: the mapping is read-only for the whole lifetime of the value and
// is unmapped exactly once, in Drop; sharing &self across threads only ever
// reads the bytes.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only.
    ///
    /// Returns `Err` when mapping is unavailable ([`mmap_supported`] is
    /// `false`) or the syscall fails; callers fall back to [`ReadAtFile`].
    pub fn open(path: impl AsRef<Path>) -> io::Result<Self> {
        if !mmap_supported() {
            return Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "memory mapping unavailable on this platform/configuration",
            ));
        }
        let file = File::open(path.as_ref())?;
        let len = file.metadata()?.len();
        if len == 0 {
            return Ok(Self { inner: Inner::Empty });
        }
        if len > usize::MAX as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "file exceeds the address space",
            ));
        }
        Self::map_file(&file, len as usize)
    }

    #[cfg(unix)]
    fn map_file(file: &File, len: usize) -> io::Result<Self> {
        use std::os::unix::io::AsRawFd;
        // SAFETY: len > 0, fd is a valid open file descriptor, and we ask
        // for a fresh kernel-chosen address. The region is only ever read.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::map_failed() || ptr.is_null() {
            return Err(io::Error::last_os_error());
        }
        Ok(Self { inner: Inner::Map { ptr: ptr as *const u8, len } })
    }

    #[cfg(not(unix))]
    fn map_file(_file: &File, _len: usize) -> io::Result<Self> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "memory mapping unavailable on this platform",
        ))
    }

    /// The mapped bytes.
    pub fn as_bytes(&self) -> &[u8] {
        match &self.inner {
            #[cfg(unix)]
            Inner::Map { ptr, len } => {
                // SAFETY: ptr/len describe a live PROT_READ mapping owned
                // by self; the slice's lifetime is tied to &self.
                unsafe { std::slice::from_raw_parts(*ptr, *len) }
            }
            Inner::Empty => &[],
        }
    }

    /// Number of mapped bytes.
    pub fn len(&self) -> usize {
        self.as_bytes().len()
    }

    /// Is the mapping empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hint the kernel about the access pattern of the whole mapping.
    /// Best-effort: `Ok(())` on empty mappings and non-Unix targets.
    pub fn advise(&self, advice: Advice) -> io::Result<()> {
        self.advise_range(advice, 0, self.len())
    }

    /// Hint the kernel about `len` bytes starting at byte `offset` of the
    /// mapping. `madvise` requires a page-aligned start, so the range is
    /// shrunk inward to page boundaries (a partial page shared with a
    /// neighbouring range is never advised away); a range that shrinks to
    /// nothing is a successful no-op, as is any call on a non-Unix target.
    pub fn advise_range(&self, advice: Advice, offset: usize, len: usize) -> io::Result<()> {
        match &self.inner {
            #[cfg(unix)]
            Inner::Map { ptr, len: map_len } => {
                let page = unsafe { sys::getpagesize() }.max(1) as usize;
                let end = offset.saturating_add(len).min(*map_len);
                let start = offset.min(*map_len).div_ceil(page) * page;
                // round the end down too: DONTNEED on a page the caller
                // does not own would drop a neighbour's warm pages
                let end = (end / page) * page;
                if start >= end {
                    return Ok(());
                }
                // SAFETY: [start, end) lies inside the live mapping and is
                // page-aligned; the advice values are read-only hints.
                let rc = unsafe {
                    sys::madvise(
                        ptr.add(start) as *mut std::os::raw::c_void,
                        end - start,
                        advice.raw(),
                    )
                };
                if rc != 0 {
                    return Err(io::Error::last_os_error());
                }
                Ok(())
            }
            Inner::Empty => {
                let _ = (advice, offset, len);
                Ok(())
            }
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Inner::Map { ptr, len } = self.inner {
            // SAFETY: exactly the region mmap returned; dropped once.
            unsafe {
                sys::munmap(ptr as *mut std::os::raw::c_void, len);
            }
        }
    }
}

impl std::ops::Deref for Mmap {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_bytes()
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mmap[{} bytes]", self.len())
    }
}

/// Positional-read fallback for platforms (or configurations) without
/// `mmap`: each [`ReadAtFile::read_at`] call reads one byte range into an
/// owned buffer. Lazy — only touched ranges are ever resident — but not
/// zero-copy.
pub struct ReadAtFile {
    file: Mutex<File>,
    len: u64,
}

impl ReadAtFile {
    /// Open `path` for positional reads.
    pub fn open(path: impl AsRef<Path>) -> io::Result<Self> {
        let file = File::open(path.as_ref())?;
        let len = file.metadata()?.len();
        Ok(Self { file: Mutex::new(file), len })
    }

    /// Total file length in bytes.
    pub fn len(&self) -> u64 {
        self.len
    }

    /// Is the file empty?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read exactly `len` bytes starting at `offset`.
    ///
    /// Errors if the range extends past end-of-file (a truncated container,
    /// not a short read).
    pub fn read_at(&self, offset: u64, len: usize) -> io::Result<Vec<u8>> {
        if offset.checked_add(len as u64).map(|end| end > self.len).unwrap_or(true) {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("range {offset}+{len} exceeds file length {}", self.len),
            ));
        }
        let mut buf = vec![0u8; len];
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            let file = self.file.lock().unwrap_or_else(|e| e.into_inner());
            file.read_exact_at(&mut buf, offset)?;
        }
        #[cfg(not(unix))]
        {
            use std::io::{Read, Seek, SeekFrom};
            let mut file = self.file.lock().unwrap_or_else(|e| e.into_inner());
            file.seek(SeekFrom::Start(offset))?;
            file.read_exact(&mut buf)?;
        }
        Ok(buf)
    }
}

impl std::fmt::Debug for ReadAtFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ReadAtFile[{} bytes]", self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(name);
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn maps_file_contents() {
        let p = tmp("mmap_basic.bin", b"hello mapped world");
        if let Ok(m) = Mmap::open(&p) {
            assert_eq!(&m[..], b"hello mapped world");
            assert_eq!(m.len(), 18);
            assert!(!m.is_empty());
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn maps_empty_file() {
        let p = tmp("mmap_empty.bin", b"");
        if let Ok(m) = Mmap::open(&p) {
            assert!(m.is_empty());
            assert_eq!(m.as_bytes(), &[] as &[u8]);
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn read_at_ranges_and_eof() {
        let p = tmp("mmap_readat.bin", b"0123456789");
        let f = ReadAtFile::open(&p).unwrap();
        assert_eq!(f.len(), 10);
        assert_eq!(f.read_at(0, 4).unwrap(), b"0123");
        assert_eq!(f.read_at(6, 4).unwrap(), b"6789");
        assert_eq!(f.read_at(10, 0).unwrap(), b"");
        assert!(f.read_at(7, 4).is_err(), "read past EOF must fail");
        assert!(f.read_at(u64::MAX, 2).is_err(), "offset overflow must fail");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn advise_is_best_effort_and_bounds_safe() {
        let p = tmp("mmap_advise.bin", &[3u8; 3 * 4096 + 100]);
        if let Ok(m) = Mmap::open(&p) {
            m.advise(Advice::Sequential).unwrap();
            m.advise_range(Advice::WillNeed, 0, 4096).unwrap();
            m.advise_range(Advice::DontNeed, 4096, 4096).unwrap();
            // unaligned range: shrinks inward, never errors
            m.advise_range(Advice::DontNeed, 100, 5000).unwrap();
            // degenerate ranges: no-ops
            m.advise_range(Advice::DontNeed, 10, 20).unwrap();
            m.advise_range(Advice::DontNeed, m.len(), 4096).unwrap();
            m.advise_range(Advice::DontNeed, usize::MAX - 10, usize::MAX).unwrap();
            // the data is still readable after DONTNEED (pages re-fault)
            assert!(m.as_bytes().iter().all(|&b| b == 3));
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn base_pointer_is_page_aligned() {
        let p = tmp("mmap_align.bin", &[7u8; 4096]);
        if let Ok(m) = Mmap::open(&p) {
            assert_eq!(m.as_bytes().as_ptr() as usize % 4096, 0);
        }
        std::fs::remove_file(p).ok();
    }
}
