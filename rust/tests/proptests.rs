//! Property-based tests over the coordinator/quantization invariants.
//!
//! The build environment vendors no proptest crate, so the generators are
//! hand-rolled around xorshift64* (the same PRNG the corpus substrate uses):
//! each property is checked over a few hundred random cases with
//! deterministic seeds, and failures print the seed for replay.
//! `PROPTEST_CASES=N` overrides the per-property case count (CI's
//! scheduler-sim job runs the suite at an elevated count).

use cbq::calib::corpus::XorShift64Star;
use cbq::cfp;
use cbq::config::{qmax, BitSpec, RoundingMode};
use cbq::coordinator::qstate::LinearQ;
use cbq::linalg::Mat;
use cbq::quant;
use cbq::tensor::Tensor;

/// Per-property case count: the default, unless `PROPTEST_CASES` (the
/// conventional proptest env var) overrides it globally.
fn cases(default: u64) -> u64 {
    std::env::var("PROPTEST_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

struct Gen(XorShift64Star);

impl Gen {
    fn new(seed: u64) -> Self {
        Self(XorShift64Star::new(seed))
    }

    fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.0.next_u64() >> 40) as f32 / (1u64 << 24) as f32;
        lo + (hi - lo) * u
    }

    fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.0.next_below((hi - lo + 1) as u64) as usize)
    }

    fn tensor(&mut self, k: usize, n: usize, scale: f32) -> Tensor {
        let data = (0..k * n).map(|_| self.f32_in(-scale, scale)).collect();
        Tensor::new(vec![k, n], data)
    }
}

// ---------------------------------------------------------------------------
// quantizer invariants
// ---------------------------------------------------------------------------

/// Fake-quantized weights always land on the integer grid within clip range.
#[test]
fn prop_rtn_on_grid_and_in_range() {
    for seed in 0..cases(200) {
        let mut g = Gen::new(seed + 1);
        let (k, n) = (g.usize_in(1, 24), g.usize_in(1, 24));
        let bits = [2u8, 3, 4, 8][g.usize_in(0, 3)];
        let qm = qmax(bits);
        let scale = g.f32_in(0.01, 5.0);
        let w = g.tensor(k, n, scale);
        let s = quant::init_scales(&w, qm);
        let q = quant::fake_quant_rtn(&w, &s, qm);
        for i in 0..k {
            for j in 0..n {
                let lev = q.at2(i, j) / s.data[j].max(quant::EPS);
                assert!(
                    (lev - lev.round()).abs() < 1e-3,
                    "seed {seed}: off-grid {lev}"
                );
                assert!(lev.round() >= -qm - 1.0 && lev.round() <= qm, "seed {seed}");
            }
        }
    }
}

/// RTN error is bounded by half a step for in-range weights.
#[test]
fn prop_rtn_error_bounded() {
    for seed in 0..cases(200) {
        let mut g = Gen::new(seed + 1000);
        let (k, n) = (g.usize_in(1, 16), g.usize_in(1, 16));
        let qm = qmax(4);
        let w = g.tensor(k, n, 1.0);
        let s = quant::init_scales(&w, qm);
        let q = quant::fake_quant_rtn(&w, &s, qm);
        for i in 0..k {
            for j in 0..n {
                let err = (q.at2(i, j) - w.at2(i, j)).abs();
                // max-init scales put every weight in range => err <= s/2
                assert!(
                    err <= 0.5 * s.data[j] + 1e-6,
                    "seed {seed}: err {err} > half-step {}",
                    0.5 * s.data[j]
                );
            }
        }
    }
}

/// More bits never increases the per-matrix quantization MSE.
#[test]
fn prop_monotone_in_bits() {
    for seed in 0..cases(100) {
        let mut g = Gen::new(seed + 2000);
        let (k, n) = (g.usize_in(2, 20), g.usize_in(2, 20));
        let scale = g.f32_in(0.05, 3.0);
        let w = g.tensor(k, n, scale);
        let mut last = f32::INFINITY;
        for bits in [2u8, 3, 4, 6, 8] {
            let qm = qmax(bits);
            let s = quant::init_scales(&w, qm);
            let e = quant::quant_mse(&w, &s, qm);
            assert!(
                e <= last + 1e-9,
                "seed {seed}: mse not monotone at {bits} bits ({e} > {last})"
            );
            last = e;
        }
    }
}

/// finalize_weights with any rho never leaves the clip range and moves each
/// weight at most one step from the floor.
#[test]
fn prop_finalize_bounded() {
    for seed in 0..cases(200) {
        let mut g = Gen::new(seed + 3000);
        let (k, n) = (g.usize_in(1, 16), g.usize_in(1, 16));
        let qm = qmax([2u8, 4][g.usize_in(0, 1)]);
        let w = g.tensor(k, n, 1.0);
        let s = quant::init_scales(&w, qm);
        let rho = Tensor::new(
            vec![k, n],
            (0..k * n).map(|_| g.f32_in(0.0, 1.0)).collect(),
        );
        let q = quant::finalize_weights(&w, &s, Some(&rho), qm);
        for i in 0..k {
            for j in 0..n {
                let sc = s.data[j].max(quant::EPS);
                let lev = q.at2(i, j) / sc;
                assert!(lev >= -qm - 1.0 - 1e-4 && lev <= qm + 1e-4, "seed {seed}");
                let floor = (w.at2(i, j) / sc).floor();
                assert!(
                    (lev - floor).abs() <= 1.0 + 1e-4,
                    "seed {seed}: moved more than one step"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// CFP invariants
// ---------------------------------------------------------------------------

/// Truncation never increases any magnitude and preserves every sign.
#[test]
fn prop_cfp_truncation_contracts() {
    for seed in 0..cases(200) {
        let mut g = Gen::new(seed + 4000);
        let n = g.usize_in(16, 400);
        let mut data: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
        // sometimes plant outliers
        for _ in 0..g.usize_in(0, 3) {
            let i = g.usize_in(0, n - 1);
            data[i] = g.f32_in(5.0, 50.0) * data[i].signum().max(0.1).signum();
        }
        let before = data.clone();
        let det = cfp::detect_default(&data);
        cfp::truncate_weights(&mut data, &det);
        for (a, b) in data.iter().zip(&before) {
            assert!(a.abs() <= b.abs() + 1e-6, "seed {seed}: magnitude grew");
            if b.abs() > 1e-6 && a.abs() > 1e-6 {
                assert_eq!(a.signum(), b.signum(), "seed {seed}: sign flip");
            }
        }
    }
}

/// Detection threshold is always above the reserved maximum, and scales are
/// always >= 1 (activation scaling only ever shrinks channels).
#[test]
fn prop_cfp_detection_consistent() {
    for seed in 0..cases(200) {
        let mut g = Gen::new(seed + 5000);
        let n = g.usize_in(8, 300);
        let mut data: Vec<f32> = (0..n).map(|_| g.f32_in(0.0, 2.0)).collect();
        for _ in 0..g.usize_in(0, 4) {
            let i = g.usize_in(0, n - 1);
            data[i] = g.f32_in(10.0, 100.0);
        }
        let det = cfp::detect_default(&data);
        if let Some(t) = det.threshold {
            assert!(t > det.reserved_max - 1e-6, "seed {seed}");
            assert!(det.n_outliers > 0, "seed {seed}");
        } else {
            assert_eq!(det.n_outliers, 0, "seed {seed}");
        }
        let scales = cfp::activation_scales(&data, &det);
        assert!(scales.iter().all(|&s| s >= 1.0), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// coordinator state invariants
// ---------------------------------------------------------------------------

/// Rank projection is idempotent and Adam steps never break it.
#[test]
fn prop_rank_projection_invariant() {
    for seed in 0..cases(60) {
        let mut g = Gen::new(seed + 6000);
        let (fi, fo) = (g.usize_in(2, 32), g.usize_in(2, 32));
        let rank_pad = 8;
        let rank = g.usize_in(1, rank_pad);
        let w = g.tensor(fi, fo, 0.5);
        let mut q = LinearQ::init(&w, 4, rank_pad, rank, RoundingMode::Lora);
        for _ in 0..5 {
            let g1 = g.tensor(fi, rank_pad, 0.1);
            let g2 = g.tensor(rank_pad, fo, 0.1);
            let gs = Tensor::zeros(&[fo]);
            q.step(&gs, 0.0, Some(&g1), Some(&g2), None, (0.0, 0.0, 1e-2), rank,
                   RoundingMode::Lora);
        }
        for i in 0..fi {
            for c in rank..rank_pad {
                assert_eq!(q.a1.at2(i, c), 0.0, "seed {seed}: a1 rank leak");
            }
        }
        for r in rank..rank_pad {
            for j in 0..fo {
                assert_eq!(q.a2.at2(r, j), 0.0, "seed {seed}: a2 rank leak");
            }
        }
        // effective rank of V = a1 @ a2 is <= rank by construction: every
        // column of a1 beyond `rank` is zero
        assert!(q.s_w.data.iter().all(|&s| s > 0.0), "seed {seed}");
    }
}

/// BitSpec per-layer overrides only ever touch the named (block, linear).
#[test]
fn prop_bitspec_overrides_local() {
    for seed in 0..cases(200) {
        let mut g = Gen::new(seed + 7000);
        let n_layers = g.usize_in(2, 12);
        let mut bits = BitSpec::new(2, 16);
        let ob = g.usize_in(0, n_layers - 1);
        let lin = quant::LINEARS[g.usize_in(0, 6)];
        bits.overrides.push((ob, lin.to_string(), 8));
        for blk in 0..n_layers {
            for l in quant::LINEARS {
                let want = if blk == ob && l == lin { 8 } else { 2 };
                assert_eq!(bits.weight_bits(blk, l), want, "seed {seed}");
            }
        }
    }
}

/// CBD window schedule covers every block, never exceeds bounds, and the
/// number of windows matches ceil((L - w) / step) + 1.
#[test]
fn prop_window_schedule() {
    for seed in 0..cases(300) {
        let mut g = Gen::new(seed + 8000);
        let l_total = g.usize_in(1, 24);
        let w = g.usize_in(1, l_total);
        let overlap = g.usize_in(0, w - 1);
        let step = w - overlap;
        let mut starts: Vec<usize> =
            (0..).map(|k| k * step).take_while(|s| s + w <= l_total).collect();
        if starts.last().map(|&s| s + w < l_total).unwrap_or(true) {
            starts.push(l_total - w);
        }
        let mut covered = vec![false; l_total];
        for &s in &starts {
            assert!(s + w <= l_total, "seed {seed}: window out of bounds");
            for c in covered.iter_mut().skip(s).take(w) {
                *c = true;
            }
        }
        assert!(covered.iter().all(|&c| c), "seed {seed}: uncovered block");
    }
}

/// The serve/eval dispatch plan (`coordinator::window_plan`) is a greedy
/// covering: every block is covered exactly once, the steps are contiguous
/// from block 0, every width is either an exported window size or the
/// width-1 fallback, no width exceeds the largest requested window, and
/// each step takes the largest window that fits the remainder.
#[test]
fn prop_window_plan_greedy_covering() {
    use cbq::coordinator::window_plan;
    for seed in 0..cases(300) {
        let mut g = Gen::new(seed + 85000);
        let n_layers = g.usize_in(0, 48);
        // window sets with duplicates and the occasional bogus zero entry
        let n_win = g.usize_in(0, 5);
        let windows: Vec<usize> = (0..n_win).map(|_| g.usize_in(0, 12)).collect();
        let plan = window_plan(&windows, n_layers);

        // contiguous from 0, covering every block exactly once
        let mut k = 0usize;
        for &(start, w) in &plan {
            assert_eq!(start, k, "seed {seed}: plan not contiguous ({plan:?})");
            assert!(w > 0, "seed {seed}: zero-width step ({plan:?})");
            k += w;
        }
        assert_eq!(
            k, n_layers,
            "seed {seed}: plan covers {k} of {n_layers} blocks ({plan:?})"
        );
        if n_layers == 0 {
            assert!(plan.is_empty(), "seed {seed}: empty chain needs no steps");
            continue;
        }

        let positive: Vec<usize> = windows.iter().copied().filter(|&w| w > 0).collect();
        let cap = positive.iter().copied().max().unwrap_or(1);
        for &(start, w) in &plan {
            // width never exceeds the largest requested window (width-1
            // fallback only when nothing requested fits)
            assert!(
                w <= cap.max(1),
                "seed {seed}: width {w} exceeds requested max {cap} ({plan:?})"
            );
            assert!(
                positive.contains(&w) || w == 1,
                "seed {seed}: width {w} is neither exported nor the fallback"
            );
            // greedy maximality: no exported window fits the remainder
            // better than the one chosen
            let remaining = n_layers - start;
            let best = positive.iter().copied().filter(|&x| x <= remaining).max();
            assert_eq!(
                w,
                best.unwrap_or(1),
                "seed {seed}: step at {start} not greedy-max ({plan:?})"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// linalg invariants
// ---------------------------------------------------------------------------

/// Cholesky-based SPD inverse satisfies A * inv(A) = I for random SPD A.
#[test]
fn prop_spd_inverse() {
    for seed in 0..cases(60) {
        let mut g = Gen::new(seed + 9000);
        let n = g.usize_in(1, 16);
        // A = B B^T + (n+1) I
        let mut a = Mat::zeros(n);
        let b: Vec<f64> = (0..n * n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a.set(i, j, s);
            }
        }
        a.add_diag(n as f64 + 1.0);
        let inv = a.spd_inverse().unwrap();
        let prod = a.matmul(&inv);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (prod.at(i, j) - want).abs() < 1e-7,
                    "seed {seed}: inverse off at ({i},{j})"
                );
            }
        }
    }
}

/// V0 warm-start: h(V0) == frac(W/s) within tolerance for random weights.
#[test]
fn prop_v0_roundtrip() {
    use cbq::coordinator::qstate::v0_init;
    for seed in 0..cases(100) {
        let mut g = Gen::new(seed + 10000);
        let (k, n) = (g.usize_in(1, 16), g.usize_in(1, 16));
        let scale = g.f32_in(0.05, 2.0);
        let w = g.tensor(k, n, scale);
        let s = quant::init_scales(&w, qmax(4));
        let v0 = v0_init(&w, &s);
        for i in 0..k {
            for j in 0..n {
                let rho = quant::rect_sigmoid(v0.at2(i, j));
                let v = w.at2(i, j) / s.data[j].max(1e-8);
                let frac = v - v.floor();
                assert!(
                    (rho - frac).abs() < 2e-3,
                    "seed {seed}: rho {rho} vs frac {frac}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// blocked matmul kernels (native backend hot path)
// ---------------------------------------------------------------------------

/// The cache-blocked/packed-panel matmuls must agree with the naive
/// row-parallel loops *bitwise*: they keep the identical per-element
/// accumulation order (reduction index ascending, one accumulator per
/// output element), so this is equality, not tolerance. Shapes straddle
/// the block-path threshold, so both the naive fallback and the packed
/// micro-kernel path are exercised.
#[test]
fn prop_blocked_matmul_bitwise_matches_naive() {
    use cbq::runtime::backend::kernels as k;
    for seed in 0..cases(120) {
        let mut g = Gen::new(seed + 60000);
        let (m, kk, n) = (g.usize_in(1, 40), g.usize_in(1, 48), g.usize_in(1, 40));
        let plant_zeros = seed % 3 == 0;
        let mut mk_vec = |len: usize| -> Vec<f32> {
            (0..len)
                .map(|_| {
                    if plant_zeros && g.usize_in(0, 3) == 0 {
                        0.0
                    } else {
                        g.f32_in(-2.0, 2.0)
                    }
                })
                .collect()
        };
        let a = mk_vec(m * kk);
        let b = mk_vec(kk * n);
        assert_eq!(
            k::matmul(&a, m, kk, &b, n),
            k::matmul_naive(&a, m, kk, &b, n),
            "seed {seed}: matmul {m}x{kk}x{n}"
        );
        let bt = mk_vec(n * kk);
        assert_eq!(
            k::matmul_transb(&a, m, kk, &bt, n),
            k::matmul_transb_naive(&a, m, kk, &bt, n),
            "seed {seed}: transb {m}x{kk}x{n}"
        );
        let bm = mk_vec(m * n);
        assert_eq!(
            k::matmul_transa(&a, m, kk, &bm, n),
            k::matmul_transa_naive(&a, m, kk, &bm, n),
            "seed {seed}: transa {m}x{kk}x{n}"
        );
    }
}

/// Cross-check against the host `Tensor::matmul` oracle (different loop
/// structure entirely) within float tolerance.
#[test]
fn prop_blocked_matmul_matches_tensor_oracle() {
    use cbq::runtime::backend::kernels as k;
    for seed in 0..cases(60) {
        let mut g = Gen::new(seed + 61000);
        let (m, kk, n) = (g.usize_in(1, 24), g.usize_in(1, 24), g.usize_in(1, 24));
        let ta = g.tensor(m, kk, 1.0);
        let tb = g.tensor(kk, n, 1.0);
        let want = ta.matmul(&tb);
        let got = k::matmul(&ta.data, m, kk, &tb.data, n);
        for (i, (x, y)) in got.iter().zip(&want.data).enumerate() {
            assert!(
                (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
                "seed {seed}: [{i}] {x} vs {y}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// packed-domain matmul (serve directly from 2/4/8-bit codes)
// ---------------------------------------------------------------------------

/// `qmatmul` over packed codes + scales must equal dequantize-then-`matmul`
/// *bitwise* for every supported width and edge-case scale column (exact
/// zero and negatives hit the `EPS` floor, below-floor-small and huge
/// scales stress the multiply), across shapes straddling the blocked-path
/// threshold — the identity packed-domain serving rests on.
#[test]
fn prop_qmatmul_bitwise_matches_dequant_matmul() {
    use cbq::runtime::backend::kernels as k;
    use cbq::runtime::backend::kernels::SimdTier;
    for seed in 0..cases(150) {
        let mut g = Gen::new(seed + 70000);
        let (m, kk, n) = (g.usize_in(1, 40), g.usize_in(1, 48), g.usize_in(1, 40));
        // straddling widths (3/5/6/7) decode scalar under every tier but
        // must still agree bitwise with the vectorized 2/4/8 paths' oracle
        let bits = [2u8, 3, 4, 5, 6, 7, 8][g.usize_in(0, 6)];
        let half = 1i32 << (bits - 1);
        let codes: Vec<i32> = (0..kk * n)
            .map(|_| g.0.next_below(2 * half as u64) as i32 - half)
            .collect();
        // scale columns: mostly ordinary positive, with planted edge cases
        let s_w: Vec<f32> = (0..n)
            .map(|_| match g.usize_in(0, 5) {
                0 => 0.0,                 // EPS-floored
                1 => -0.25,               // negative: also EPS-floored
                2 => quant::EPS / 4.0,    // below the floor
                3 => 2.9e4,               // huge
                _ => g.f32_in(1e-3, 2.0),
            })
            .collect();
        // planted zeros in A exercise the naive path's zero-skip
        let a: Vec<f32> = (0..m * kk)
            .map(|_| if g.usize_in(0, 4) == 0 { 0.0 } else { g.f32_in(-2.0, 2.0) })
            .collect();

        let q = k::QPanels::pack(&codes, kk, n, bits, &s_w);
        let deq: Vec<f32> = (0..kk * n)
            .map(|i| codes[i] as f32 * s_w[i % n].max(quant::EPS))
            .collect();
        assert_eq!(q.dequant(), deq, "seed {seed}: dequant mismatch");
        assert_eq!(
            k::qmatmul(&a, m, kk, &q),
            k::matmul(&a, m, kk, &deq, n),
            "seed {seed}: qmatmul {m}x{kk}x{n} bits {bits}"
        );
        assert_eq!(
            k::qmatmul_naive(&a, m, kk, &q),
            k::matmul_naive(&a, m, kk, &deq, n),
            "seed {seed}: qmatmul_naive {m}x{kk}x{n} bits {bits}"
        );
        // every forced SIMD tier must agree bitwise — including widths the
        // vector decode doesn't cover (tiers clamp to scalar decode there)
        let blocked = k::matmul(&a, m, kk, &deq, n);
        for tier in [SimdTier::Scalar, SimdTier::Sse2, SimdTier::Avx2] {
            assert_eq!(
                k::qmatmul_with_tier(&a, m, kk, &q, tier),
                blocked,
                "seed {seed}: qmatmul {m}x{kk}x{n} bits {bits} tier {}",
                tier.name()
            );
        }

        // the transposed packer feeds the same kernel and must match the
        // f32 result over the same logical matrix
        let codes_t: Vec<i32> = {
            let mut t = vec![0i32; n * kk];
            for p in 0..kk {
                for j in 0..n {
                    t[j * kk + p] = codes[p * n + j];
                }
            }
            t
        };
        let qt = k::QPanels::pack_transb(&codes_t, kk, n, bits, &s_w);
        assert_eq!(
            k::qmatmul_transb(&a, m, kk, &qt),
            k::matmul(&a, m, kk, &deq, n),
            "seed {seed}: qmatmul_transb {m}x{kk}x{n} bits {bits}"
        );
    }
}

/// The decode hot path: `qmatvec` must equal dequantize-then-`matmul` at
/// `m == 1` *and* the corresponding `qmatmul` row, bitwise, at **every**
/// forced SIMD tier (scalar / SSE2 / AVX2, clamped to what the CPU has —
/// tiers differ only in lane count, never in per-element order). Shapes
/// straddle the blocked-path threshold, scales hit the same edge cases as
/// the qmatmul property above, and A gets planted zeros for the naive
/// path's zero-skip.
#[test]
fn prop_qmatvec_bitwise_matches_qmatmul_row() {
    use cbq::runtime::backend::kernels as k;
    use cbq::runtime::backend::kernels::SimdTier;
    for seed in 0..cases(150) {
        let mut g = Gen::new(seed + 75000);
        let (kk, n) = (g.usize_in(1, 96), g.usize_in(1, 80));
        // include the straddling widths: they decode scalar under every
        // tier, and the tiers must still agree bitwise
        let bits = [2u8, 3, 4, 5, 6, 7, 8][g.usize_in(0, 6)];
        let half = 1i32 << (bits - 1);
        let codes: Vec<i32> = (0..kk * n)
            .map(|_| g.0.next_below(2 * half as u64) as i32 - half)
            .collect();
        let s_w: Vec<f32> = (0..n)
            .map(|_| match g.usize_in(0, 5) {
                0 => 0.0,                 // EPS-floored
                1 => -0.25,               // negative: also EPS-floored
                2 => quant::EPS / 4.0,    // below the floor
                3 => 2.9e4,               // huge
                _ => g.f32_in(1e-3, 2.0),
            })
            .collect();
        let a: Vec<f32> = (0..kk)
            .map(|_| if g.usize_in(0, 4) == 0 { 0.0 } else { g.f32_in(-2.0, 2.0) })
            .collect();

        let q = k::QPanels::pack(&codes, kk, n, bits, &s_w);
        let deq: Vec<f32> = (0..kk * n)
            .map(|i| codes[i] as f32 * s_w[i % n].max(quant::EPS))
            .collect();
        let oracle = k::matmul(&a, 1, kk, &deq, n);
        for tier in [SimdTier::Scalar, SimdTier::Sse2, SimdTier::Avx2] {
            assert_eq!(
                k::qmatvec_with_tier(&a, kk, &q, tier),
                oracle,
                "seed {seed}: qmatvec {kk}x{n} bits {bits} tier {}",
                tier.name()
            );
            assert_eq!(
                k::qmatvec_with_tier(&a, kk, &q, tier),
                k::qmatmul_with_tier(&a, 1, kk, &q, tier),
                "seed {seed}: qmatvec vs qmatmul row {kk}x{n} bits {bits} tier {}",
                tier.name()
            );
        }
        // default entry points and the transposed packer feed the same
        // kernels
        assert_eq!(k::qmatvec(&a, kk, &q), oracle, "seed {seed}: qmatvec default tier");
        let codes_t: Vec<i32> = {
            let mut t = vec![0i32; n * kk];
            for p in 0..kk {
                for j in 0..n {
                    t[j * kk + p] = codes[p * n + j];
                }
            }
            t
        };
        let qt = k::QPanels::pack_transb(&codes_t, kk, n, bits, &s_w);
        assert_eq!(
            k::qmatvec_transb(&a, kk, &qt),
            oracle,
            "seed {seed}: qmatvec_transb {kk}x{n} bits {bits}"
        );
    }
}

// ---------------------------------------------------------------------------
// packed-tensor invariants (snapshot store)
// ---------------------------------------------------------------------------

/// Randomized 2/4/8-bit pack -> unpack round trips: every in-range code
/// survives exactly, payload size matches the analytic bit count, and
/// out-of-range codes are rejected.
#[test]
fn prop_pack_unpack_roundtrip() {
    use cbq::tensor::io::PackedTensor;
    for seed in 0..cases(300) {
        let mut g = Gen::new(seed + 40000);
        let bits = [2u8, 4, 8][g.usize_in(0, 2)];
        let half = 1i32 << (bits - 1);
        let (k, n) = (g.usize_in(1, 23), g.usize_in(1, 17));
        let codes: Vec<i32> = (0..k * n)
            .map(|_| g.0.next_below(2 * half as u64) as i32 - half)
            .collect();
        let p = PackedTensor::pack(&codes, vec![k, n], bits)
            .unwrap_or_else(|e| panic!("seed {seed}: pack failed: {e}"));
        assert_eq!(
            p.data.len(),
            (k * n * bits as usize).div_ceil(8),
            "seed {seed}: payload size"
        );
        assert_eq!(p.unpack(), codes, "seed {seed}: bits {bits} round trip");

        // boundary codes are exact
        let edge = vec![-half, half - 1, 0, -half, half - 1];
        let pe = PackedTensor::pack(&edge, vec![5], bits).unwrap();
        assert_eq!(pe.unpack(), edge, "seed {seed}: boundary codes");

        // out-of-range rejected in both directions
        assert!(PackedTensor::pack(&[half], vec![1], bits).is_err());
        assert!(PackedTensor::pack(&[-half - 1], vec![1], bits).is_err());
    }
}

// ---------------------------------------------------------------------------
// serve-stats invariants (batcher admission + accounting)
// ---------------------------------------------------------------------------

/// Minimal deterministic executor for serve-stats properties.
struct RowMock {
    batch: usize,
    seq: usize,
}

impl cbq::serve::RowExecutor for RowMock {
    fn batch_rows(&self) -> usize {
        self.batch
    }
    fn seq(&self) -> usize {
        self.seq
    }
    fn execute(
        &self,
        rows: &[cbq::serve::WorkRow],
    ) -> anyhow::Result<Vec<cbq::serve::RowOut>> {
        Ok(rows
            .iter()
            .map(|r| cbq::serve::RowOut {
                nll: r.targets.iter().zip(&r.mask).map(|(&t, &m)| t as f32 * m).sum(),
                count: r.mask.iter().sum(),
            })
            .collect())
    }
}

/// One random request: 1..=3 rows, random kind, tokens/score_from from `g`.
fn random_request(g: &mut Gen, seq: usize) -> cbq::serve::Request {
    use cbq::serve::{Request, RequestKind, WorkRow};
    let n_rows = g.usize_in(1, 3);
    let rows: Vec<WorkRow> = (0..n_rows)
        .map(|_| {
            let toks: Vec<u32> = (0..seq + 1).map(|_| g.usize_in(0, 97) as u32).collect();
            WorkRow::from_tokens(&toks, g.usize_in(0, seq))
        })
        .collect();
    let kind = match g.usize_in(0, 2) {
        0 => RequestKind::Ppl,
        1 => RequestKind::Choice { correct: g.usize_in(0, n_rows - 1) },
        _ => RequestKind::Hidden,
    };
    Request { kind, rows }
}

/// For arbitrary request mixes, queue caps and lane counts, the ServeStats
/// invariants hold: occupancy in [0,1], rows <= row_capacity,
/// rejected <= requests, completed + rejected == submitted, token
/// accounting exact, and the throughput rates are finite and >= 0.
#[test]
fn prop_serve_stats_invariants() {
    use cbq::serve::{Batcher, Response};
    for seed in 0..cases(200) {
        let mut g = Gen::new(seed + 80000);
        let seq = g.usize_in(1, 8);
        let batch = g.usize_in(1, 6);
        let n_req = g.usize_in(1, 30);
        let cap = g.usize_in(0, 12); // 0 = unlimited
        let dispatch = g.usize_in(1, 4);
        let reqs: Vec<cbq::serve::Request> =
            (0..n_req).map(|_| random_request(&mut g, seq)).collect();
        let m = RowMock { batch, seq };
        let (resp, stats) = Batcher::coalescing(&m)
            .with_queue_cap(cap)
            .with_dispatch(dispatch)
            .run(&m, &reqs)
            .unwrap_or_else(|e| panic!("seed {seed}: run failed: {e}"));

        assert_eq!(stats.requests, n_req, "seed {seed}");
        assert!(stats.rejected <= stats.requests, "seed {seed}");
        assert!(stats.rows <= stats.row_capacity, "seed {seed}");
        let occ = stats.occupancy();
        assert!((0.0..=1.0).contains(&occ), "seed {seed}: occupancy {occ}");
        assert_eq!(stats.tokens, stats.rows * seq, "seed {seed}");

        // conservation via the responses themselves
        assert_eq!(resp.len(), n_req, "seed {seed}");
        let completed = resp.iter().filter(|r| !matches!(r, Response::Rejected)).count();
        assert_eq!(completed + stats.rejected, n_req, "seed {seed}");

        // admitted row accounting: executed rows == sum of admitted rows
        let admitted_rows: usize = reqs
            .iter()
            .zip(&resp)
            .filter(|(_, r)| !matches!(r, Response::Rejected))
            .map(|(q, _)| q.rows.len())
            .sum();
        assert_eq!(stats.rows, admitted_rows, "seed {seed}");

        // rates never underflow or go non-finite
        let rps = stats.requests_per_s();
        assert!(rps.is_finite() && rps >= 0.0, "seed {seed}: requests/s {rps}");
        let tps = stats.tokens_per_s();
        assert!(tps.is_finite() && tps >= 0.0, "seed {seed}: tokens/s {tps}");
        assert!(stats.lane_occupancy() >= 0.0, "seed {seed}");
    }
}

/// Degenerate overload: a cap smaller than every request rejects the whole
/// mix, and the stats stay well-defined — `requests_per_s` must come out 0,
/// not underflow, with zero dispatches and occupancy 0.
#[test]
fn prop_serve_stats_all_rejected_no_underflow() {
    use cbq::serve::{Batcher, RequestKind, Response};
    for seed in 0..cases(100) {
        let mut g = Gen::new(seed + 90000);
        let seq = g.usize_in(1, 6);
        let batch = g.usize_in(2, 6);
        let n_req = g.usize_in(1, 20);
        // every request needs >= 2 rows; cap 1 can never admit one
        let reqs: Vec<cbq::serve::Request> = (0..n_req)
            .map(|_| {
                let mut r = random_request(&mut g, seq);
                while r.rows.len() < 2 {
                    let extra = r.rows[0].clone();
                    r.rows.push(extra);
                }
                if let RequestKind::Choice { correct } = &mut r.kind {
                    *correct = (*correct).min(r.rows.len() - 1);
                }
                r
            })
            .collect();
        let m = RowMock { batch, seq };
        let (resp, stats) =
            Batcher::coalescing(&m).with_queue_cap(1).run(&m, &reqs).unwrap();
        assert_eq!(stats.rejected, n_req, "seed {seed}: everything must be rejected");
        assert!(resp.iter().all(|r| matches!(r, Response::Rejected)), "seed {seed}");
        assert_eq!(stats.rows, 0, "seed {seed}");
        assert_eq!(stats.dispatches, 0, "seed {seed}");
        assert_eq!(stats.tokens, 0, "seed {seed}");
        assert_eq!(stats.occupancy(), 0.0, "seed {seed}");
        assert_eq!(stats.requests_per_s(), 0.0, "seed {seed}: rejected-only run must be 0 req/s");
        assert_eq!(stats.tokens_per_s(), 0.0, "seed {seed}");
    }
}

/// Zero-elapsed stats: whatever the counters say, a `wall_seconds` of 0
/// (a run faster than the clock tick, or a synthetic snapshot) must yield
/// exactly 0.0 for every rate — never NaN, never +inf, never a negative
/// from the shed/rejected subtraction.
#[test]
fn prop_serve_stats_zero_elapsed_rates_are_exact_zero() {
    use cbq::serve::ServeStats;
    for seed in 0..cases(100) {
        let mut g = Gen::new(seed + 95000);
        let stats = ServeStats {
            requests: g.usize_in(0, 50),
            dispatches: g.usize_in(0, 20),
            rows: g.usize_in(0, 64),
            row_capacity: g.usize_in(0, 64),
            tokens: g.usize_in(0, 4096),
            rejected: g.usize_in(0, 50),
            shed: g.usize_in(0, 50),
            wall_seconds: 0.0,
            dispatch_lanes: g.usize_in(0, 4),
            peak_in_flight: g.usize_in(0, 4),
            lane_busy_seconds: g.usize_in(0, 10) as f64,
            ..ServeStats::default()
        };
        assert_eq!(stats.tokens_per_s(), 0.0, "seed {seed}: tokens/s with zero wall");
        assert_eq!(stats.requests_per_s(), 0.0, "seed {seed}: req/s with zero wall");
        assert_eq!(stats.lane_occupancy(), 0.0, "seed {seed}: occupancy with zero wall");
        // and with shed + rejected exceeding requests, a positive wall still
        // never underflows (saturating admitted count)
        let mut s2 = stats.clone();
        s2.wall_seconds = 1.0;
        let rps = s2.requests_per_s();
        assert!(rps.is_finite() && rps >= 0.0, "seed {seed}: req/s {rps}");
    }
}

/// Packed entries survive the shared entry codec byte-exactly for every
/// supported bit width (the CBQS on-disk path).
#[test]
fn prop_packed_entry_codec_roundtrip() {
    use cbq::tensor::io::{read_entry, write_entry, ByteReader, Entry, PackedTensor};
    for seed in 0..cases(100) {
        let mut g = Gen::new(seed + 50000);
        let bits = [2u8, 4, 8][g.usize_in(0, 2)];
        let half = 1i32 << (bits - 1);
        let count = g.usize_in(1, 257);
        let codes: Vec<i32> = (0..count)
            .map(|_| g.0.next_below(2 * half as u64) as i32 - half)
            .collect();
        let p = PackedTensor::pack(&codes, vec![count], bits).unwrap();
        let mut buf = Vec::new();
        write_entry(&mut buf, "codes", &Entry::Packed(p.clone())).unwrap();
        let mut r = ByteReader::new(&buf);
        let (name, back) = read_entry(&mut r).unwrap();
        assert_eq!(name, "codes");
        assert_eq!(back, Entry::Packed(p), "seed {seed}");
        assert!(r.is_done());
    }
}
