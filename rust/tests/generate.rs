//! Token-generation subsystem tests: KV-cached decode correctness and
//! continuous-batching determinism.
//!
//! * **Bitwise decode**: every logit vector the incremental KV-cached
//!   decode path emits equals a full prefill recomputation over the same
//!   prefix, exactly — no tolerance.
//! * **Batch == sequential**: the continuous-batching loop's token streams
//!   equal the one-request-at-a-time greedy reference.
//! * **Replay determinism**: a seeded trace under the simulated clock
//!   replays to identical outcomes (tokens, emission ticks, admission
//!   log) across repeat runs and across dispatch lane counts {1, 2, 4}.
//! * **Conservation**: per decode step, every offered arrival is admitted
//!   or rejected — never both, never dropped.
//!
//! Host-only: `cbq synth` artifacts + the native CPU backend, 4 layers so
//! the greedy covering yields a 2-window plan and decode crosses a window
//! boundary every step.

use std::path::PathBuf;
use std::sync::OnceLock;

use cbq::config::{BitSpec, QuantJob};
use cbq::coordinator::Pipeline;
use cbq::runtime::{synth, Artifacts, NativeBackend};
use cbq::serve::{
    synth_gen_trace, EngineOptions, GenCfg, GenTraceSpec, GenerateEngine, LoadMode, ModelRegistry,
    ServeEngine, SimClock,
};
use cbq::snapshot;

fn artifacts_dir() -> &'static PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("cbq_synth_gen_{}", std::process::id()));
        let mut spec = synth::SynthSpec::tiny();
        // 4 layers + the tiny window set {1, 2} => a 2-step serve plan, so
        // every decode step crosses a window boundary
        spec.n_layers = 4;
        spec.pretrain_steps = 40;
        synth::generate(&dir, &spec).expect("synthetic artifact generation");
        dir
    })
}

fn setup() -> (Artifacts, NativeBackend) {
    let art = Artifacts::load(artifacts_dir()).expect("loading artifacts");
    let rt = NativeBackend::new(&art).expect("native backend");
    (art, rt)
}

/// Quantize (fast RTN path), export, and load an eager serve engine.
fn engine<'rt>(art: &'rt Artifacts, rt: &'rt NativeBackend, tag: &str) -> ServeEngine<'rt> {
    let p = std::env::temp_dir().join(format!("cbq_gen_{}_{tag}.cbqs", std::process::id()));
    let m = art.default_model().to_string();
    let mut pipe = Pipeline::new(art, rt, &m).unwrap();
    let mut job = QuantJob::rtn(BitSpec::new(4, 16));
    job.calib_sequences = 4;
    let (qm, _) = pipe.run(&job).unwrap();
    snapshot::save(&p, &pipe.cfg, &qm).unwrap();
    let mut reg = ModelRegistry::new();
    let snap = reg.load_with(tag, &p, LoadMode::Eager).unwrap();
    std::fs::remove_file(&p).ok();
    ServeEngine::new(rt, art, snap).unwrap()
}

fn trace_spec(cfg: &cbq::runtime::ModelCfg, requests: usize, seed: u64) -> GenTraceSpec {
    GenTraceSpec {
        requests,
        mean_gap: 500,
        seed,
        vocab: cfg.vocab,
        max_prompt: (cfg.seq / 2).max(1),
        max_new_tokens: 4,
    }
}

// ---------------------------------------------------------------------------
// bitwise: incremental KV-cached decode == full prefill, per step
// ---------------------------------------------------------------------------

#[test]
fn kv_cached_decode_logits_equal_full_prefill_bitwise() {
    let (art, rt) = setup();
    let eng = engine(&art, &rt, "bitwise");
    let cfg = eng.snapshot().meta.cfg.clone();
    let gen = GenerateEngine::new(&eng).unwrap();

    // a prompt long enough to exercise multi-position prefill, short
    // enough to leave decode room
    let plen = (cfg.seq / 2).max(1);
    let prompt: Vec<i32> = (0..plen).map(|i| (i * 7 + 3) as i32 % cfg.vocab as i32).collect();
    let max_new = cfg.seq - plen;
    let (tokens, logits_log) = gen.decode_trace(&prompt, max_new).unwrap();
    assert_eq!(tokens.len(), max_new, "decode must fill the remaining context");
    assert_eq!(logits_log.len(), tokens.len());

    // each emission's logits must equal a *full prefill* recomputation
    // over exactly the prefix consumed so far — bitwise, no tolerance
    for (k, logits) in logits_log.iter().enumerate() {
        let mut prefix = prompt.clone();
        prefix.extend_from_slice(&tokens[..k]);
        let reference = gen.prefill_logits(&prefix).unwrap();
        assert_eq!(
            logits, &reference,
            "decode step {k} (prefix len {}) diverged from full prefill",
            prefix.len()
        );
    }

    // greedy argmax consistency: the logged logits really produced the
    // emitted tokens
    for (k, logits) in logits_log.iter().enumerate() {
        let best = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
            .unwrap()
            .0;
        assert_eq!(tokens[k], best as i32, "emission {k} is not the argmax");
    }
}

// ---------------------------------------------------------------------------
// continuous batching == one-request-at-a-time reference
// ---------------------------------------------------------------------------

#[test]
fn continuous_batching_streams_equal_sequential_reference() {
    let (art, rt) = setup();
    let eng = engine(&art, &rt, "batchref");
    let cfg = eng.snapshot().meta.cfg.clone();
    let gen = GenerateEngine::new(&eng).unwrap();

    let trace = synth_gen_trace(&trace_spec(&cfg, 10, 11));
    let gcfg = GenCfg { max_new_tokens: 4, slots: 3, ..Default::default() };
    let clock = SimClock::new();
    let (outcomes, stats) = gen.run(&trace, &gcfg, &clock).unwrap();

    assert_eq!(outcomes.len(), trace.len(), "every request gets exactly one outcome");
    assert!(stats.tokens > 0, "trace must generate tokens");
    assert!(stats.peak_active > 1, "trace must actually overlap requests in the batch");
    for o in outcomes.iter().filter(|o| !o.rejected) {
        let a = &trace[o.seq];
        let want = gen
            .decode_reference(&a.request.prompt, a.request.max_new_tokens.min(4))
            .unwrap();
        assert_eq!(o.tokens, want, "request {} diverged from sequential greedy", o.seq);
        assert_eq!(o.tokens.len(), o.token_ticks.len());
        assert!(o.token_ticks.windows(2).all(|w| w[0] < w[1]), "emission ticks increase");
        assert!(o.arrival <= o.admitted && o.admitted <= o.finish);
    }
}

// ---------------------------------------------------------------------------
// determinism: repeat runs and lane counts {1, 2, 4}
// ---------------------------------------------------------------------------

#[test]
fn seeded_trace_replays_identically_across_runs_and_lane_counts() {
    let (art, rt) = setup();
    let eng = engine(&art, &rt, "replay");
    let cfg = eng.snapshot().meta.cfg.clone();
    let gen = GenerateEngine::new(&eng).unwrap();

    let trace = synth_gen_trace(&trace_spec(&cfg, 12, 23));
    let base_cfg = GenCfg { max_new_tokens: 4, slots: 4, ..Default::default() };

    let run = |lanes: usize| {
        let clock = SimClock::new();
        gen.run(&trace, &GenCfg { dispatch: lanes, ..base_cfg.clone() }, &clock).unwrap()
    };

    let (out1, stats1) = run(1);
    let (out1b, stats1b) = run(1);
    assert_eq!(out1, out1b, "same trace, same lanes: outcomes must replay bitwise");
    assert_eq!(stats1, stats1b, "stats must replay too");

    for lanes in [2usize, 4] {
        let (out_n, stats_n) = run(lanes);
        assert_eq!(
            out1, out_n,
            "dispatch 1 vs {lanes}: token streams/ticks must be identical"
        );
        assert_eq!(stats1.steps, stats_n.steps, "admission log must be lane-independent");
        assert_eq!(stats1.tokens, stats_n.tokens);
        assert_eq!(stats1.decode_steps, stats_n.decode_steps);
        assert_eq!(stats1.wall_ticks, stats_n.wall_ticks, "modeled time is lane-independent");
    }
}

// ---------------------------------------------------------------------------
// packed decode == f32 decode == prefill, bitwise, at every lane count
// ---------------------------------------------------------------------------

#[test]
fn packed_decode_streams_bitwise_equal_f32_decode_and_prefill() {
    let (art, rt) = setup();
    // export once and keep the file alive: the mmap-lazy engine reads
    // window tensors from it on every fault for as long as it lives
    let p = std::env::temp_dir().join(format!("cbq_gen_{}_packed.cbqs", std::process::id()));
    let m = art.default_model().to_string();
    let mut pipe = Pipeline::new(&art, &rt, &m).unwrap();
    let mut job = QuantJob::rtn(BitSpec::new(4, 16));
    job.calib_sequences = 4;
    let (qm, _) = pipe.run(&job).unwrap();
    snapshot::save(&p, &pipe.cfg, &qm).unwrap();

    let mut reg_f32 = ModelRegistry::new();
    let snap_f32 = reg_f32.load_with("pk-f32", &p, LoadMode::Eager).unwrap();
    let eng_f32 = ServeEngine::new(&rt, &art, snap_f32).unwrap();

    let mut reg_pk = ModelRegistry::new();
    let snap_pk = reg_pk.load_with("pk-packed", &p, LoadMode::Mmap).unwrap();
    let eng_pk = ServeEngine::with_options(
        &rt,
        &art,
        snap_pk,
        EngineOptions { packed: true, ..EngineOptions::default() },
    )
    .unwrap();
    assert!(eng_pk.is_packed(), "mmap + packed options must pin packed windows");

    let cfg = eng_f32.snapshot().meta.cfg.clone();
    let gen_f32 = GenerateEngine::new(&eng_f32).unwrap();
    let gen_pk = GenerateEngine::new(&eng_pk).unwrap();

    // 1) sequential decode: tokens AND every logit vector bitwise equal
    //    between the packed and f32 engines, and equal to a full prefill
    //    recomputation through the packed engine
    let plen = (cfg.seq / 2).max(1);
    let prompt: Vec<i32> = (0..plen).map(|i| (i * 7 + 3) as i32 % cfg.vocab as i32).collect();
    let max_new = cfg.seq - plen;
    let (toks_f32, logits_f32) = gen_f32.decode_trace(&prompt, max_new).unwrap();
    let (toks_pk, logits_pk) = gen_pk.decode_trace(&prompt, max_new).unwrap();
    assert_eq!(toks_pk, toks_f32, "packed decode tokens diverged from f32 decode");
    assert_eq!(logits_pk, logits_f32, "packed decode logits diverged from f32 decode");
    for (k, logits) in logits_pk.iter().enumerate() {
        let mut prefix = prompt.clone();
        prefix.extend_from_slice(&toks_pk[..k]);
        let reference = gen_pk.prefill_logits(&prefix).unwrap();
        assert_eq!(
            logits, &reference,
            "packed decode step {k} diverged from packed full prefill"
        );
    }

    // 2) continuous batching at lane counts {1, 2, 4}: identical outcomes
    //    (token streams + emission ticks) and admission logs across engines
    let trace = synth_gen_trace(&trace_spec(&cfg, 10, 31));
    for lanes in [1usize, 2, 4] {
        let gcfg = GenCfg { max_new_tokens: 4, slots: 3, dispatch: lanes, ..Default::default() };
        let c1 = SimClock::new();
        let (out_f32, stats_f32) = gen_f32.run(&trace, &gcfg, &c1).unwrap();
        let c2 = SimClock::new();
        let (out_pk, stats_pk) = gen_pk.run(&trace, &gcfg, &c2).unwrap();
        assert_eq!(out_pk, out_f32, "dispatch {lanes}: packed vs f32 outcomes diverged");
        assert_eq!(stats_pk.steps, stats_f32.steps, "dispatch {lanes}: admission logs diverged");
        assert_eq!(stats_pk.tokens, stats_f32.tokens);
    }

    // 3) residency during generation reflects the packed footprint (codes
    //    + scales, smaller than the f32 pins), and the generate loop's
    //    background prefetch actually fired on this 2-window plan
    let r = eng_pk.residency();
    let r_f32 = eng_f32.residency();
    assert!(r.peak_bytes > 0, "packed engine must have pinned windows");
    assert!(
        r.peak_bytes < r_f32.peak_bytes,
        "packed residency ({}) must undercut f32 residency ({})",
        r.peak_bytes,
        r_f32.peak_bytes
    );
    assert!(r.prefetches > 0, "lazy generate decode must issue background prefetches");

    std::fs::remove_file(&p).ok();
}

// ---------------------------------------------------------------------------
// conservation: offered == admitted + rejected, per decode step
// ---------------------------------------------------------------------------

#[test]
fn admission_conservation_holds_per_step_and_in_total() {
    let (art, rt) = setup();
    let eng = engine(&art, &rt, "conserve");
    let cfg = eng.snapshot().meta.cfg.clone();
    let gen = GenerateEngine::new(&eng).unwrap();

    // tiny queue + one slot + a fast trace => real rejections
    let mut spec = trace_spec(&cfg, 14, 5);
    spec.mean_gap = 50;
    let trace = synth_gen_trace(&spec);
    let gcfg = GenCfg {
        max_new_tokens: 4,
        slots: 1,
        queue_cap: Some(1),
        ..Default::default()
    };
    let clock = SimClock::new();
    let (outcomes, stats) = gen.run(&trace, &gcfg, &clock).unwrap();

    for (i, s) in stats.steps.iter().enumerate() {
        assert_eq!(
            s.offered,
            s.admitted + s.rejected,
            "step {i}: conservation violated ({s:?})"
        );
    }
    let offered: usize = stats.steps.iter().map(|s| s.offered).sum();
    assert_eq!(offered, trace.len(), "every arrival must be offered exactly once");
    assert!(stats.rejected > 0, "this trace must overflow the 1-deep queue");
    assert_eq!(
        stats.completed + stats.rejected,
        stats.requests,
        "every request completes or is rejected"
    );
    assert_eq!(outcomes.len(), trace.len());
    // rejected requests carry no tokens; completed ones carry their budget
    for o in &outcomes {
        if o.rejected {
            assert!(o.tokens.is_empty() && o.token_ticks.is_empty());
        } else {
            assert!(!o.tokens.is_empty());
        }
    }
}
