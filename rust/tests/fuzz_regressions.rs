//! Fuzz regression suite: every minimized `CBQF` fixture under
//! `rust/tests/fixtures/` replays against today's parsers forever, plus
//! short deterministic fuzz runs as an always-on smoke gate.
//!
//! A fixture is self-describing (target, expectation, clean hash,
//! payload), so this suite needs no out-of-band knowledge: drop a file in
//! the directory and it is enforced from the next `cargo test` on. CI's
//! `fuzz-smoke` job runs the same binaries at larger budgets.

use cbq::fuzzing::{self, FuzzOpts};

fn fixtures_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures")
}

fn scratch(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cbq_fuzzreg_{tag}_{}", std::process::id()))
}

#[test]
fn every_committed_fixture_replays() {
    let dir = fixtures_dir();
    let mut paths: Vec<_> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("fixture dir {dir:?} must exist: {e}"))
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "cbqf"))
        .collect();
    paths.sort();
    assert!(
        !paths.is_empty(),
        "no .cbqf fixtures under {dir:?} — the seeded regression corpus is missing"
    );
    for p in &paths {
        fuzzing::replay_fixture(p).unwrap_or_else(|e| {
            panic!("fixture {} no longer holds: {e:#}", p.display());
        });
    }
}

/// Two invocations of the same seeded run must report the identical digest
/// with zero findings — the property `cbq fuzz` (and CI's double-run
/// comparison) rests on.
fn smoke(target: &str, seed: u64, iters: u64) {
    let mut opts = FuzzOpts::new(seed, iters);
    opts.scratch = scratch(target);
    let a = fuzzing::run_target(target, &opts).expect("fuzz run must not error");
    let b = fuzzing::run_target(target, &opts).expect("fuzz run must not error");
    assert_eq!(a.digest, b.digest, "{target}: digest must replay bitwise across invocations");
    assert_eq!(a.cases_ok + a.cases_rejected, b.cases_ok + b.cases_rejected);
    for f in &a.findings {
        eprintln!("{target} FINDING iter {}: {}", f.iter, f.summary);
    }
    assert!(a.findings.is_empty(), "{target}: {} finding(s) on a healthy tree", a.findings.len());
    std::fs::remove_dir_all(&opts.scratch).ok();
}

#[test]
fn snapshot_target_smoke_is_clean_and_reproducible() {
    smoke("snapshot", 7, 60);
}

#[test]
fn trace_target_smoke_is_clean_and_reproducible() {
    smoke("trace", 7, 24);
}

#[test]
fn differential_target_smoke_is_clean_and_reproducible() {
    smoke("differential", 7, 9);
}
