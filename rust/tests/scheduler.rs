//! Deterministic simulation + invariant harness for the live-arrival
//! priority scheduler (`serve::scheduler`).
//!
//! Seeded synthetic traces replay under the virtual clock and the tests
//! assert the scheduling contract *exactly* (equality, not tolerance):
//!
//! * conservation — admitted + rejected == offered; every admitted request
//!   completes with a real response, every rejected slot is
//!   `Response::Rejected`;
//! * determinism — the same seed replays to bitwise-identical responses
//!   and an identical decision log, for any dispatch lane count;
//! * real-vs-sim — with an unbounded queue (admission cannot depend on
//!   timing) responses are bitwise-identical under the real clock too;
//! * priority ordering up to aging — per drain cycle, everything
//!   dispatched outranks (score-wise, at that cycle's decision time)
//!   everything left pending;
//! * starvation freedom — with aging enabled a Background request
//!   overtakes a saturating Interactive stream; with aging disabled it
//!   demonstrably starves until the stream ends;
//! * re-credited admission — the scheduler's queue cap bounds rows
//!   *currently waiting* (capacity returns as cycles drain), contrasted
//!   against the batcher's per-burst cap on the identical offered load.

use std::sync::atomic::{AtomicUsize, Ordering};

use cbq::serve::clock::{RealClock, SimClock};
use cbq::serve::scheduler::{synth_trace, Arrival, Priority, Scheduler, SchedulerCfg, TraceSpec};
use cbq::serve::{
    AlertKind, Batcher, LiveOutcome, Request, RequestKind, Response, RowExecutor, RowOut,
    ServeMetrics, WorkRow,
};

const SEQ: usize = 6;
const BATCH: usize = 4;

/// Deterministic executor: every row's result is a pure function of its
/// own content, so any schedule must produce identical responses.
struct Mock {
    batch: usize,
    seq: usize,
    rows_executed: AtomicUsize,
}

impl Mock {
    fn new(batch: usize, seq: usize) -> Self {
        Self { batch, seq, rows_executed: AtomicUsize::new(0) }
    }

    fn rows_executed(&self) -> usize {
        self.rows_executed.load(Ordering::SeqCst)
    }
}

impl RowExecutor for Mock {
    fn batch_rows(&self) -> usize {
        self.batch
    }
    fn seq(&self) -> usize {
        self.seq
    }
    fn execute(&self, rows: &[WorkRow]) -> anyhow::Result<Vec<RowOut>> {
        assert!(!rows.is_empty() && rows.len() <= self.batch);
        self.rows_executed.fetch_add(rows.len(), Ordering::SeqCst);
        Ok(rows
            .iter()
            .map(|r| RowOut {
                nll: r
                    .targets
                    .iter()
                    .zip(&r.mask)
                    .map(|(&t, &m)| (t % 23) as f32 * 0.25 * m)
                    .sum(),
                count: r.mask.iter().sum(),
            })
            .collect())
    }
}

fn spec(seed: u64) -> TraceSpec {
    TraceSpec { seed, requests: 60, mean_gap_ticks: 400, seq: SEQ, vocab: 40, priorities: true }
}

fn run_once(trace: &[Arrival], cfg: SchedulerCfg) -> (LiveOutcome, usize) {
    let m = Mock::new(BATCH, SEQ);
    let clock = SimClock::new();
    let out = Scheduler::new(&clock, cfg).run(&m, trace).unwrap();
    (out, m.rows_executed())
}

/// Single-row perplexity request with deterministic token content.
fn ppl1(tok: u32) -> Request {
    ppl_rows(tok, 1)
}

/// n-row perplexity request with deterministic token content.
fn ppl_rows(tok: u32, n_rows: usize) -> Request {
    let rows = (0..n_rows)
        .map(|r| {
            let toks: Vec<u32> =
                (0..SEQ as u32 + 1).map(|i| (tok + 7 * r as u32 + i) % 40).collect();
            WorkRow::from_tokens(&toks, 0)
        })
        .collect();
    Request { kind: RequestKind::Ppl, rows }
}

/// Mirror of the scheduler's scoring function, recomputed independently.
fn score(cfg: &SchedulerCfg, class: Priority, arrival: u64, now: u64) -> u64 {
    cfg.weights[class.index()] + cfg.aging * (now - arrival)
}

// ---------------------------------------------------------------------------
// determinism + conservation
// ---------------------------------------------------------------------------

#[test]
fn seeded_replay_is_deterministic_and_conserves() {
    for seed in [3u64, 17, 99] {
        let trace = synth_trace(&spec(seed));
        let cfg = SchedulerCfg { queue_cap: Some(6), ..Default::default() };
        let (a, rows_a) = run_once(&trace, cfg.clone());
        let (b, rows_b) = run_once(&trace, cfg.clone());
        assert_eq!(a.responses, b.responses, "seed {seed}: responses must replay bitwise");
        assert_eq!(a.decisions, b.decisions, "seed {seed}: decisions must replay identically");
        assert_eq!(a.cycles, b.cycles, "seed {seed}");
        assert_eq!(rows_a, rows_b, "seed {seed}");

        // conservation: every request admitted or rejected exactly once
        let admitted = a.decisions.iter().filter(|d| d.admitted).count();
        let rejected = a.decisions.iter().filter(|d| !d.admitted).count();
        assert_eq!(admitted + rejected, trace.len(), "seed {seed}");
        assert_eq!(a.stats.rejected, rejected, "seed {seed}");
        for d in &a.decisions {
            if d.admitted {
                assert_ne!(
                    d.cycle,
                    usize::MAX,
                    "seed {seed}: admitted request {} never dispatched",
                    d.seq
                );
                assert!(
                    !matches!(a.responses[d.seq], Response::Rejected),
                    "seed {seed}: admitted request {} answered Rejected",
                    d.seq
                );
                assert!(d.dispatch_time >= d.arrival, "seed {seed}: dispatched before arrival");
                assert!(d.complete_time > d.dispatch_time, "seed {seed}: zero service time");
            } else {
                assert_eq!(a.responses[d.seq], Response::Rejected, "seed {seed}");
            }
        }

        // aggregate ServeStats invariants
        assert_eq!(a.stats.requests, trace.len(), "seed {seed}");
        assert!(a.stats.rejected <= a.stats.requests, "seed {seed}");
        assert!(a.stats.rows <= a.stats.row_capacity, "seed {seed}");
        assert!(
            a.stats.occupancy() >= 0.0 && a.stats.occupancy() <= 1.0,
            "seed {seed}: occupancy {}",
            a.stats.occupancy()
        );
        let admitted_rows: usize =
            a.decisions.iter().filter(|d| d.admitted).map(|d| d.rows).sum();
        assert_eq!(a.stats.rows, admitted_rows, "seed {seed}: executed rows == admitted rows");
        assert_eq!(rows_a, admitted_rows, "seed {seed}: executor saw exactly the admitted rows");
    }
}

#[test]
fn responses_and_decisions_identical_across_dispatch_lanes() {
    for seed in [5u64, 29, 71] {
        let trace = synth_trace(&spec(seed));
        let base = SchedulerCfg { queue_cap: Some(10), ..Default::default() };
        let (r1, rows1) = run_once(&trace, SchedulerCfg { dispatch: 1, ..base.clone() });
        for lanes in [2usize, 4, 8] {
            let (rn, rowsn) = run_once(&trace, SchedulerCfg { dispatch: lanes, ..base.clone() });
            assert_eq!(
                rn.responses, r1.responses,
                "seed {seed}: {lanes} lanes changed responses"
            );
            assert_eq!(
                rn.decisions, r1.decisions,
                "seed {seed}: {lanes} lanes changed admission/ordering decisions"
            );
            assert_eq!(rn.cycles, r1.cycles, "seed {seed}");
            assert_eq!(rowsn, rows1, "seed {seed}");
        }
    }
}

#[test]
fn real_and_sim_clocks_agree_bitwise_on_responses() {
    // unbounded queue: admission cannot depend on timing, so the answers
    // must be bitwise-identical even though real cycle boundaries differ.
    // small tick values keep the real run to a few ms of sleeping.
    let trace = synth_trace(&TraceSpec {
        seed: 13,
        requests: 40,
        mean_gap_ticks: 150,
        seq: SEQ,
        vocab: 40,
        priorities: true,
    });
    let cfg = SchedulerCfg::default();

    let m_sim = Mock::new(BATCH, SEQ);
    let sim = SimClock::new();
    let out_sim = Scheduler::new(&sim, cfg.clone()).run(&m_sim, &trace).unwrap();

    let m_real = Mock::new(BATCH, SEQ);
    let real = RealClock::new();
    let out_real = Scheduler::new(&real, cfg).run(&m_real, &trace).unwrap();

    assert_eq!(out_sim.responses, out_real.responses, "clock choice changed answers");
    assert_eq!(out_sim.stats.rejected, 0);
    assert_eq!(out_real.stats.rejected, 0);
    assert_eq!(m_sim.rows_executed(), m_real.rows_executed());
    assert_eq!(out_sim.stats.class_lat.len(), 3);
    assert_eq!(out_real.stats.class_lat.len(), 3);
}

// ---------------------------------------------------------------------------
// priority ordering + starvation freedom
// ---------------------------------------------------------------------------

#[test]
fn dispatched_outrank_pending_up_to_aging() {
    for seed in [11u64, 47, 83] {
        let trace = synth_trace(&spec(seed));
        let cfg = SchedulerCfg::default();
        let (out, _) = run_once(&trace, cfg.clone());
        for c in 0..out.cycles {
            let batch: Vec<_> = out.decisions.iter().filter(|d| d.cycle == c).collect();
            assert!(!batch.is_empty(), "seed {seed}: cycle {c} dispatched nothing");
            let t = batch[0].dispatch_time;
            assert!(
                batch.iter().all(|d| d.dispatch_time == t),
                "seed {seed}: cycle {c} has mixed dispatch times"
            );
            // pending at this decision time: admitted, arrived by t, but
            // dispatched in a strictly later cycle
            let pending: Vec<_> = out
                .decisions
                .iter()
                .filter(|d| d.admitted && d.arrival <= t && d.cycle > c)
                .collect();
            for d in &batch {
                let sd = score(&cfg, d.class, d.arrival, t);
                for p in &pending {
                    let sp = score(&cfg, p.class, p.arrival, t);
                    assert!(
                        sd > sp || (sd == sp && d.seq < p.seq),
                        "seed {seed} cycle {c}: dispatched #{} (score {sd}) ranked behind \
                         pending #{} (score {sp})",
                        d.seq,
                        p.seq
                    );
                }
            }
        }
    }
}

#[test]
fn aging_prevents_background_starvation_and_strict_priority_starves() {
    // one Background request at t=0 under an Interactive stream that
    // saturates the drain budget: 4-row requests every 100 ticks against a
    // 4-row budget drained every 200 ticks (one dispatch x 200
    // ticks/dispatch), so Interactive work is always pending mid-trace.
    let mut trace =
        vec![Arrival { at: 0, class: Priority::Background, request: ppl1(1) }];
    for i in 0..40usize {
        trace.push(Arrival {
            at: i as u64 * 100,
            class: Priority::Interactive,
            request: ppl_rows(100 + i as u32, BATCH),
        });
    }
    let aged = SchedulerCfg {
        drain_rows: BATCH,
        aging: 1000,
        service_ticks_per_dispatch: 200,
        ..Default::default()
    };
    let (out, _) = run_once(&trace, aged);
    let bg = &out.decisions[0];
    assert!(bg.admitted);
    assert!(
        bg.cycle <= 5,
        "aging must let the background request overtake the stream, got cycle {}",
        bg.cycle
    );
    let last_interactive_dispatch = out
        .decisions
        .iter()
        .filter(|d| d.class == Priority::Interactive)
        .map(|d| d.dispatch_time)
        .max()
        .unwrap();
    assert!(
        bg.dispatch_time < last_interactive_dispatch,
        "background must be served mid-stream, not after it"
    );

    // strict priority (aging = 0): the identical trace starves the
    // background request until every interactive is done
    let strict = SchedulerCfg {
        drain_rows: BATCH,
        aging: 0,
        service_ticks_per_dispatch: 200,
        ..Default::default()
    };
    let (out, _) = run_once(&trace, strict);
    let bg = &out.decisions[0];
    assert_eq!(bg.cycle, out.cycles - 1, "strict priority must starve background to the end");
    for d in out.decisions.iter().filter(|d| d.class == Priority::Interactive) {
        assert!(
            d.dispatch_time <= bg.dispatch_time,
            "interactive #{} dispatched after the starved background request",
            d.seq
        );
    }
}

// ---------------------------------------------------------------------------
// re-credited admission (vs the batcher's per-burst cap)
// ---------------------------------------------------------------------------

#[test]
fn scheduler_recredits_queue_capacity_across_cycles() {
    // 12 single-row requests spaced wider than a drain cycle: the live
    // queue never holds more than one, so a cap of 4 admits all of them
    let trace: Vec<Arrival> = (0..12)
        .map(|i| Arrival {
            at: i as u64 * 2000,
            class: Priority::Batch,
            request: ppl1(i as u32),
        })
        .collect();
    let cfg = SchedulerCfg {
        queue_cap: Some(4),
        service_ticks_per_dispatch: 500,
        ..Default::default()
    };
    let (out, _) = run_once(&trace, cfg);
    assert_eq!(out.stats.rejected, 0, "re-credited capacity must admit a drained-out stream");
    assert!(out.responses.iter().all(|r| !matches!(r, Response::Rejected)));

    // the identical 12 requests as one pre-arrived burst through the plain
    // batcher: the per-burst cap rejects 8 (regression-pinned semantics)
    let m = Mock::new(BATCH, SEQ);
    let reqs: Vec<Request> = trace.iter().map(|a| a.request.clone()).collect();
    let (resp, stats) = Batcher::coalescing(&m).with_queue_cap(4).run(&m, &reqs).unwrap();
    assert_eq!(stats.rejected, 8, "per-burst cap must not re-credit");
    assert_eq!(resp.iter().filter(|r| matches!(r, Response::Rejected)).count(), 8);
}

#[test]
fn burst_overflow_rejects_tail_then_recredits_for_late_arrivals() {
    // 8 single-row requests land in the same tick against a cap of 4: the
    // first 4 (arrival order) are admitted, the tail rejected. 4 more
    // arrive after the queue drained — all admitted via re-credit.
    let mut trace: Vec<Arrival> = (0..8)
        .map(|i| Arrival { at: 0, class: Priority::Batch, request: ppl1(50 + i as u32) })
        .collect();
    for i in 0..4 {
        trace.push(Arrival {
            at: 50_000,
            class: Priority::Batch,
            request: ppl1(90 + i as u32),
        });
    }
    let cfg = SchedulerCfg { queue_cap: Some(4), ..Default::default() };
    let (out, _) = run_once(&trace, cfg);
    assert_eq!(out.stats.rejected, 4);
    let rejected_seqs: Vec<usize> =
        out.decisions.iter().filter(|d| !d.admitted).map(|d| d.seq).collect();
    assert_eq!(rejected_seqs, vec![4, 5, 6, 7], "overflow must reject the burst tail");
    assert!(
        out.decisions[8..].iter().all(|d| d.admitted),
        "late arrivals must be re-admitted after the queue drains"
    );
}

#[test]
fn rejected_requests_do_no_model_work() {
    let trace: Vec<Arrival> = (0..10)
        .map(|i| Arrival { at: 0, class: Priority::Batch, request: ppl1(i as u32) })
        .collect();
    let cfg = SchedulerCfg { queue_cap: Some(3), ..Default::default() };
    let m = Mock::new(BATCH, SEQ);
    let clock = SimClock::new();
    let out = Scheduler::new(&clock, cfg).run(&m, &trace).unwrap();
    assert_eq!(out.stats.rejected, 7);
    assert_eq!(m.rows_executed(), 3, "rejected requests must never reach the executor");
    assert_eq!(out.stats.rows, 3);
}

// ---------------------------------------------------------------------------
// accounting + edge cases
// ---------------------------------------------------------------------------

#[test]
fn class_latency_accounting_is_consistent() {
    let trace = synth_trace(&spec(23));
    let cfg = SchedulerCfg { queue_cap: Some(8), ..Default::default() };
    let (out, _) = run_once(&trace, cfg);
    let cl = &out.stats.class_lat;
    assert_eq!(cl.len(), 3);
    assert_eq!(cl[0].class, "interactive");
    assert_eq!(cl[1].class, "batch");
    assert_eq!(cl[2].class, "background");
    let submitted: usize = cl.iter().map(|c| c.submitted).sum();
    assert_eq!(submitted, trace.len());
    let completed: usize = cl.iter().map(|c| c.completed).sum();
    let rejected: usize = cl.iter().map(|c| c.rejected).sum();
    assert_eq!(completed + rejected, trace.len());
    assert_eq!(rejected, out.stats.rejected);
    for c in cl {
        assert_eq!(c.completed + c.rejected, c.submitted, "{c:?}");
        assert!(c.queue_p50_s <= c.queue_p95_s && c.queue_p95_s <= c.queue_p99_s, "{c:?}");
        assert!(
            c.service_p50_s <= c.service_p95_s && c.service_p95_s <= c.service_p99_s,
            "{c:?}"
        );
        if c.completed > 0 {
            assert!(c.service_p50_s > 0.0, "service is at least one tick: {c:?}");
        }
    }
    // the decision log mirrors the trace exactly
    for (i, a) in trace.iter().enumerate() {
        let d = &out.decisions[i];
        assert_eq!(d.seq, i);
        assert_eq!(d.class, a.class);
        assert_eq!(d.rows, a.request.rows.len());
    }
}

#[test]
fn oversized_request_dispatches_alone_in_chunks() {
    // a 10-row request against a 4-row budget: the head-of-line rule takes
    // it alone and the batcher chunks it — progress is guaranteed
    let trace =
        vec![Arrival { at: 0, class: Priority::Batch, request: ppl_rows(5, 10) }];
    let cfg = SchedulerCfg { drain_rows: BATCH, ..Default::default() };
    let (out, rows) = run_once(&trace, cfg);
    assert_eq!(rows, 10);
    assert_eq!(out.cycles, 1);
    assert_eq!(out.stats.dispatches, 3, "10 rows at batch 4 = 4+4+2");
    assert!(matches!(out.responses[0], Response::Ppl { .. }));
}

// ---------------------------------------------------------------------------
// SLO controller: shed -> recover under overload
// ---------------------------------------------------------------------------

/// The seeded overload trace: a dense Interactive burst that blows the p99
/// target, a Background wave that must be shed in its entirety, then a
/// sparse Interactive tail whose healthy windows drive recovery.
fn overload_trace() -> Vec<Arrival> {
    let mut trace = Vec::new();
    for i in 0..20u64 {
        trace.push(Arrival { at: i * 100, class: Priority::Interactive, request: ppl1(i as u32) });
    }
    for i in 0..16u64 {
        trace.push(Arrival {
            at: 2_000 + i * 400,
            class: Priority::Background,
            request: ppl1(100 + i as u32),
        });
    }
    for i in 0..8u64 {
        trace.push(Arrival {
            at: 20_000 + i * 10_000,
            class: Priority::Interactive,
            request: ppl1(200 + i as u32),
        });
    }
    trace.sort_by_key(|a| a.at);
    trace
}

fn overload_cfg(dispatch: usize) -> SchedulerCfg {
    SchedulerCfg {
        slo_p99_ticks: Some(3_000),
        slo_min_samples: 2,
        slo_recover_cycles: 2,
        dispatch,
        ..Default::default()
    }
}

#[test]
fn slo_controller_sheds_and_recovers_deterministically() {
    let trace = overload_trace();
    let run = |dispatch: usize| {
        let m = Mock::new(BATCH, SEQ);
        let clock = SimClock::new();
        let metrics = ServeMetrics::new();
        let out = Scheduler::new(&clock, overload_cfg(dispatch))
            .run_with_metrics(&m, &trace, Some(&metrics))
            .unwrap();
        (out, metrics)
    };
    let (out, metrics) = run(1);

    // the exact alert timeline, hand-traced at 1000 ticks/dispatch: the
    // 10-deep Interactive burst drains in one 3-dispatch cycle ending at
    // t=4000 with window p99 4096t > 3000t -> shed; the sparse tail's
    // 1000t latencies close 2-sample healthy windows until the second one
    // ends shedding at t=51000
    let alerts = metrics.alerts();
    let kinds: Vec<(AlertKind, u64)> = alerts.iter().map(|a| (a.kind, a.at_ticks)).collect();
    assert_eq!(kinds, vec![(AlertKind::SloShed, 4_000), (AlertKind::SloRecover, 51_000)]);

    // every shed decision is a Background arrival inside the shed window:
    // never admitted, answered Rejected, never dispatched
    let shed: Vec<_> = out.decisions.iter().filter(|d| d.shed).collect();
    assert_eq!(shed.len(), 16, "the whole Background wave lands in the shed window");
    for d in &shed {
        assert_eq!(d.class, Priority::Background, "only Background may be shed");
        assert!(!d.admitted, "a shed request must not be admitted");
        assert_eq!(out.responses[d.seq], Response::Rejected);
        assert_eq!(d.cycle, usize::MAX, "a shed request must never dispatch");
    }
    assert_eq!(out.stats.shed, 16);
    assert_eq!(out.stats.rejected, 0, "shedding is not a capacity reject");

    // conservation across all three admission outcomes — in the decision
    // log, the aggregate stats and the metrics counters
    let admitted = out.decisions.iter().filter(|d| d.admitted).count();
    assert_eq!(admitted, 28);
    assert_eq!(admitted + out.stats.shed + out.stats.rejected, trace.len());
    assert_eq!(metrics.offered(), trace.len() as u64);
    assert_eq!(metrics.admitted() + metrics.shed() + metrics.rejected(), metrics.offered());
    assert_eq!(metrics.shed(), 16);

    // bitwise replay: other lane counts and a rerun at the same lane count
    // reproduce the responses, decisions, alert timeline and every
    // recorded counter/histogram
    for lanes in [1usize, 2, 4] {
        let (o2, m2) = run(lanes);
        assert_eq!(o2.responses, out.responses, "{lanes} lanes changed responses");
        assert_eq!(o2.decisions, out.decisions, "{lanes} lanes changed decisions");
        assert_eq!(o2.cycles, out.cycles, "{lanes} lanes changed cycle count");
        assert_eq!(m2.alerts(), alerts, "{lanes} lanes changed the alert timeline");
        assert_eq!(m2.snapshot(0), metrics.snapshot(0), "{lanes} lanes changed metrics");
    }
}

#[test]
fn slo_off_by_default_never_sheds() {
    // the same overload trace with the controller disarmed: nothing is
    // shed, every request is admitted (no queue cap), and no alert fires
    let trace = overload_trace();
    let m = Mock::new(BATCH, SEQ);
    let clock = SimClock::new();
    let metrics = ServeMetrics::new();
    let out = Scheduler::new(&clock, SchedulerCfg::default())
        .run_with_metrics(&m, &trace, Some(&metrics))
        .unwrap();
    assert!(out.decisions.iter().all(|d| d.admitted && !d.shed));
    assert_eq!(out.stats.shed, 0);
    assert_eq!(metrics.shed(), 0);
    assert!(metrics.alerts().is_empty(), "no SLO target -> no alerts");
    assert!(out.responses.iter().all(|r| !matches!(r, Response::Rejected)));
}

#[test]
fn unsorted_trace_is_rejected() {
    let trace = vec![
        Arrival { at: 100, class: Priority::Batch, request: ppl1(0) },
        Arrival { at: 0, class: Priority::Batch, request: ppl1(1) },
    ];
    let m = Mock::new(BATCH, SEQ);
    let clock = SimClock::new();
    let err = Scheduler::new(&clock, SchedulerCfg::default()).run(&m, &trace).unwrap_err();
    assert!(format!("{err:#}").contains("time-sorted"), "{err:#}");
}

#[test]
fn empty_trace_completes_with_empty_outcome() {
    let m = Mock::new(BATCH, SEQ);
    let clock = SimClock::new();
    let out = Scheduler::new(&clock, SchedulerCfg::default()).run(&m, &[]).unwrap();
    assert!(out.responses.is_empty());
    assert_eq!(out.cycles, 0);
    assert_eq!(out.stats.requests, 0);
    assert_eq!(out.stats.rejected, 0);
    assert_eq!(m.rows_executed(), 0);
}
