//! Lazy (memory-mapped) CBQS loading + serving tests — the failure-mode
//! and bitwise-identity coverage for the larger-than-RAM path:
//!
//! * v1 and v2 frames decode bit-exactly through the one shared loader,
//!   eagerly and lazily;
//! * truncation mid-tensor is rejected at open; a payload bit flip is
//!   caught by the per-tensor CRC on the *lazy* path at first touch;
//! * an mmap engine with a 1-window budget serves bitwise-identical
//!   responses to the eager engine while peak residency stays bounded
//!   (asserted through `Storage`/`Pinned` heap introspection), and
//!   eviction-then-retouch re-materializes bitwise-identical tensors;
//! * residency accounting is exact under thrash: every touch under a
//!   1-slot cache is a fault, each fault past the first evicts exactly
//!   once, and a re-fault after eviction never claims a stale prefetch
//!   hit (the warm marker dies with the eviction);
//! * packed-domain pinning (codes + scales, no dequantized f32) serves
//!   bitwise-identically to both f32 engines while pinning >= 4x fewer
//!   bytes at 4 bits, and background prefetch warms the next window;
//! * several engines (and threads) over one registry entry share a single
//!   mapping of the file.
//!
//! Everything here is host-only: `cbq synth` artifacts + the native CPU
//! backend. The model is synthesized with 4 layers so the greedy covering
//! has 2 windows — enough for real eviction traffic under a 1-window
//! budget.

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use cbq::config::{BitSpec, QuantJob};
use cbq::coordinator::Pipeline;
use cbq::quant::LINEARS;
use cbq::runtime::{synth, Artifacts, NativeBackend};
use cbq::serve::{batcher, Batcher, EngineOptions, LoadMode, ModelRegistry, ServeEngine};
use cbq::snapshot;

/// Serializes tests in this binary against the `CBQ_NO_MMAP` env flip in
/// `read_at_fallback_serves_identically_without_a_mapping`: mutating the
/// environment while another thread reads it is a getenv/setenv data race
/// (and would also make the other tests' "is it mapped?" checks flaky).
/// Every test takes this lock first.
static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn env_guard() -> std::sync::MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn artifacts_dir() -> &'static PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("cbq_synth_mmap_{}", std::process::id()));
        let mut spec = synth::SynthSpec::tiny();
        // 4 layers + the tiny window set {1, 2} => a 2-step serve plan, so
        // a 1-window budget actually exercises eviction
        spec.n_layers = 4;
        spec.pretrain_steps = 40;
        synth::generate(&dir, &spec).expect("synthetic artifact generation");
        dir
    })
}

fn setup() -> (Artifacts, NativeBackend) {
    let art = Artifacts::load(artifacts_dir()).expect("loading artifacts");
    let rt = NativeBackend::new(&art).expect("native backend");
    (art, rt)
}

/// Quantize the synth model (fast RTN path) and export it at `path`.
fn export_snapshot(
    art: &Artifacts,
    rt: &NativeBackend,
    path: &std::path::Path,
) -> (cbq::runtime::ModelCfg, cbq::coordinator::QuantizedModel) {
    let m = art.default_model().to_string();
    let mut pipe = Pipeline::new(art, rt, &m).unwrap();
    let mut job = QuantJob::rtn(BitSpec::new(4, 16));
    job.calib_sequences = 4;
    let (qm, _) = pipe.run(&job).unwrap();
    snapshot::save(path, &pipe.cfg, &qm).unwrap();
    (pipe.cfg.clone(), qm)
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cbq_mmap_{}_{name}", std::process::id()))
}

fn assert_models_bitwise_equal(
    a: &cbq::coordinator::QuantizedModel,
    b: &cbq::coordinator::QuantizedModel,
) {
    assert_eq!(a.params.embed, b.params.embed, "embed");
    assert_eq!(a.params.final_norm, b.params.final_norm, "final_norm");
    assert_eq!(a.params.head, b.params.head, "head");
    assert_eq!(a.params.blocks.len(), b.params.blocks.len());
    for (i, (ba, bb)) in a.params.blocks.iter().zip(&b.params.blocks).enumerate() {
        assert_eq!(ba.attn_norm, bb.attn_norm, "block {i} attn_norm");
        assert_eq!(ba.mlp_norm, bb.mlp_norm, "block {i} mlp_norm");
        for l in LINEARS {
            assert_eq!(ba.linears[l], bb.linears[l], "block {i} {l}");
        }
    }
    for (i, (qa, qb)) in a.qstate.iter().zip(&b.qstate).enumerate() {
        for l in LINEARS {
            assert_eq!(qa[l].s_w, qb[l].s_w, "block {i} {l} s_w");
            assert_eq!(qa[l].alpha, qb[l].alpha, "block {i} {l} alpha");
            assert_eq!(qa[l].a1, qb[l].a1, "block {i} {l} a1");
            assert_eq!(qa[l].a2, qb[l].a2, "block {i} {l} a2");
        }
    }
}

// ---------------------------------------------------------------------------
// format compatibility: v1 == v2 == lazy, bitwise
// ---------------------------------------------------------------------------

#[test]
fn v1_and_lazy_loads_are_bitwise_equal_to_eager_v2() {
    let _env = env_guard();
    let (art, rt) = setup();
    let p2 = tmp("compat_v2.cbqs");
    let p1 = tmp("compat_v1.cbqs");
    let (cfg, qm) = export_snapshot(&art, &rt, &p2);
    snapshot::save_v1(&p1, &cfg, &qm).unwrap();

    // eager: v2 and the legacy v1 frame decode to the identical model,
    // which is itself bit-identical to the in-memory one that exported
    let s2 = snapshot::load(&p2).unwrap();
    let s1 = snapshot::load(&p1).unwrap();
    assert_models_bitwise_equal(&s2.model, &qm);
    assert_models_bitwise_equal(&s1.model, &s2.model);

    // lazy: per-block materialization equals the eager decode, tensor by
    // tensor — for the mapped v2 file AND the degraded in-memory v1 path
    for path in [&p2, &p1] {
        let lz = snapshot::load_lazy(path).unwrap();
        assert_eq!(lz.model.embed().unwrap(), s2.model.params.embed);
        assert_eq!(lz.model.final_norm().unwrap(), s2.model.params.final_norm);
        assert_eq!(lz.model.head().unwrap(), s2.model.params.head);
        for i in 0..cfg.n_layers {
            let mb = lz.model.block(i).unwrap();
            let eb = &s2.model.params.blocks[i];
            assert_eq!(mb.params.attn_norm, eb.attn_norm);
            assert_eq!(mb.params.mlp_norm, eb.mlp_norm);
            for l in LINEARS {
                assert_eq!(mb.params.linears[l], eb.linears[l], "lazy block {i} {l}");
                assert_eq!(mb.qstate[l].s_w, s2.model.qstate[i][l].s_w);
                assert_eq!(mb.qstate[l].alpha, s2.model.qstate[i][l].alpha);
            }
        }
    }

    // when the v2 file really is mapped, its big f32 tensors are zero-copy
    let lz = snapshot::load_lazy(&p2).unwrap();
    if lz.model.is_mapped() {
        let embed = lz.model.embed().unwrap();
        assert!(embed.data.is_mapped(), "mapped snapshot must hand out mapped embed");
        assert_eq!(embed.data.heap_bytes(), 0, "mapped tensors keep no heap bytes");
    }

    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
}

// ---------------------------------------------------------------------------
// failure modes on the lazy path
// ---------------------------------------------------------------------------

#[test]
fn truncation_mid_tensor_is_rejected_at_open() {
    let _env = env_guard();
    let (art, rt) = setup();
    let p = tmp("trunc.cbqs");
    export_snapshot(&art, &rt, &p);
    let clean = std::fs::read(&p).unwrap();

    // cut into the last tensor's payload: the record table then points
    // past end-of-file, which both loaders must refuse up front
    for cut in [3usize, 64, clean.len() / 3] {
        std::fs::write(&p, &clean[..clean.len() - cut]).unwrap();
        let e = snapshot::load_lazy(&p).unwrap_err();
        let msg = format!("{e:#}");
        assert!(
            msg.contains("truncated") || msg.contains("exceeds file length"),
            "lazy open after {cut}B truncation: {msg}"
        );
        assert!(snapshot::load(&p).is_err(), "eager load after {cut}B truncation");
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn payload_corruption_is_caught_on_lazy_first_touch() {
    let _env = env_guard();
    let (art, rt) = setup();
    let p = tmp("crc_lazy.cbqs");
    export_snapshot(&art, &rt, &p);

    // find a packed-code payload via the inspector's offset table and flip
    // one bit in the middle of it
    let info = snapshot::inspect(&p).unwrap();
    let rec = info
        .tensors
        .iter()
        .find(|t| t.name == "blocks.1.wq.q")
        .expect("block 1 wq codes in offset table");
    let mut bytes = std::fs::read(&p).unwrap();
    let pos = rec.offset as usize + rec.bytes / 2;
    bytes[pos] ^= 0x20;
    std::fs::write(&p, &bytes).unwrap();

    // lazy open succeeds — the metadata is intact...
    let lz = snapshot::load_lazy(&p).unwrap();
    // ...undamaged blocks still materialize...
    lz.model.block(0).unwrap();
    // ...and the damaged one fails its per-tensor CRC on first touch
    let e = lz.model.block(1).unwrap_err();
    assert!(format!("{e:#}").contains("checksum"), "{e:#}");

    // the eager loader (which touches everything) refuses the whole file
    assert!(snapshot::load(&p).is_err());
    std::fs::remove_file(&p).ok();
}

// ---------------------------------------------------------------------------
// serving: bitwise identity + bounded residency + eviction/retouch
// ---------------------------------------------------------------------------

#[test]
fn mmap_serving_is_bitwise_identical_with_bounded_residency() {
    let _env = env_guard();
    let (art, rt) = setup();
    let p = tmp("serve.cbqs");
    let (cfg, _) = export_snapshot(&art, &rt, &p);

    let mut reg = ModelRegistry::new();
    let eager_snap = reg.load_with("eager", &p, LoadMode::Eager).unwrap();
    let mmap_snap = reg.load_with("mmap", &p, LoadMode::Mmap).unwrap();
    assert!(mmap_snap.is_lazy() && !eager_snap.is_lazy());

    let eager = ServeEngine::new(&rt, &art, eager_snap.clone()).unwrap();
    let lazy = ServeEngine::with_options(
        &rt,
        &art,
        mmap_snap,
        // packed: false — this test covers the dequantized-f32 lazy path;
        // the packed domain has its own identity + residency test below
        EngineOptions { resident_windows: Some(1), resident_bytes: None, packed: false },
    )
    .unwrap();
    assert!(lazy.is_lazy() && !eager.is_lazy());
    assert!(eager.plan_len() >= 2, "need >= 2 windows to exercise eviction");

    let requests = batcher::standard_mix(cfg.seq, 8, 3, 2);
    let (resp_e, _) = Batcher::coalescing(&eager).run(&eager, &requests).unwrap();
    let (resp_m, _) = Batcher::coalescing(&lazy).run(&lazy, &requests).unwrap();
    assert_eq!(resp_m, resp_e, "mmap responses must be bitwise-identical to eager");

    // residency: the 1-window budget bounds the peak — never two windows
    // resident, peak bytes well under the eager engine's full-plan pins —
    // and the 2-step plan under a 1-slot cache means every forward evicts
    let res = lazy.residency();
    let eager_res = eager.residency();
    assert_eq!(res.peak_windows, 1, "budget of 1 window exceeded: {res:?}");
    assert!(res.resident_windows <= 1);
    assert!(res.evictions > 0, "2-window plan under 1-window budget must evict: {res:?}");
    assert!(res.faults > eager.plan_len() as u64, "re-faults after eviction expected");
    assert!(res.peak_bytes > 0, "pinned windows must be accounted: {res:?}");
    assert!(
        res.peak_bytes < eager_res.resident_bytes,
        "lazy peak {} must undercut eager residency {}",
        res.peak_bytes,
        eager_res.resident_bytes
    );

    // eviction-then-retouch: a second pass re-materializes every window
    // from the map and must reproduce the responses bit for bit
    let (resp_m2, _) = Batcher::coalescing(&lazy).run(&lazy, &requests).unwrap();
    assert_eq!(resp_m2, resp_e, "retouched windows diverged from eager");

    std::fs::remove_file(&p).ok();
}

#[test]
fn evict_then_refault_counts_fault_not_prefetch_hit() {
    let _env = env_guard();
    let (art, rt) = setup();
    let p = tmp("accounting.cbqs");
    let (cfg, _) = export_snapshot(&art, &rt, &p);

    let mut reg = ModelRegistry::new();
    let eager_snap = reg.load_with("acct-eager", &p, LoadMode::Eager).unwrap();
    let mmap_snap = reg.load_with("acct-mmap", &p, LoadMode::Mmap).unwrap();
    let eager = ServeEngine::new(&rt, &art, eager_snap).unwrap();
    let lazy = ServeEngine::with_options(
        &rt,
        &art,
        mmap_snap,
        EngineOptions { resident_windows: Some(1), resident_bytes: None, packed: false },
    )
    .unwrap();
    let plan_len = lazy.plan_len() as u64;
    assert!(plan_len >= 2, "need >= 2 windows for eviction traffic");

    // regression (residency accounting): a window evicted and later
    // re-faulted must count a plain fault — never a stale prefetch hit from
    // a warm marker that survived the eviction — and every fault after the
    // very first one evicts the single resident slot, exactly once
    let requests = batcher::standard_mix(cfg.seq, 8, 3, 2);
    let (resp_e, _) = Batcher::coalescing(&eager).run(&eager, &requests).unwrap();
    let (resp_1, st_1) = Batcher::coalescing(&lazy).run(&lazy, &requests).unwrap();
    assert_eq!(resp_1, resp_e, "pass A diverged from eager");
    let r1 = lazy.residency();

    // under a 1-window budget the 2-step plan alternates windows, so no
    // touch ever finds its window still resident
    assert_eq!(r1.hits, 0, "1-window budget over a 2-step plan cannot hit: {r1:?}");
    assert_eq!(r1.faults, st_1.dispatches as u64 * plan_len, "every window touch faults");
    assert_eq!(
        r1.evictions,
        r1.faults - 1,
        "each fault but the first evicts the one resident window: {r1:?}"
    );

    // pass B re-faults every window from the map: counters double, the
    // responses stay bit-identical, and warm-marker hits never exceed the
    // warms actually issued
    let (resp_2, st_2) = Batcher::coalescing(&lazy).run(&lazy, &requests).unwrap();
    assert_eq!(resp_2, resp_e, "pass B diverged from eager");
    let r2 = lazy.residency();
    assert_eq!(st_2.dispatches, st_1.dispatches, "same mix must batch the same way");
    assert_eq!(r2.hits, 0);
    assert_eq!(r2.faults, 2 * r1.faults, "pass B must re-fault every window");
    assert_eq!(r2.evictions, r2.faults - 1);
    assert!(
        r2.prefetch_hits <= r2.prefetches,
        "a hit without a live warm means the marker leaked across eviction: {r2:?}"
    );

    std::fs::remove_file(&p).ok();
}

#[test]
fn packed_serving_is_bitwise_identical_with_smaller_residency() {
    let _env = env_guard();
    let (art, rt) = setup();
    let p = tmp("packed.cbqs");
    let (cfg, _) = export_snapshot(&art, &rt, &p);

    let mut reg = ModelRegistry::new();
    let eager_snap = reg.load_with("pk-eager", &p, LoadMode::Eager).unwrap();
    let f32_snap = reg.load_with("pk-f32", &p, LoadMode::Mmap).unwrap();
    let packed_snap = reg.load_with("pk-packed", &p, LoadMode::Mmap).unwrap();

    let eager = ServeEngine::new(&rt, &art, eager_snap).unwrap();
    let f32_eng = ServeEngine::with_options(
        &rt,
        &art,
        f32_snap,
        EngineOptions { resident_windows: Some(1), resident_bytes: None, packed: false },
    )
    .unwrap();
    let packed_eng = ServeEngine::with_options(
        &rt,
        &art,
        packed_snap.clone(),
        EngineOptions { resident_windows: Some(1), resident_bytes: None, packed: true },
    )
    .unwrap();
    assert!(packed_eng.is_packed(), "native mmap engine must honor packed: true");
    assert!(!f32_eng.is_packed() && !eager.is_packed());

    // bitwise identity across all three domains: eager f32, lazy f32, lazy
    // packed (2/4/8-bit codes + scales fed straight to the quantized matmul)
    let requests = batcher::standard_mix(cfg.seq, 8, 3, 2);
    let (resp_e, _) = Batcher::coalescing(&eager).run(&eager, &requests).unwrap();
    let (resp_f, _) = Batcher::coalescing(&f32_eng).run(&f32_eng, &requests).unwrap();
    let (resp_p, _) = Batcher::coalescing(&packed_eng).run(&packed_eng, &requests).unwrap();
    assert_eq!(resp_f, resp_e, "lazy f32 diverged from eager");
    assert_eq!(resp_p, resp_e, "packed-domain serving must be bitwise-identical to f32");

    // the 4-bit snapshot pins >= 4x fewer bytes per window in the packed
    // domain: codes at 4 bits + one f32 scale column, versus dequantized
    // f32 weights plus the f32-graph side tensors (s_w, rounding state)
    let rf = f32_eng.residency();
    let rp = packed_eng.residency();
    assert!(rp.peak_bytes > 0 && rf.peak_bytes > 0, "pins must be accounted: {rp:?} {rf:?}");
    assert!(
        rp.peak_bytes * 4 <= rf.peak_bytes,
        "packed peak {} not >= 4x under f32 peak {}",
        rp.peak_bytes,
        rf.peak_bytes
    );

    // prefetch: the 2-step plan under a 1-window budget keeps issuing
    // background warms for the evicted next window, and later faults land
    // on warmed pages (only a real mapping has file spans to warm)
    if packed_snap.model.lazy().unwrap().is_mapped() {
        assert!(rp.prefetches > 0, "prefetches expected on a mapped 2-step plan: {rp:?}");
        assert!(rp.prefetch_hits > 0, "faults should land on warmed windows: {rp:?}");
    }

    std::fs::remove_file(&p).ok();
}

#[test]
fn concurrent_engines_share_one_mapping_and_agree() {
    let _env = env_guard();
    let (art, rt) = setup();
    let p = tmp("shared.cbqs");
    let (cfg, _) = export_snapshot(&art, &rt, &p);

    let mut reg = ModelRegistry::new();
    let snap = reg.load_with("shared", &p, LoadMode::Mmap).unwrap();
    // registry cache: a second load by the same name is the same Arc —
    // and therefore the same mapping
    let snap2 = reg.load_with("shared", &p, LoadMode::Mmap).unwrap();
    assert!(Arc::ptr_eq(&snap, &snap2), "registry must dedupe by name");

    let opts = EngineOptions { resident_windows: Some(1), resident_bytes: None, packed: false };
    let e1 = ServeEngine::with_options(&rt, &art, snap.clone(), opts).unwrap();
    let e2 = ServeEngine::with_options(&rt, &art, snap.clone(), opts).unwrap();

    // the registry entry both engines share holds exactly one byte source:
    // repeated zero-copy materializations view the same mapped bytes
    let m = snap.model.lazy().expect("mmap load must be lazy");
    assert_eq!(
        m.source_ptr(),
        snap2.model.lazy().unwrap().source_ptr(),
        "one mapping per registry entry"
    );
    if m.is_mapped() {
        let emb1 = m.embed().unwrap();
        let emb2 = m.embed().unwrap();
        assert!(
            cbq::tensor::Storage::ptr_eq(&emb1.data, &emb2.data),
            "mapped embed views must alias the same file bytes"
        );
    }

    // concurrent pinning from two engines over the one mapping: both must
    // serve the exact same answers as an eager reference
    let eager_snap = reg.load_with("shared-eager", &p, LoadMode::Eager).unwrap();
    let eager = ServeEngine::new(&rt, &art, eager_snap).unwrap();
    let requests = batcher::standard_mix(cfg.seq, 6, 2, 2);
    let (resp_ref, _) = Batcher::coalescing(&eager).run(&eager, &requests).unwrap();
    let (ra, rb) = std::thread::scope(|s| {
        let ha = s.spawn(|| Batcher::coalescing(&e1).run(&e1, &requests).unwrap().0);
        let hb = s.spawn(|| Batcher::coalescing(&e2).run(&e2, &requests).unwrap().0);
        (ha.join().unwrap(), hb.join().unwrap())
    });
    assert_eq!(ra, resp_ref, "engine 1 diverged");
    assert_eq!(rb, resp_ref, "engine 2 diverged");

    std::fs::remove_file(&p).ok();
}

// ---------------------------------------------------------------------------
// the positional-read fallback (CBQ_NO_MMAP=1)
// ---------------------------------------------------------------------------

#[test]
fn read_at_fallback_serves_identically_without_a_mapping() {
    let _env = env_guard();
    let (art, rt) = setup();
    let p = tmp("fallback.cbqs");
    let (cfg, _) = export_snapshot(&art, &rt, &p);

    let baseline = snapshot::load(&p).unwrap();

    // CBQ_NO_MMAP disables real mapping process-wide while set; ENV_LOCK
    // (held by every test in this binary) serializes the flip against any
    // concurrent env read, and the flag is always removed before release.
    std::env::set_var("CBQ_NO_MMAP", "1");
    let outcome: anyhow::Result<()> = (|| {
        let lz = snapshot::load_lazy(&p)?;
        anyhow::ensure!(!lz.model.is_mapped(), "CBQ_NO_MMAP=1 must suppress the mapping");
        anyhow::ensure!(lz.model.embed()? == baseline.model.params.embed, "embed differs");
        let mb = lz.model.block(0)?;
        for l in LINEARS {
            anyhow::ensure!(
                mb.params.linears[l] == baseline.model.params.blocks[0].linears[l],
                "fallback block 0 {l} differs"
            );
        }
        // and the serving layer agrees end-to-end
        let mut reg = ModelRegistry::new();
        let snap = reg.load_with("fb", &p, LoadMode::Mmap)?;
        let lazy = ServeEngine::with_options(
            &rt,
            &art,
            snap,
            EngineOptions { resident_windows: Some(1), resident_bytes: None, packed: false },
        )?;
        let requests = batcher::standard_mix(cfg.seq, 4, 2, 1);
        let (resp_m, _) = Batcher::coalescing(&lazy).run(&lazy, &requests)?;
        let mut reg2 = ModelRegistry::new();
        let esnap = reg2.load_with("fb-eager", &p, LoadMode::Eager)?;
        let eager = ServeEngine::new(&rt, &art, esnap)?;
        let (resp_e, _) = Batcher::coalescing(&eager).run(&eager, &requests)?;
        anyhow::ensure!(resp_m == resp_e, "fallback responses diverged");
        Ok(())
    })();
    std::env::remove_var("CBQ_NO_MMAP");
    outcome.unwrap();
    std::fs::remove_file(&p).ok();
}
