//! Snapshot store tests — all host-side (no artifacts or PJRT needed):
//! save/load round-trips a quantized model **bit-exactly**, corruption /
//! version / fingerprint mismatches are rejected, the packed dtype
//! round-trips w2/w4/w8 codes, and a w4 snapshot is a small fraction of the
//! f32 CBQW representation (true bitpacking, not fake-quant f32).

use std::collections::BTreeMap;

use cbq::calib::corpus::XorShift64Star;
use cbq::config::{BitSpec, RoundingMode};
use cbq::coordinator::{LinearQ, QuantizedModel};
use cbq::model_state::{BlockParams, ModelParams};
use cbq::quant::{self, LINEARS};
use cbq::runtime::ModelCfg;
use cbq::snapshot;
use cbq::tensor::io::{self, PackedTensor};
use cbq::tensor::Tensor;

struct Gen(XorShift64Star);

impl Gen {
    fn new(seed: u64) -> Self {
        Self(XorShift64Star::new(seed))
    }

    fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.0.next_u64() >> 40) as f32 / (1u64 << 24) as f32;
        lo + (hi - lo) * u
    }

    fn tensor(&mut self, dims: &[usize], scale: f32) -> Tensor {
        let n: usize = dims.iter().product();
        Tensor::new(dims.to_vec(), (0..n).map(|_| self.f32_in(-scale, scale)).collect())
    }
}

fn cfg(d_model: usize, d_ffn: usize, n_layers: usize, vocab: usize) -> ModelCfg {
    ModelCfg {
        name: "tiny".into(),
        d_model,
        n_layers,
        n_heads: 2,
        d_ffn,
        vocab,
        seq: 6,
        batch: 2,
        rank_pad: 4,
        head_dim: d_model / 2,
        outlier_channels: 0,
        outlier_gain: 0.0,
    }
}

/// Build a synthetic finalized quantized model the way the pipeline does:
/// RTN-bake each linear with scales derived from the pre-quant weights, and
/// install those *same* scales in the qstate (the run_rtn/run_gptq/run_cbd
/// invariant the snapshot round-trip relies on).
fn quantized_model(cfg: &ModelCfg, bits: BitSpec, rounding: RoundingMode, seed: u64) -> QuantizedModel {
    let mut g = Gen::new(seed);
    let d = cfg.d_model;
    let mut blocks = Vec::new();
    let mut qstate = Vec::new();
    for bi in 0..cfg.n_layers {
        let mut linears = BTreeMap::new();
        let mut lqs = BTreeMap::new();
        for l in LINEARS {
            let (fan_in, fan_out) = cfg.linear_shape(l);
            let w = g.tensor(&[fan_in, fan_out], 0.5);
            let b = bits.weight_bits(bi, l);
            let qmax = cbq::config::qmax(b);
            let s = quant::init_scales(&w, qmax);
            let wq = quant::fake_quant_rtn(&w, &s, qmax);
            let (a1, a2) = if matches!(rounding, RoundingMode::Lora) {
                (g.tensor(&[fan_in, cfg.rank_pad], 0.01), g.tensor(&[cfg.rank_pad, fan_out], 0.01))
            } else {
                (Tensor::zeros(&[fan_in, cfg.rank_pad]), Tensor::zeros(&[cfg.rank_pad, fan_out]))
            };
            let lq = LinearQ::restore(&wq, s, g.f32_in(0.3, 1.5), a1, a2, b);
            linears.insert(l.to_string(), wq);
            lqs.insert(l.to_string(), lq);
        }
        blocks.push(BlockParams {
            attn_norm: g.tensor(&[d], 1.0),
            mlp_norm: g.tensor(&[d], 1.0),
            linears,
        });
        qstate.push(lqs);
    }
    QuantizedModel {
        params: ModelParams {
            embed: g.tensor(&[cfg.vocab, d], 0.2),
            final_norm: g.tensor(&[d], 1.0),
            head: g.tensor(&[d, cfg.vocab], 0.2),
            blocks,
        },
        qstate,
        bits,
        rounding,
    }
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(name)
}

// ---------------------------------------------------------------------------
// round trips
// ---------------------------------------------------------------------------

#[test]
fn roundtrip_is_bit_exact_across_bits_and_rounding() {
    for (seed, bits, rounding) in [
        (1u64, BitSpec::new(4, 16), RoundingMode::Lora),
        (2, BitSpec::new(2, 16), RoundingMode::Nearest),
        (3, BitSpec::new(8, 8), RoundingMode::Lora),
        (4, BitSpec::new(3, 4), RoundingMode::Nearest),
    ] {
        let c = cfg(8, 16, 2, 12);
        let m = quantized_model(&c, bits.clone(), rounding, seed);
        let p = tmp(&format!("cbqs_rt_{seed}.cbqs"));
        snapshot::save(&p, &c, &m).unwrap();
        let snap = snapshot::load(&p).unwrap();
        std::fs::remove_file(&p).ok();

        assert_eq!(snap.meta.bits, bits);
        assert_eq!(snap.meta.rounding, rounding);
        assert_eq!(snap.meta.cfg, c);
        assert_eq!(snapshot::fingerprint_mismatches(&snap.meta.cfg, &c), Vec::<String>::new());

        // every tensor the eval path touches must be IDENTICAL f32 values
        let (a, b) = (&snap.model, &m);
        assert_eq!(a.params.embed, b.params.embed);
        assert_eq!(a.params.final_norm, b.params.final_norm);
        assert_eq!(a.params.head, b.params.head);
        for (ba, bb) in a.params.blocks.iter().zip(&b.params.blocks) {
            assert_eq!(ba.attn_norm, bb.attn_norm);
            assert_eq!(ba.mlp_norm, bb.mlp_norm);
            for l in LINEARS {
                assert_eq!(ba.linears[l], bb.linears[l], "weights of {l} not bit-exact");
            }
        }
        for (qa, qb) in a.qstate.iter().zip(&b.qstate) {
            for l in LINEARS {
                assert_eq!(qa[l].s_w, qb[l].s_w, "{l} scales");
                assert_eq!(qa[l].alpha, qb[l].alpha, "{l} alpha");
                assert_eq!(qa[l].a1, qb[l].a1, "{l} a1");
                assert_eq!(qa[l].a2, qb[l].a2, "{l} a2");
                assert_eq!(qa[l].bits_w, qb[l].bits_w, "{l} bits");
                assert_eq!(qa[l].qmax_w, qb[l].qmax_w, "{l} qmax");
            }
        }
        assert_eq!(a.bits, b.bits);
        assert_eq!(a.rounding, b.rounding);
    }
}

#[test]
fn roundtrip_preserves_per_layer_overrides() {
    let c = cfg(8, 16, 3, 12);
    let bits = BitSpec::w2a16_star(c.n_layers);
    let m = quantized_model(&c, bits.clone(), RoundingMode::Nearest, 77);
    let p = tmp("cbqs_star.cbqs");
    snapshot::save(&p, &c, &m).unwrap();
    let snap = snapshot::load(&p).unwrap();
    std::fs::remove_file(&p).ok();
    assert_eq!(snap.meta.bits, bits);
    assert_eq!(snap.model.qstate[0]["wdown"].bits_w, 4);
    assert_eq!(snap.model.qstate[1]["wdown"].bits_w, 2);
    assert_eq!(snap.model.qstate[2]["wdown"].bits_w, 4);
    assert_eq!(snap.model.params.blocks[0].linears["wdown"], m.params.blocks[0].linears["wdown"]);
}

// ---------------------------------------------------------------------------
// size: true bitpacking
// ---------------------------------------------------------------------------

#[test]
fn w4_snapshot_is_at_most_a_sixth_of_f32_cbqw() {
    // a shape where the quantized linears dominate (as in any real LLM)
    let c = cfg(64, 128, 4, 16);
    let m = quantized_model(&c, BitSpec::new(4, 16), RoundingMode::Nearest, 5);

    let p_snap = tmp("cbqs_size.cbqs");
    let report = snapshot::save(&p_snap, &c, &m).unwrap();

    // the equivalent f32 CBQW file
    let mut all = BTreeMap::new();
    all.insert("embed".to_string(), m.params.embed.clone());
    all.insert("final_norm".to_string(), m.params.final_norm.clone());
    all.insert("head".to_string(), m.params.head.clone());
    for (i, blk) in m.params.blocks.iter().enumerate() {
        all.insert(format!("blocks.{i}.attn_norm"), blk.attn_norm.clone());
        all.insert(format!("blocks.{i}.mlp_norm"), blk.mlp_norm.clone());
        for l in LINEARS {
            all.insert(format!("blocks.{i}.{l}"), blk.linears[l].clone());
        }
    }
    let p_cbqw = tmp("cbqs_size_ref.bin");
    io::write_tensors(&p_cbqw, &all).unwrap();
    let cbqw_bytes = std::fs::metadata(&p_cbqw).unwrap().len();
    let snap_bytes = std::fs::metadata(&p_snap).unwrap().len();
    std::fs::remove_file(&p_snap).ok();
    std::fs::remove_file(&p_cbqw).ok();

    assert_eq!(snap_bytes, report.file_bytes);
    // true 4-bit packing: codes are exactly half a byte per weight
    let linear_params: u64 = (c.quant_params()) as u64;
    assert_eq!(report.packed_code_bytes, linear_params / 2);
    assert!(
        snap_bytes * 6 <= cbqw_bytes,
        "w4 snapshot {snap_bytes}B should be <= 1/6 of CBQW {cbqw_bytes}B"
    );

    // w2 packs twice as tight again on the code payload
    let m2 = quantized_model(&c, BitSpec::new(2, 16), RoundingMode::Nearest, 6);
    let p2 = tmp("cbqs_size_w2.cbqs");
    let r2 = snapshot::save(&p2, &c, &m2).unwrap();
    std::fs::remove_file(&p2).ok();
    assert_eq!(r2.packed_code_bytes, linear_params / 4);
}

// ---------------------------------------------------------------------------
// rejection paths
// ---------------------------------------------------------------------------

#[test]
fn rejects_corruption_version_magic_and_fp_models() {
    let c = cfg(8, 16, 2, 12);
    let m = quantized_model(&c, BitSpec::new(4, 16), RoundingMode::Nearest, 9);
    let p = tmp("cbqs_reject.cbqs");
    snapshot::save(&p, &c, &m).unwrap();
    let clean = std::fs::read(&p).unwrap();

    // bad checksum: flip a bit inside a tensor payload (located via the
    // v2 offset table — a blind mid-file flip could land in alignment
    // padding, which is structurally dead and not CRC-covered)
    let info = snapshot::inspect(&p).unwrap();
    let rec = info.tensors.iter().find(|t| t.name == "embed").unwrap();
    let mut bad = clean.clone();
    bad[rec.offset as usize + rec.bytes / 2] ^= 0x40;
    std::fs::write(&p, &bad).unwrap();
    let e = snapshot::load(&p).unwrap_err();
    assert!(format!("{e:#}").contains("checksum"), "{e:#}");
    // metadata corruption is caught by the meta CRC before any payload
    let mut bad = clean.clone();
    bad[20] ^= 0x40; // inside the header JSON
    std::fs::write(&p, &bad).unwrap();
    let e = snapshot::load(&p).unwrap_err();
    assert!(format!("{e:#}").contains("checksum"), "{e:#}");

    // version mismatch
    let mut bad = clean.clone();
    bad[4] = 0xEE;
    std::fs::write(&p, &bad).unwrap();
    let e = snapshot::load(&p).unwrap_err();
    assert!(format!("{e:#}").contains("version"), "{e:#}");

    // corrupt header magic
    let mut bad = clean.clone();
    bad[1] = b'!';
    std::fs::write(&p, &bad).unwrap();
    let e = snapshot::load(&p).unwrap_err();
    assert!(format!("{e:#}").contains("magic"), "{e:#}");

    // truncation
    std::fs::write(&p, &clean[..clean.len() - 9]).unwrap();
    assert!(snapshot::load(&p).is_err());
    std::fs::remove_file(&p).ok();

    // FP models don't export
    let fp = quantized_model(&c, BitSpec::new(16, 16), RoundingMode::Nearest, 10);
    let e = snapshot::save(tmp("cbqs_fp.cbqs"), &c, &fp).unwrap_err();
    assert!(format!("{e:#}").contains("packable"), "{e:#}");
}

#[test]
fn rejects_off_grid_weights() {
    let c = cfg(8, 16, 1, 12);
    let mut m = quantized_model(&c, BitSpec::new(4, 16), RoundingMode::Nearest, 11);
    // nudge one baked weight off the quantization grid
    m.params.blocks[0].linears.get_mut("wq").unwrap().data[3] += 1e-3;
    let e = snapshot::save(tmp("cbqs_offgrid.cbqs"), &c, &m).unwrap_err();
    assert!(format!("{e:#}").contains("grid"), "{e:#}");
}

#[test]
fn fingerprint_mismatch_is_reported_per_field() {
    let a = cfg(8, 16, 2, 12);
    let mut b = a.clone();
    b.d_model = 16;
    b.n_layers = 4;
    let mism = snapshot::fingerprint_mismatches(&a, &b);
    assert_eq!(mism.len(), 2);
    assert!(mism.iter().any(|m| m.contains("d_model")));
    assert!(mism.iter().any(|m| m.contains("n_layers")));
}

// ---------------------------------------------------------------------------
// packed dtype property tests (w2/w4/w8)
// ---------------------------------------------------------------------------

#[test]
fn prop_pack_unpack_roundtrips_random_codes() {
    for seed in 0..100u64 {
        let mut g = Gen::new(seed + 400);
        for bits in [2u8, 4, 8] {
            let half = 1i32 << (bits - 1);
            let n = 1 + (g.0.next_below(64) as usize);
            let codes: Vec<i32> = (0..n)
                .map(|_| (g.0.next_below(2 * half as u64) as i32) - half)
                .collect();
            let packed = PackedTensor::pack(&codes, vec![n], bits).unwrap();
            assert_eq!(packed.data.len(), PackedTensor::byte_len(bits, n), "seed {seed}");
            assert_eq!(packed.unpack(), codes, "seed {seed} bits {bits}");
        }
    }
}

#[test]
fn prop_packed_grid_dequant_matches_fake_quant() {
    // derive codes from random weights the way save() does, and check the
    // dequantized values reproduce fake_quant_rtn exactly
    for seed in 0..50u64 {
        let mut g = Gen::new(seed + 900);
        for bits in [2u8, 4, 8] {
            let qmax = cbq::config::qmax(bits);
            let w = g.tensor(&[5, 7], 1.0);
            let s = quant::init_scales(&w, qmax);
            let wq = quant::fake_quant_rtn(&w, &s, qmax);
            let half = 1i32 << (bits - 1);
            let codes: Vec<i32> = (0..5 * 7)
                .map(|i| {
                    let sc = s.data[i % 7].max(quant::EPS);
                    (wq.data[i] / sc).round() as i32
                })
                .collect();
            assert!(codes.iter().all(|&q| (-half..half).contains(&q)), "seed {seed}");
            let packed = PackedTensor::pack(&codes, vec![5, 7], bits).unwrap();
            for (i, q) in packed.unpack().into_iter().enumerate() {
                let sc = s.data[i % 7].max(quant::EPS);
                assert_eq!(q as f32 * sc, wq.data[i], "seed {seed} bits {bits} idx {i}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// edge containers (fuzz-harness satellite): shapes at the format's limits
// must round-trip bit-exactly through BOTH frame versions
// ---------------------------------------------------------------------------

#[test]
fn edge_containers_round_trip_v1_and_v2() {
    use cbq::json::Value;
    use cbq::snapshot::format::{self, OpenMode};
    use cbq::tensor::io::Entry;

    let header = Value::obj(vec![("format", Value::str("CBQS")), ("edge", Value::num(1.0))]);
    // a scalar: rank 0, one element (the format allows empty dims)
    let scalar = Entry::F32(Tensor::new(vec![], vec![3.25]));
    // a packed tensor under the longest legal name
    let long_name = "n".repeat(io::MAX_NAME_LEN);
    let packed =
        Entry::Packed(PackedTensor::pack(&[-2, 1, 0, -1, 1, 0], vec![2, 3], 2).unwrap());

    let cases: Vec<(&str, Vec<(String, Entry)>)> = vec![
        ("empty", vec![]), // zero tensors: header-only container
        ("scalar", vec![("s".to_string(), scalar)]),
        ("maxname", vec![(long_name, packed)]),
    ];

    for (tag, entries) in &cases {
        // v1 frame
        let p1 = tmp(&format!("cbqs_edge_v1_{tag}.cbqs"));
        format::write_container_v1(&p1, &header, entries).unwrap();
        let (h1, back1) = format::read_container(&p1).unwrap();
        assert_eq!(h1, header, "{tag}: v1 header");
        assert_eq!(back1.len(), entries.len(), "{tag}: v1 entry count");
        for (name, e) in entries {
            assert_eq!(back1.get(name), Some(e), "{tag}: v1 entry {name:.32}");
        }
        std::fs::remove_file(&p1).ok();

        // v2 frame (offset table + per-tensor CRCs), eager AND lazy reads
        let with_groups: Vec<(String, Entry, i32)> =
            entries.iter().map(|(n, e)| (n.clone(), e.clone(), -1)).collect();
        let p2 = tmp(&format!("cbqs_edge_v2_{tag}.cbqs"));
        format::write_container(&p2, &header, &with_groups).unwrap();
        let (h2, back2) = format::read_container(&p2).unwrap();
        assert_eq!(h2, header, "{tag}: v2 header");
        assert_eq!(back2, back1, "{tag}: v1 and v2 must decode identically");
        let lazy = format::open_container(&p2, OpenMode::Lazy).unwrap();
        assert_eq!(lazy.records.len(), entries.len(), "{tag}: v2 record count");
        for rec in &lazy.records {
            let e = lazy.materialize(rec).unwrap();
            assert_eq!(back2.get(&rec.name), Some(&e), "{tag}: lazy materialize {:.32}", rec.name);
        }
        std::fs::remove_file(&p2).ok();
    }
}
