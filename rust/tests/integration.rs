//! Integration tests over the real artifacts: cross-language parity
//! (corpus PRNG, FP forward, NLL), runtime contract checks, and an
//! end-to-end mini-quantization. Requires `make artifacts` to have run —
//! in environments without artifacts (or with the stub xla backend) every
//! test here skips instead of failing, so tier-1 stays green; the host-only
//! coverage lives in the unit tests, proptests.rs, snapshot.rs and serve.rs.

use cbq::calib::{self, corpus};
use cbq::config::{BitSpec, PreprocMethod, QuantJob, RoundingMode};
use cbq::coordinator::Pipeline;
use cbq::runtime::{Artifacts, Bindings, Runtime};
use cbq::tensor::{io, Tensor};

// PjRtClient is Rc-based (not Sync), so each test owns its runtime.
// Returns None (=> skip) when artifacts or a real PJRT backend are absent.
fn setup() -> Option<(Artifacts, Runtime)> {
    let art = match Artifacts::discover() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("skipping integration test: {e:#}");
            return None;
        }
    };
    match Runtime::new(&art) {
        Ok(rt) => Some((art, rt)),
        Err(e) => {
            eprintln!("skipping integration test: {e:#}");
            None
        }
    }
}

fn close(a: &[f32], b: &[f32], atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst <= atol, "{what}: max abs err {worst} > {atol}");
}

// ---------------------------------------------------------------------------
// cross-language parity
// ---------------------------------------------------------------------------

#[test]
fn corpus_matches_python_reference() {
    let Some((art, _rt)) = setup() else { return };
    let refs = art.corpus_ref().unwrap();
    for (style, want) in [(corpus::Style::C4, &refs["c4"]), (corpus::Style::Wiki, &refs["wiki"])] {
        let got = corpus::generate(style, 42, want.len());
        assert_eq!(&got, want, "corpus {:?} diverges from python", style);
    }
}

#[test]
fn fp_forward_matches_python_reference() {
    let Some((art, rt)) = setup() else { return };
    let refs = io::read_tensors(art.dir.join("test_ref_t.bin")).unwrap();
    let pipe = Pipeline::new(&art, &rt, "t").unwrap();

    // tokens generated in rust must equal the reference tokens
    let batch = &calib::eval_stream(corpus::Style::C4, 1, 4, pipe.cfg.seq)[0];
    let x = batch.inputs();
    let x_want: Vec<i32> = refs["tokens_x"].data.iter().map(|&v| v as i32).collect();
    assert_eq!(x.data, x_want, "eval tokens diverge");

    // embedding gather
    let h0 = pipe.fp.embed_tokens(&x.data, 4, pipe.cfg.seq);
    close(&h0.data, &refs["h_embed"].data, 1e-6, "embedding");

    // full FP forward through win_fwd_w1 chain
    let fp = pipe.fp_model();
    let h = pipe.forward_hidden(&fp, &x).unwrap();
    close(&h.data, &refs["h_final"].data, 2e-3, "fp hidden");

    // masked NLL through lm_eval
    let mask = Tensor::full(&[4, pipe.cfg.seq], 1.0);
    let (nll, _) = pipe.lm_nll(&fp, &x, &batch.targets(), &mask).unwrap();
    close(&nll, &refs["nll_per_seq"].data, 0.5, "nll per sequence");
}

#[test]
fn fp_perplexity_in_sane_range() {
    let Some((art, rt)) = setup() else { return };
    let pipe = Pipeline::new(&art, &rt, "t").unwrap();
    let fp = pipe.fp_model();
    let ppl = pipe.perplexity(&fp, corpus::Style::C4, 4).unwrap();
    assert!(
        (5.0..120.0).contains(&ppl),
        "FP ppl {ppl} outside sane range — eval path broken"
    );
}

// ---------------------------------------------------------------------------
// runtime contract
// ---------------------------------------------------------------------------

#[test]
fn runtime_rejects_missing_and_misshapen_inputs() {
    let Some((art, r)) = setup() else { return };
    let r = &r;
    let err = r.run("lm_eval_t", Bindings::new().inner()).unwrap_err();
    assert!(format!("{err:#}").contains("missing input"));

    let pipe = Pipeline::new(&art, r, "t").unwrap();
    let mut b = Bindings::new();
    b.set("h", Tensor::zeros(&[1, 2, 3])); // wrong shape
    b.set("final_norm", pipe.fp.final_norm.clone());
    b.set("head", pipe.fp.head.clone());
    let err = r.run("lm_eval_t", b.inner()).unwrap_err();
    assert!(format!("{err:#}").contains("shape mismatch"), "got: {err:#}");
}

#[test]
fn unknown_executable_is_error() {
    let Some((_art, rt)) = setup() else { return };
    assert!(rt.run("nope", Bindings::new().inner()).is_err());
}

// ---------------------------------------------------------------------------
// quantization behaviour on the real model
// ---------------------------------------------------------------------------

fn quick_job(mut job: QuantJob) -> QuantJob {
    job.calib_sequences = 8;
    job.epochs = 1;
    job
}

#[test]
fn rtn_w8_is_near_lossless_and_w2_is_not() {
    let Some((art, rt)) = setup() else { return };
    let mut pipe = Pipeline::new(&art, &rt, "t").unwrap();
    let fp = pipe.fp_model();
    let fp_ppl = pipe.perplexity(&fp, corpus::Style::C4, 4).unwrap();

    let (m8, _) = pipe.run(&quick_job(QuantJob::rtn(BitSpec::new(8, 16)))).unwrap();
    let p8 = pipe.perplexity(&m8, corpus::Style::C4, 4).unwrap();
    assert!((p8 - fp_ppl).abs() / fp_ppl < 0.05, "W8 rtn ppl {p8} vs fp {fp_ppl}");

    let (m2, _) = pipe.run(&quick_job(QuantJob::rtn(BitSpec::w2a16()))).unwrap();
    let p2 = pipe.perplexity(&m2, corpus::Style::C4, 4).unwrap();
    assert!(p2 > fp_ppl * 1.5, "W2 rtn should degrade badly: {p2} vs {fp_ppl}");
}

#[test]
fn cbq_w2_beats_rtn_w2() {
    let Some((art, rt)) = setup() else { return };
    let mut pipe = Pipeline::new(&art, &rt, "t").unwrap();
    let (rtn, _) = pipe.run(&quick_job(QuantJob::rtn(BitSpec::w2a16()))).unwrap();
    let p_rtn = pipe.perplexity(&rtn, corpus::Style::C4, 4).unwrap();

    let mut job = quick_job(QuantJob::cbq(BitSpec::w2a16()));
    job.epochs = 2;
    let (cbq, summary) = pipe.run(&job).unwrap();
    let p_cbq = pipe.perplexity(&cbq, corpus::Style::C4, 4).unwrap();
    assert!(
        p_cbq < p_rtn,
        "CBQ W2 ({p_cbq}) must beat RTN W2 ({p_rtn}); window losses {:?}",
        summary.window_losses
    );
}

#[test]
fn gptq_runs_and_beats_rtn_at_w2() {
    let Some((art, rt)) = setup() else { return };
    let mut pipe = Pipeline::new(&art, &rt, "t").unwrap();
    let (rtn, _) = pipe.run(&quick_job(QuantJob::rtn(BitSpec::w2a16()))).unwrap();
    let p_rtn = pipe.perplexity(&rtn, corpus::Style::C4, 4).unwrap();
    let (g, _) = pipe.run(&quick_job(QuantJob::gptq(BitSpec::w2a16()))).unwrap();
    let p_g = pipe.perplexity(&g, corpus::Style::C4, 4).unwrap();
    assert!(p_g < p_rtn * 1.05, "GPTQ W2 {p_g} should be <= RTN {p_rtn}");
}

#[test]
fn cbd_window_losses_are_finite() {
    let Some((art, rt)) = setup() else { return };
    let mut pipe = Pipeline::new(&art, &rt, "t").unwrap();
    let mut job = quick_job(QuantJob::cbq(BitSpec::w4a4()));
    job.window = 2;
    job.overlap = 1;
    let (_m, summary) = pipe.run(&job).unwrap();
    assert!(!summary.window_losses.is_empty());
    assert!(summary.window_losses.iter().all(|l| l.is_finite()));
}

#[test]
fn star_override_only_changes_marked_layers() {
    let Some((art, rt)) = setup() else { return };
    let pipe = Pipeline::new(&art, &rt, "t").unwrap();
    let bits = BitSpec::w2a16_star(pipe.cfg.n_layers);
    let qs = pipe.init_qstate(&pipe.fp, &bits, 5, RoundingMode::Nearest);
    assert_eq!(qs[0]["wdown"].bits_w, 4);
    assert_eq!(qs[0]["wq"].bits_w, 2);
    let last = pipe.cfg.n_layers - 1;
    assert_eq!(qs[last]["wdown"].bits_w, 4);
    assert_eq!(qs[1]["wdown"].bits_w, 2);
}

#[test]
fn preproc_cfp_reports_work_on_outlier_injected_model() {
    let Some((art, rt)) = setup() else { return };
    let mut pipe = Pipeline::new(&art, &rt, "t").unwrap();
    let mut job = quick_job(QuantJob::rtn(BitSpec::w4a4()));
    job.preproc = PreprocMethod::CfpFull;
    let (_m, summary) = pipe.run(&job).unwrap();
    // the build injects activation outlier channels; CFP must find some
    assert!(
        summary.preproc_channels_scaled > 0,
        "CFP found no outlier channels on an outlier-injected model"
    );
}

// ---------------------------------------------------------------------------
// runtime pinned-path equivalence + eval determinism
// ---------------------------------------------------------------------------

#[test]
fn pinned_execution_matches_full_upload() {
    use std::collections::BTreeMap;
    let Some((art, rt)) = setup() else { return };
    let pipe = Pipeline::new(&art, &rt, "t").unwrap();
    let qs = pipe.init_qstate(
        &pipe.fp,
        &BitSpec::w4a4(),
        5,
        RoundingMode::Lora,
    );
    let batch = &calib::calibration(4, 4, pipe.cfg.seq)[0];
    let h0 = pipe.fp.embed_tokens(&batch.inputs().data, 4, pipe.cfg.seq);
    let mut b = cbq::runtime::Bindings::new();
    b.set("h_in", h0.clone());
    b.set("target", Tensor::zeros(&h0.dims));
    Pipeline::bind_block_weights(&mut b, 0, &pipe.fp.blocks[0]);
    Pipeline::bind_qblock(&mut b, 0, &qs[0], 7.0, 1.0, 1.0, false);
    Pipeline::bind_globals(&mut b, 1.0, 10.0, 0.01, 1.0, 1.0);

    let full = rt.run("win_fwd_w1_t", b.inner()).unwrap();
    let statics: BTreeMap<String, cbq::runtime::Value> = b
        .inner()
        .iter()
        .filter(|(k, _)| k.starts_with("blocks."))
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect();
    let pinned = rt.pin("win_fwd_w1_t", &statics).unwrap();
    let dynamic: BTreeMap<String, cbq::runtime::Value> = b
        .inner()
        .iter()
        .filter(|(k, _)| !statics.contains_key(*k))
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect();
    let pin = rt.run_pinned(&pinned, &dynamic).unwrap();
    assert_eq!(full["h_out"].dims, pin["h_out"].dims);
    for (a, b) in full["h_out"].data.iter().zip(&pin["h_out"].data) {
        assert_eq!(a, b, "pinned path must be bit-identical");
    }
}

#[test]
fn perplexity_is_deterministic() {
    let Some((art, rt)) = setup() else { return };
    let pipe = Pipeline::new(&art, &rt, "t").unwrap();
    let fp = pipe.fp_model();
    let a = pipe.perplexity(&fp, corpus::Style::C4, 2).unwrap();
    let b = pipe.perplexity(&fp, corpus::Style::C4, 2).unwrap();
    assert_eq!(a, b);
}

#[test]
fn zero_shot_fp_beats_chance() {
    let Some((art, rt)) = setup() else { return };
    let pipe = Pipeline::new(&art, &rt, "t").unwrap();
    let fp = pipe.fp_model();
    let r = pipe.zero_shot(&fp, 16).unwrap();
    // TopicMatch is the easiest task: the trained FP model must clear 50%
    assert!(
        r.accuracy["TopicMatch"] > 0.5,
        "FP TopicMatch accuracy {} at chance — task or model broken",
        r.accuracy["TopicMatch"]
    );
    assert!(r.mrr > 0.25, "ranking MRR {} below random", r.mrr);
}

#[test]
fn cbq_star_recovers_over_cbq_at_w2() {
    let Some((art, rt)) = setup() else { return };
    let mut pipe = Pipeline::new(&art, &rt, "t").unwrap();
    let mut base = quick_job(QuantJob::cbq(BitSpec::w2a16()));
    base.epochs = 4;
    base.calib_sequences = 16;
    let mut star = base.clone();
    star.bits = BitSpec::w2a16_star(pipe.cfg.n_layers);
    let (m1, _) = pipe.run(&base).unwrap();
    let (m2, _) = pipe.run(&star).unwrap();
    let p1 = pipe.perplexity(&m1, corpus::Style::C4, 4).unwrap();
    let p2 = pipe.perplexity(&m2, corpus::Style::C4, 4).unwrap();
    // CBQ* promotes the most damaging layers to 4 bits; it must not hurt
    assert!(p2 < p1 * 1.05, "CBQ* ({p2}) should be <= CBQ ({p1})");
}

#[test]
fn dense_adaround_path_runs() {
    let Some((art, rt)) = setup() else { return };
    let mut pipe = Pipeline::new(&art, &rt, "t").unwrap();
    let mut job = quick_job(QuantJob::cbq(BitSpec::w4a4()));
    job.rounding = RoundingMode::DenseAdaRound;
    job.window = 2; // dense artifact exported at w=2
    job.overlap = 1;
    let (m, s) = pipe.run(&job).unwrap();
    assert!(s.window_losses.iter().all(|l| l.is_finite()));
    let ppl = pipe.perplexity(&m, corpus::Style::C4, 2).unwrap();
    assert!(ppl.is_finite() && ppl < 1e4);
}
