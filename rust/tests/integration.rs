//! Integration tests over real artifacts when present, else **synthetic
//! artifacts** generated on the fly (`runtime::synth`) and executed on the
//! native CPU backend — so this suite runs live everywhere instead of
//! self-skipping. Only the Python cross-language parity checks still gate
//! on files that exist solely in `make artifacts` builds (test_ref_t.bin).

use std::path::PathBuf;
use std::sync::OnceLock;

use cbq::calib::{self, corpus};
use cbq::config::{BitSpec, PreprocMethod, QuantJob, RoundingMode};
use cbq::coordinator::Pipeline;
use cbq::runtime::{self, synth, Artifacts, Backend, Bindings};
use cbq::tensor::{io, Tensor};

/// Artifacts directory shared by every test in this binary: the real one
/// when discoverable, else synthetic artifacts generated once per process.
fn artifacts_dir() -> &'static PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        if let Ok(art) = Artifacts::discover() {
            return art.dir;
        }
        let dir = std::env::temp_dir().join(format!("cbq_synth_integration_{}", std::process::id()));
        synth::generate(&dir, &synth::SynthSpec::tiny()).expect("synthetic artifact generation");
        dir
    })
}

fn setup() -> (Artifacts, Box<dyn Backend>) {
    let art = Artifacts::load(artifacts_dir()).expect("loading artifacts");
    let rt = runtime::create_selected(&art, None).expect("backend construction");
    (art, rt)
}

/// The smallest trained config: `t` in `make artifacts` builds, else the
/// synthetic sole config.
fn model(art: &Artifacts) -> String {
    art.model_or_default("t").to_string()
}

/// Are these the fully-trained `make artifacts` models? The quality bars
/// below (paper-shaped wins) only hold for those; the short-schedule
/// synthetic models get structural + "not worse" assertions instead.
fn trained_artifacts(art: &Artifacts) -> bool {
    art.dir.join("test_ref_t.bin").exists()
}

fn close(a: &[f32], b: &[f32], atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst <= atol, "{what}: max abs err {worst} > {atol}");
}

// ---------------------------------------------------------------------------
// corpus + (optional) cross-language parity
// ---------------------------------------------------------------------------

#[test]
fn corpus_matches_reference_file() {
    let (art, _rt) = setup();
    let refs = art.corpus_ref().unwrap();
    for (style, want) in [(corpus::Style::C4, &refs["c4"]), (corpus::Style::Wiki, &refs["wiki"])] {
        let got = corpus::generate(style, 42, want.len());
        assert_eq!(&got, want, "corpus {style:?} diverges from corpus_ref.json");
    }
}

#[test]
fn fp_forward_matches_python_reference() {
    // parity tensors exist only in `make artifacts` builds (JAX writes them)
    let (art, rt) = setup();
    let ref_path = art.dir.join("test_ref_t.bin");
    if !ref_path.exists() {
        eprintln!("skipping python-parity check: {ref_path:?} absent (synthetic artifacts)");
        return;
    }
    let refs = io::read_tensors(ref_path).unwrap();
    let pipe = Pipeline::new(&art, rt.as_ref(), "t").unwrap();

    let batch = &calib::eval_stream(corpus::Style::C4, 1, 4, pipe.cfg.seq)[0];
    let x = batch.inputs();
    let x_want: Vec<i32> = refs["tokens_x"].data.iter().map(|&v| v as i32).collect();
    assert_eq!(x.data, x_want, "eval tokens diverge");

    let h0 = pipe.fp.embed_tokens(&x.data, 4, pipe.cfg.seq);
    close(&h0.data, &refs["h_embed"].data, 1e-6, "embedding");

    let fp = pipe.fp_model();
    let h = pipe.forward_hidden(&fp, &x).unwrap();
    close(&h.data, &refs["h_final"].data, 2e-3, "fp hidden");

    let mask = Tensor::full(&[4, pipe.cfg.seq], 1.0);
    let (nll, _) = pipe.lm_nll(&fp, &x, &batch.targets(), &mask).unwrap();
    close(&nll, &refs["nll_per_seq"].data, 0.5, "nll per sequence");
}

#[test]
fn fp_perplexity_in_sane_range() {
    let (art, rt) = setup();
    let m = model(&art);
    let pipe = Pipeline::new(&art, rt.as_ref(), &m).unwrap();
    let fp = pipe.fp_model();
    let ppl = pipe.perplexity(&fp, corpus::Style::C4, 4).unwrap();
    // pretraining (python or synth host-side) must beat the uniform
    // baseline (ppl == vocab) by a clear margin
    let vocab = pipe.cfg.vocab as f64;
    assert!(
        ppl.is_finite() && ppl > 1.0 && ppl < vocab * 0.9,
        "FP ppl {ppl} not in (1, {:.0}) — eval path or pretraining broken",
        vocab * 0.9
    );
}

// ---------------------------------------------------------------------------
// backend contract
// ---------------------------------------------------------------------------

#[test]
fn backend_rejects_missing_and_misshapen_inputs() {
    let (art, rt) = setup();
    let m = model(&art);
    let lm = format!("lm_eval_{m}");
    let err = rt.run(&lm, Bindings::new().inner()).unwrap_err();
    assert!(format!("{err:#}").contains("missing input"), "got: {err:#}");

    let pipe = Pipeline::new(&art, rt.as_ref(), &m).unwrap();
    let mut b = Bindings::new();
    b.set("h", Tensor::zeros(&[1, 2, 3])); // wrong shape
    b.set("final_norm", pipe.fp.final_norm.clone());
    b.set("head", pipe.fp.head.clone());
    let err = rt.run(&lm, b.inner()).unwrap_err();
    assert!(format!("{err:#}").contains("shape mismatch"), "got: {err:#}");
}

#[test]
fn unknown_executable_is_error() {
    let (_art, rt) = setup();
    assert!(rt.run("nope", Bindings::new().inner()).is_err());
}

// ---------------------------------------------------------------------------
// quantization behaviour (live on both backends)
// ---------------------------------------------------------------------------

fn quick_job(mut job: QuantJob) -> QuantJob {
    job.calib_sequences = 8;
    job.epochs = 1;
    job
}

#[test]
fn rtn_w8_is_near_lossless_and_w2_degrades() {
    let (art, rt) = setup();
    let m = model(&art);
    let mut pipe = Pipeline::new(&art, rt.as_ref(), &m).unwrap();
    let fp = pipe.fp_model();
    let fp_ppl = pipe.perplexity(&fp, corpus::Style::C4, 4).unwrap();

    let (m8, _) = pipe.run(&quick_job(QuantJob::rtn(BitSpec::new(8, 16)))).unwrap();
    let p8 = pipe.perplexity(&m8, corpus::Style::C4, 4).unwrap();
    assert!((p8 - fp_ppl).abs() / fp_ppl < 0.05, "W8 rtn ppl {p8} vs fp {fp_ppl}");

    let (m2, _) = pipe.run(&quick_job(QuantJob::rtn(BitSpec::w2a16()))).unwrap();
    let p2 = pipe.perplexity(&m2, corpus::Style::C4, 4).unwrap();
    assert!(
        p2 > p8 && p2 > fp_ppl * 1.1,
        "W2 rtn should degrade clearly: W2 {p2} vs W8 {p8} vs FP {fp_ppl}"
    );
}

#[test]
fn cbq_w2_not_worse_than_rtn_w2() {
    let (art, rt) = setup();
    let m = model(&art);
    let mut pipe = Pipeline::new(&art, rt.as_ref(), &m).unwrap();
    let (rtn, _) = pipe.run(&quick_job(QuantJob::rtn(BitSpec::w2a16()))).unwrap();
    let p_rtn = pipe.perplexity(&rtn, corpus::Style::C4, 4).unwrap();

    let mut job = quick_job(QuantJob::cbq(BitSpec::w2a16()));
    job.epochs = 2;
    job.calib_sequences = 16;
    let (cbq, summary) = pipe.run(&job).unwrap();
    let p_cbq = pipe.perplexity(&cbq, corpus::Style::C4, 4).unwrap();
    assert!(p_cbq.is_finite() && summary.window_losses.iter().all(|l| l.is_finite()));
    if trained_artifacts(&art) {
        // the paper-shaped win must hold on the trained reference models
        assert!(
            p_cbq < p_rtn,
            "CBQ W2 ({p_cbq}) must beat RTN W2 ({p_rtn}); window losses {:?}",
            summary.window_losses
        );
    } else {
        // short-schedule synthetic models: reconstruction starts at the
        // RTN operating point, so assert "not (much) worse"
        assert!(
            p_cbq < p_rtn * 1.15,
            "CBQ W2 ({p_cbq}) much worse than RTN W2 ({p_rtn}); window losses {:?}",
            summary.window_losses
        );
    }
}

#[test]
fn gptq_runs_and_tracks_rtn_at_w2() {
    let (art, rt) = setup();
    let m = model(&art);
    let mut pipe = Pipeline::new(&art, rt.as_ref(), &m).unwrap();
    let (rtn, _) = pipe.run(&quick_job(QuantJob::rtn(BitSpec::w2a16()))).unwrap();
    let p_rtn = pipe.perplexity(&rtn, corpus::Style::C4, 4).unwrap();
    let (g, _) = pipe.run(&quick_job(QuantJob::gptq(BitSpec::w2a16()))).unwrap();
    let p_g = pipe.perplexity(&g, corpus::Style::C4, 4).unwrap();
    assert!(p_g.is_finite() && p_g < p_rtn * 1.10, "GPTQ W2 {p_g} should track RTN {p_rtn}");
}

#[test]
fn cbd_window_losses_are_finite() {
    let (art, rt) = setup();
    let m = model(&art);
    let mut pipe = Pipeline::new(&art, rt.as_ref(), &m).unwrap();
    let mut job = quick_job(QuantJob::cbq(BitSpec::w4a4()));
    job.window = 2;
    job.overlap = 1;
    let (_m, summary) = pipe.run(&job).unwrap();
    assert!(!summary.window_losses.is_empty());
    assert!(summary.window_losses.iter().all(|l| l.is_finite()));
}

#[test]
fn star_override_only_changes_marked_layers() {
    let (art, rt) = setup();
    let m = model(&art);
    let pipe = Pipeline::new(&art, rt.as_ref(), &m).unwrap();
    let bits = BitSpec::w2a16_star(pipe.cfg.n_layers);
    let qs = pipe.init_qstate(&pipe.fp, &bits, 5, RoundingMode::Nearest);
    assert_eq!(qs[0]["wdown"].bits_w, 4);
    assert_eq!(qs[0]["wq"].bits_w, 2);
    let last = pipe.cfg.n_layers - 1;
    assert_eq!(qs[last]["wdown"].bits_w, 4);
    if pipe.cfg.n_layers > 2 {
        assert_eq!(qs[1]["wdown"].bits_w, 2);
    }
}

#[test]
fn preproc_cfp_reports_work_on_outlier_injected_model() {
    let (art, rt) = setup();
    let m = model(&art);
    let mut pipe = Pipeline::new(&art, rt.as_ref(), &m).unwrap();
    let mut job = quick_job(QuantJob::rtn(BitSpec::w4a4()));
    job.preproc = PreprocMethod::CfpFull;
    let (_m, summary) = pipe.run(&job).unwrap();
    // both the python and the synth build inject activation outlier
    // channels; CFP must find some
    assert!(
        summary.preproc_channels_scaled > 0,
        "CFP found no outlier channels on an outlier-injected model"
    );
}

// ---------------------------------------------------------------------------
// pinned-path equivalence + eval determinism
// ---------------------------------------------------------------------------

#[test]
fn pinned_execution_matches_full_upload() {
    use std::collections::BTreeMap;
    let (art, rt) = setup();
    let m = model(&art);
    let pipe = Pipeline::new(&art, rt.as_ref(), &m).unwrap();
    let qs = pipe.init_qstate(&pipe.fp, &BitSpec::w4a4(), 5, RoundingMode::Lora);
    let batch = &calib::calibration(pipe.cfg.batch, pipe.cfg.batch, pipe.cfg.seq)[0];
    let h0 = pipe.fp.embed_tokens(&batch.inputs().data, pipe.cfg.batch, pipe.cfg.seq);
    let mut b = Bindings::new();
    b.set("h_in", h0.clone());
    b.set("target", Tensor::zeros(&h0.dims));
    Pipeline::bind_block_weights(&mut b, 0, &pipe.fp.blocks[0]);
    Pipeline::bind_qblock(&mut b, 0, &qs[0], 7.0, 1.0, 1.0, false);
    Pipeline::bind_globals(&mut b, 1.0, 10.0, 0.01, 1.0, 1.0);

    let exec = format!("win_fwd_w1_{m}");
    let full = rt.run(&exec, b.inner()).unwrap();
    let statics: BTreeMap<String, cbq::runtime::Value> = b
        .inner()
        .iter()
        .filter(|(k, _)| k.starts_with("blocks."))
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect();
    let pinned = rt.pin(&exec, &statics).unwrap();
    let dynamic: BTreeMap<String, cbq::runtime::Value> = b
        .inner()
        .iter()
        .filter(|(k, _)| !statics.contains_key(*k))
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect();
    let pin = rt.run_pinned(&pinned, &dynamic).unwrap();
    assert_eq!(full["h_out"].dims, pin["h_out"].dims);
    for (a, b) in full["h_out"].data.iter().zip(&pin["h_out"].data) {
        assert_eq!(a, b, "pinned path must be bit-identical");
    }
}

#[test]
fn perplexity_is_deterministic() {
    let (art, rt) = setup();
    let m = model(&art);
    let pipe = Pipeline::new(&art, rt.as_ref(), &m).unwrap();
    let fp = pipe.fp_model();
    let a = pipe.perplexity(&fp, corpus::Style::C4, 2).unwrap();
    let b = pipe.perplexity(&fp, corpus::Style::C4, 2).unwrap();
    assert_eq!(a, b);
}

#[test]
fn zero_shot_suite_is_well_formed() {
    let (art, rt) = setup();
    let m = model(&art);
    let pipe = Pipeline::new(&art, rt.as_ref(), &m).unwrap();
    let fp = pipe.fp_model();
    let r = pipe.zero_shot(&fp, 8).unwrap();
    assert_eq!(r.accuracy.len(), 4, "all four choice tasks must report");
    for (task, acc) in &r.accuracy {
        assert!((0.0..=1.0).contains(acc), "{task} accuracy {acc} out of range");
    }
    assert!(r.mrr > 0.0 && r.mrr <= 1.0, "MRR {} out of range", r.mrr);
    assert!(r.recall1 <= r.recall2, "R@1 {} > R@2 {}", r.recall1, r.recall2);
    if trained_artifacts(&art) {
        // quality bars for the trained reference models (the old suite's
        // assertions, kept behind the trained gate)
        let r16 = pipe.zero_shot(&fp, 16).unwrap();
        assert!(
            r16.accuracy["TopicMatch"] > 0.5,
            "FP TopicMatch accuracy {} at chance — task or model broken",
            r16.accuracy["TopicMatch"]
        );
        assert!(r16.mrr > 0.25, "ranking MRR {} below random", r16.mrr);
    }
}

#[test]
fn cbq_star_recovers_over_cbq_at_w2_on_trained_models() {
    let (art, rt) = setup();
    if !trained_artifacts(&art) {
        eprintln!("skipping CBQ* quality bar: needs trained `make artifacts` models");
        return;
    }
    let m = model(&art);
    let mut pipe = Pipeline::new(&art, rt.as_ref(), &m).unwrap();
    let mut base = quick_job(QuantJob::cbq(BitSpec::w2a16()));
    base.epochs = 4;
    base.calib_sequences = 16;
    let mut star = base.clone();
    star.bits = BitSpec::w2a16_star(pipe.cfg.n_layers);
    let (m1, _) = pipe.run(&base).unwrap();
    let (m2, _) = pipe.run(&star).unwrap();
    let p1 = pipe.perplexity(&m1, corpus::Style::C4, 4).unwrap();
    let p2 = pipe.perplexity(&m2, corpus::Style::C4, 4).unwrap();
    // CBQ* promotes the most damaging layers to 4 bits; it must not hurt
    assert!(p2 < p1 * 1.05, "CBQ* ({p2}) should be <= CBQ ({p1})");
}

#[test]
fn dense_adaround_path_runs() {
    let (art, rt) = setup();
    let m = model(&art);
    let mut pipe = Pipeline::new(&art, rt.as_ref(), &m).unwrap();
    let mut job = quick_job(QuantJob::cbq(BitSpec::w4a4()));
    job.rounding = RoundingMode::DenseAdaRound;
    job.window = 2; // dense artifact exported at w=2
    job.overlap = 1;
    let (qm, s) = pipe.run(&job).unwrap();
    assert!(s.window_losses.iter().all(|l| l.is_finite()));
    let ppl = pipe.perplexity(&qm, corpus::Style::C4, 2).unwrap();
    assert!(ppl.is_finite() && ppl < 1e4);
}
