//! Native-backend parity and gradient tests over synthetic artifacts:
//! window-chain composition, bit-determinism, lm_eval vs a test-local
//! reference, `win_grad_*` gradients against finite differences on the
//! smooth (LoRA) path, and an export -> registry -> serve-engine pass.
//!
//! Everything here is host-only: `cbq synth` artifacts + the native CPU
//! backend, no PJRT and no HLO artifacts.

#![allow(clippy::too_many_arguments)]

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use cbq::calib;
use cbq::config::{BitSpec, QuantJob, RoundingMode};
use cbq::coordinator::qstate::Adam;
use cbq::coordinator::Pipeline;
use cbq::runtime::{synth, Artifacts, Backend, Bindings, NativeBackend};
use cbq::serve::{batcher, Batcher, ModelRegistry, ServeEngine};
use cbq::tensor::Tensor;

fn artifacts_dir() -> &'static PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("cbq_synth_backend_{}", std::process::id()));
        let mut spec = synth::SynthSpec::tiny();
        // gradient tests don't need a well-trained model; keep setup fast
        spec.pretrain_steps = 60;
        synth::generate(&dir, &spec).expect("synthetic artifact generation");
        dir
    })
}

fn setup() -> (Artifacts, NativeBackend) {
    let art = Artifacts::load(artifacts_dir()).expect("loading artifacts");
    let rt = NativeBackend::new(&art).expect("native backend");
    (art, rt)
}

/// Deterministic pseudo-random fill for test tensors.
fn fill(t: &mut Tensor, seed: u64, scale: f32) {
    let mut rng = cbq::calib::corpus::XorShift64Star::new(seed);
    for v in t.data.iter_mut() {
        let u = (rng.next_u64() >> 40) as f32 / (1u64 << 24) as f32;
        *v = (u - 0.5) * 2.0 * scale;
    }
}

/// Bindings for a window executable over blocks `[0, w)` of the FP model.
fn window_bindings(
    pipe: &Pipeline,
    qs: &[BTreeMap<String, cbq::coordinator::LinearQ>],
    w: usize,
    h_in: &Tensor,
    target: &Tensor,
    qmax_a: f32,
    w_en: f32,
    a_en: f32,
    use_lora: f32,
    gamma_c: f32,
) -> Bindings {
    let mut b = Bindings::new();
    b.set("h_in", h_in.clone());
    b.set("target", target.clone());
    for j in 0..w {
        Pipeline::bind_block_weights(&mut b, j, &pipe.fp.blocks[j]);
        Pipeline::bind_qblock(&mut b, j, &qs[j], qmax_a, w_en, a_en, false);
    }
    Pipeline::bind_globals(&mut b, use_lora, 2.0, gamma_c, 1.0, 1.0);
    b
}

fn embed_batch(pipe: &Pipeline) -> Tensor {
    let batch = &calib::calibration(pipe.cfg.batch, pipe.cfg.batch, pipe.cfg.seq)[0];
    pipe.fp.embed_tokens(&batch.inputs().data, pipe.cfg.batch, pipe.cfg.seq)
}

#[test]
fn window_chain_composes_bitwise() {
    // win_fwd_w2 must equal two win_fwd_w1 dispatches bit-for-bit: the
    // native interpreter runs the identical arithmetic either way
    let (art, rt) = setup();
    let m = art.default_model().to_string();
    let pipe = Pipeline::new(&art, &rt, &m).unwrap();
    let qs = pipe.init_qstate(&pipe.fp, &BitSpec::w4a16(), 5, RoundingMode::Lora);
    let h0 = embed_batch(&pipe);
    let zeros = Tensor::zeros(&h0.dims);

    let b2 = window_bindings(&pipe, &qs, 2, &h0, &zeros, 32767.0, 1.0, 0.0, 1.0, 0.0);
    let out2 = rt.run(&format!("win_fwd_w2_{m}"), b2.inner()).unwrap();

    let b1a = window_bindings(&pipe, &qs[0..1], 1, &h0, &zeros, 32767.0, 1.0, 0.0, 1.0, 0.0);
    let mid = rt.run(&format!("win_fwd_w1_{m}"), b1a.inner()).unwrap()["h_out"].clone();
    let mut b1b = Bindings::new();
    b1b.set("h_in", mid);
    b1b.set("target", zeros.clone());
    Pipeline::bind_block_weights(&mut b1b, 0, &pipe.fp.blocks[1]);
    Pipeline::bind_qblock(&mut b1b, 0, &qs[1], 32767.0, 1.0, 0.0, false);
    Pipeline::bind_globals(&mut b1b, 1.0, 2.0, 0.0, 1.0, 1.0);
    let fin = rt.run(&format!("win_fwd_w1_{m}"), b1b.inner()).unwrap();

    assert_eq!(out2["h_out"].dims, fin["h_out"].dims);
    for (a, b) in out2["h_out"].data.iter().zip(&fin["h_out"].data) {
        assert_eq!(a, b, "w2 chain != w1+w1 chain");
    }
}

#[test]
fn forward_is_deterministic_across_runs() {
    let (art, rt) = setup();
    let m = art.default_model().to_string();
    let pipe = Pipeline::new(&art, &rt, &m).unwrap();
    let qs = pipe.init_qstate(&pipe.fp, &BitSpec::w4a4(), 5, RoundingMode::Lora);
    let h0 = embed_batch(&pipe);
    let zeros = Tensor::zeros(&h0.dims);
    let b = window_bindings(&pipe, &qs, 2, &h0, &zeros, 7.0, 1.0, 1.0, 1.0, 0.01);
    let exec = format!("win_fwd_w2_{m}");
    let o1 = rt.run(&exec, b.inner()).unwrap();
    let o2 = rt.run(&exec, b.inner()).unwrap();
    assert_eq!(o1["h_out"].data, o2["h_out"].data, "thread pool broke determinism");
    assert_eq!(o1["loss"].item(), o2["loss"].item());
}

#[test]
fn lm_eval_matches_reference_computation() {
    let (art, rt) = setup();
    let m = art.default_model().to_string();
    let pipe = Pipeline::new(&art, &rt, &m).unwrap();
    let (bsz, seq, d, vocab) =
        (pipe.cfg.batch, pipe.cfg.seq, pipe.cfg.d_model, pipe.cfg.vocab);
    let mut h = Tensor::zeros(&[bsz, seq, d]);
    fill(&mut h, 11, 0.8);
    let batch = &calib::eval_stream(calib::corpus::Style::C4, 1, bsz, seq)[0];
    let targets = batch.targets();
    let mask = Tensor::full(&[bsz, seq], 1.0);

    let mut b = Bindings::new();
    b.set("h", h.clone());
    b.set("final_norm", pipe.fp.final_norm.clone());
    b.set("head", pipe.fp.head.clone());
    b.set_i32("targets", targets.clone());
    b.set("mask", mask.clone());
    let out = rt.run(&format!("lm_eval_{m}"), b.inner()).unwrap();

    // reference: plain rmsnorm + matmul + log-softmax in f64
    let g = &pipe.fp.final_norm.data;
    let head = &pipe.fp.head;
    for bi in 0..bsz {
        let mut want_nll = 0.0f64;
        for si in 0..seq {
            let row = &h.data[(bi * seq + si) * d..(bi * seq + si + 1) * d];
            let ms: f64 =
                row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64 + 1e-5;
            let r = 1.0 / ms.sqrt();
            let hn: Vec<f64> =
                row.iter().zip(g).map(|(&v, &gv)| v as f64 * r * gv as f64).collect();
            let mut logits = vec![0.0f64; vocab];
            for (k, &hv) in hn.iter().enumerate() {
                for (j, lv) in logits.iter_mut().enumerate() {
                    *lv += hv * head.at2(k, j) as f64;
                }
            }
            let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let lse = logits.iter().map(|&v| (v - mx).exp()).sum::<f64>().ln() + mx;
            let t = targets.data[bi * seq + si] as usize;
            want_nll += -(logits[t] - lse);
        }
        let got = out["nll"].data[bi] as f64;
        assert!(
            (got - want_nll).abs() < 2e-2 * (1.0 + want_nll.abs()),
            "nll[{bi}]: native {got} vs reference {want_nll}"
        );
        assert_eq!(out["count"].data[bi], seq as f32);
    }
}

#[test]
fn capture_exposes_every_linear_input() {
    let (art, rt) = setup();
    let m = art.default_model().to_string();
    let pipe = Pipeline::new(&art, &rt, &m).unwrap();
    let qs = pipe.init_qstate(&pipe.fp, &BitSpec::w4a16(), 5, RoundingMode::Nearest);
    let h0 = embed_batch(&pipe);
    let zeros = Tensor::zeros(&h0.dims);
    let b = window_bindings(&pipe, &qs, 1, &h0, &zeros, 32767.0, 0.0, 0.0, 0.0, 0.0);
    let out = rt.run(&format!("capture_{m}"), b.inner()).unwrap();
    let rows = pipe.cfg.batch * pipe.cfg.seq;
    for l in cbq::quant::LINEARS {
        let (fan_in, _) = pipe.cfg.linear_shape(l);
        let c = &out[&format!("captures.{l}")];
        assert_eq!(c.dims, vec![rows, fan_in], "capture {l}");
        assert!(c.data.iter().all(|v| v.is_finite()), "capture {l} not finite");
    }
    // wq and wk read the same post-norm hidden: identical captures
    assert_eq!(out["captures.wq"].data, out["captures.wk"].data);
}

/// Directional finite-difference check of the LoRA-path gradients: with
/// w_en=1, a_en=0, use_lora=1, gamma_c=0 the win_grad loss is locally
/// smooth in A2 (floor() is locally constant, rho moves continuously), so
/// (L(a2 + eps d) - L(a2 - eps d)) / 2eps must match <dL/da2, d>.
#[test]
fn win_grad_matches_finite_difference_on_lora_path() {
    let (art, rt) = setup();
    let m = art.default_model().to_string();
    let pipe = Pipeline::new(&art, &rt, &m).unwrap();
    let mut qs = pipe.init_qstate(&pipe.fp, &BitSpec::w4a16(), 5, RoundingMode::Lora);
    // enlarge the LoRA factors so the directional derivative is well above
    // f32 loss noise (init has a2 = 0 and a1 ~ 1e-2)
    for lq in qs[0].values_mut() {
        fill(&mut lq.a1, 21, 0.3);
        fill(&mut lq.a2, 22, 0.3);
    }
    let h0 = embed_batch(&pipe);
    let mut target = Tensor::zeros(&h0.dims);
    fill(&mut target, 23, 0.5);

    let exec_grad = format!("win_grad_w1_{m}");
    let exec_fwd = format!("win_fwd_w1_{m}");
    let b = window_bindings(&pipe, &qs[0..1], 1, &h0, &target, 32767.0, 1.0, 0.0, 1.0, 0.0);
    let out = rt.run(&exec_grad, b.inner()).unwrap();

    let loss_at = |qs_mod: &[BTreeMap<String, cbq::coordinator::LinearQ>]| -> f64 {
        let b = window_bindings(&pipe, qs_mod, 1, &h0, &target, 32767.0, 1.0, 0.0, 1.0, 0.0);
        rt.run(&exec_fwd, b.inner()).unwrap()["loss"].item() as f64
    };
    // gamma_c = 0: the win_grad loss equals the win_fwd reconstruction loss
    let base = loss_at(&qs[0..1]);
    assert!(
        (base - out["loss"].item() as f64).abs() < 1e-5 * (1.0 + base.abs()),
        "win_fwd loss {base} != win_grad loss {}",
        out["loss"].item()
    );

    let eps = 1e-2f32;
    for l in ["wq", "wdown"] {
        let g = &out[&format!("grads.0.{l}.a2")];
        let mut dir = g.clone();
        fill(&mut dir, 31, 1.0);
        let analytic: f64 =
            g.data.iter().zip(&dir.data).map(|(&a, &b)| (a * b) as f64).sum();
        let mut qs_p = qs.clone();
        let mut qs_m = qs.clone();
        {
            let a2 = &mut qs_p[0].get_mut(l).unwrap().a2;
            for (v, &d) in a2.data.iter_mut().zip(&dir.data) {
                *v += eps * d;
            }
            let a2 = &mut qs_m[0].get_mut(l).unwrap().a2;
            for (v, &d) in a2.data.iter_mut().zip(&dir.data) {
                *v -= eps * d;
            }
        }
        let fd = (loss_at(&qs_p[0..1]) - loss_at(&qs_m[0..1])) / (2.0 * eps as f64);
        assert!(
            (fd - analytic).abs() < 0.15 * fd.abs().max(analytic.abs()) + 1e-4,
            "{l}: directional FD {fd} vs analytic {analytic}"
        );
    }
}

#[test]
fn win_grad_descends_reconstruction_loss() {
    // Adam on (a1, a2) with the native gradients must reduce the W2
    // reconstruction loss of a window against the FP target
    let (art, rt) = setup();
    let m = art.default_model().to_string();
    let pipe = Pipeline::new(&art, &rt, &m).unwrap();
    let mut qs = pipe.init_qstate(&pipe.fp, &BitSpec::w2a16(), 5, RoundingMode::Lora);
    let h0 = embed_batch(&pipe);
    // FP target: the same block with quantization disabled
    let bf = window_bindings(&pipe, &qs[0..1], 1, &h0, &Tensor::zeros(&h0.dims), 32767.0, 0.0, 0.0, 0.0, 0.0);
    let target = rt.run(&format!("win_fwd_w1_{m}"), bf.inner()).unwrap()["h_out"].clone();

    let exec = format!("win_grad_w1_{m}");
    let mut adams: BTreeMap<String, (Adam, Adam)> = qs[0]
        .iter()
        .map(|(l, lq)| (l.clone(), (Adam::new(lq.a1.len()), Adam::new(lq.a2.len()))))
        .collect();
    let mut losses = Vec::new();
    for _ in 0..25 {
        let b = window_bindings(&pipe, &qs[0..1], 1, &h0, &target, 32767.0, 1.0, 0.0, 1.0, 0.0);
        let out = rt.run(&exec, b.inner()).unwrap();
        losses.push(out["loss"].item());
        for l in cbq::quant::LINEARS {
            let g1 = &out[&format!("grads.0.{l}.a1")];
            let g2 = &out[&format!("grads.0.{l}.a2")];
            let lq = qs[0].get_mut(l).unwrap();
            let (a1_opt, a2_opt) = adams.get_mut(l).unwrap();
            a1_opt.step(&mut lq.a1.data, &g1.data, 1e-2);
            a2_opt.step(&mut lq.a2.data, &g2.data, 1e-2);
        }
    }
    assert!(losses.iter().all(|l| l.is_finite()), "losses: {losses:?}");
    let (first, last) = (losses[0], *losses.last().unwrap());
    assert!(
        last < first,
        "25 Adam steps on native win_grad gradients did not reduce the loss: {losses:?}"
    );
}

#[test]
fn export_load_serve_end_to_end_on_native() {
    let (art, rt) = setup();
    let m = art.default_model().to_string();
    let mut pipe = Pipeline::new(&art, &rt, &m).unwrap();
    let mut job = QuantJob::rtn(BitSpec::new(4, 16));
    job.calib_sequences = 4;
    let (qm, _) = pipe.run(&job).unwrap();

    let path = std::env::temp_dir().join(format!("cbq_backend_e2e_{}.cbqs", std::process::id()));
    snapshot_roundtrip(&art, &rt, &pipe, &qm, &path, &m);
    std::fs::remove_file(&path).ok();
}

fn snapshot_roundtrip(
    art: &Artifacts,
    rt: &NativeBackend,
    pipe: &Pipeline,
    qm: &cbq::coordinator::QuantizedModel,
    path: &std::path::Path,
    model: &str,
) {
    cbq::snapshot::save(path, &pipe.cfg, qm).unwrap();

    // inspector: header + per-bits accounting agree with the spec
    let info = cbq::snapshot::inspect(path).unwrap();
    assert!(info.checksum_ok);
    assert_eq!(info.meta.cfg.name, model);
    let by_bits = info.packed_by_bits();
    assert_eq!(by_bits.len(), 1, "uniform W4 model: one packed bit width");
    assert_eq!(by_bits[0].0, 4);
    assert_eq!(by_bits[0].1, pipe.cfg.n_layers * cbq::quant::LINEARS.len());
    assert!(info.packed_code_bytes > 0 && info.file_bytes > 0);

    // registry + serve engine + batcher over the native backend
    let mut reg = ModelRegistry::new();
    let snap: Arc<_> = reg.load("e2e", path).unwrap();

    // -- pin sharing: engines must not deep-copy pinned statics ----------
    // (the ROADMAP double-residency item: Arc-backed Value storage makes
    // Backend::pin retain the registry's buffers instead of cloning them)
    let eager = snap.model.eager().expect("registry default load is eager");
    let wq = &eager.params.blocks[0].linears["wq"];
    let wq_ptr = wq.data.as_ptr();
    let rc_before = wq.data.ref_count();
    let engine = ServeEngine::new(rt, art, snap.clone()).unwrap();
    let rc_one = wq.data.ref_count();
    assert!(
        rc_one > rc_before,
        "engine must share the snapshot's weight storage (refcount {rc_before} -> {rc_one})"
    );
    let engine2 = ServeEngine::new(rt, art, snap.clone()).unwrap();
    let rc_two = wq.data.ref_count();
    assert_eq!(
        rc_two - rc_one,
        rc_one - rc_before,
        "second engine must add the same number of *shares*, not copies"
    );
    assert_eq!(wq.data.as_ptr(), wq_ptr, "weight buffer must never move");
    drop(engine2);
    assert_eq!(wq.data.ref_count(), rc_one, "dropping an engine releases its shares");

    let requests = batcher::standard_mix(pipe.cfg.seq, 6, 2, 2);
    let (resp, stats) = Batcher::coalescing(&engine).run(&engine, &requests).unwrap();
    assert_eq!(resp.len(), requests.len());
    assert!(stats.tokens > 0 && stats.tokens_per_s() > 0.0, "no throughput measured");
    for r in &resp {
        if let Some(p) = r.perplexity() {
            assert!(p.is_finite() && p > 1.0, "served ppl {p}");
        }
    }
    // concurrent window dispatch must not change a single answer
    let (resp_par, stats_par) = Batcher::coalescing(&engine)
        .with_dispatch(4)
        .run(&engine, &requests)
        .unwrap();
    assert_eq!(resp_par, resp, "--dispatch 4 changed responses");
    let completed = resp_par
        .iter()
        .filter(|r| !matches!(r, cbq::serve::Response::Rejected))
        .count();
    assert_eq!(completed + stats_par.rejected, requests.len());
    assert_eq!(stats_par.rows, stats.rows);
    assert!(stats_par.peak_in_flight >= 1);
    // bounded admission on the same engine: overload is rejected, visible
    let (resp_cap, stats_cap) = Batcher::coalescing(&engine)
        .with_queue_cap(3)
        .run(&engine, &requests)
        .unwrap();
    assert!(stats_cap.rejected > 0);
    assert_eq!(resp_cap.len(), requests.len());
}

#[test]
fn concurrent_window_dispatch_is_deterministic() {
    // the same window batch executed 8x concurrently on the shared worker
    // pool must produce bitwise-identical outputs (pool chunking is fixed;
    // every output element is written by exactly one task)
    let (art, rt) = setup();
    let m = art.default_model().to_string();
    let pipe = Pipeline::new(&art, &rt, &m).unwrap();
    let qs = pipe.init_qstate(&pipe.fp, &BitSpec::w4a4(), 5, RoundingMode::Lora);
    let h0 = embed_batch(&pipe);
    let zeros = Tensor::zeros(&h0.dims);
    let b = window_bindings(&pipe, &qs, 2, &h0, &zeros, 7.0, 1.0, 1.0, 1.0, 0.01);
    let exec = format!("win_fwd_w2_{m}");
    let reference = rt.run(&exec, b.inner()).unwrap();
    let outs: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| s.spawn(|| rt.run(&exec, b.inner()).unwrap()))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (i, o) in outs.iter().enumerate() {
        assert_eq!(
            o["h_out"].data, reference["h_out"].data,
            "concurrent run {i} diverged bitwise"
        );
        assert_eq!(o["loss"].item(), reference["loss"].item(), "run {i} loss diverged");
    }
}
