//! Serving-engine tests that need no artifacts or PJRT backend: the model
//! registry over real snapshot files, and the batcher end-to-end over the
//! standard request mix with a mock executor (including the batched vs
//! one-by-one dispatch accounting `cbq serve-bench` reports).

use std::collections::BTreeMap;

use cbq::calib::corpus::XorShift64Star;
use cbq::config::{BitSpec, RoundingMode};
use cbq::coordinator::{LinearQ, QuantizedModel};
use cbq::model_state::{BlockParams, ModelParams};
use cbq::quant::{self, LINEARS};
use cbq::runtime::ModelCfg;
use cbq::serve::{batcher, Batcher, ModelRegistry, Request, RequestKind, Response, RowExecutor, RowOut, WorkRow};
use cbq::snapshot;
use cbq::tensor::Tensor;

// -- synthetic snapshot fixture (mirrors tests/snapshot.rs) -----------------

fn rand_tensor(rng: &mut XorShift64Star, dims: &[usize], scale: f32) -> Tensor {
    let n: usize = dims.iter().product();
    let data = (0..n)
        .map(|_| {
            let u = (rng.next_u64() >> 40) as f32 / (1u64 << 24) as f32;
            (u - 0.5) * 2.0 * scale
        })
        .collect();
    Tensor::new(dims.to_vec(), data)
}

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        name: "tiny".into(),
        d_model: 8,
        n_layers: 2,
        n_heads: 2,
        d_ffn: 16,
        vocab: 12,
        seq: 6,
        batch: 4,
        rank_pad: 4,
        head_dim: 4,
        outlier_channels: 0,
        outlier_gain: 0.0,
    }
}

fn snapshot_file(name: &str, seed: u64) -> std::path::PathBuf {
    let cfg = tiny_cfg();
    let mut rng = XorShift64Star::new(seed);
    let bits = BitSpec::new(4, 16);
    let d = cfg.d_model;
    let mut blocks = Vec::new();
    let mut qstate = Vec::new();
    for _ in 0..cfg.n_layers {
        let mut linears = BTreeMap::new();
        let mut lqs = BTreeMap::new();
        for l in LINEARS {
            let (fan_in, fan_out) = cfg.linear_shape(l);
            let w = rand_tensor(&mut rng, &[fan_in, fan_out], 0.5);
            let qmax = cbq::config::qmax(4);
            let s = quant::init_scales(&w, qmax);
            let wq = quant::fake_quant_rtn(&w, &s, qmax);
            let lq = LinearQ::restore(
                &wq,
                s,
                1.0,
                Tensor::zeros(&[fan_in, cfg.rank_pad]),
                Tensor::zeros(&[cfg.rank_pad, fan_out]),
                4,
            );
            linears.insert(l.to_string(), wq);
            lqs.insert(l.to_string(), lq);
        }
        blocks.push(BlockParams {
            attn_norm: rand_tensor(&mut rng, &[d], 1.0),
            mlp_norm: rand_tensor(&mut rng, &[d], 1.0),
            linears,
        });
        qstate.push(lqs);
    }
    let model = QuantizedModel {
        params: ModelParams {
            embed: rand_tensor(&mut rng, &[cfg.vocab, d], 0.2),
            final_norm: rand_tensor(&mut rng, &[d], 1.0),
            head: rand_tensor(&mut rng, &[d, cfg.vocab], 0.2),
            blocks,
        },
        qstate,
        bits,
        rounding: RoundingMode::Nearest,
    };
    let path = std::env::temp_dir().join(name);
    snapshot::save(&path, &cfg, &model).unwrap();
    path
}

// -- registry ---------------------------------------------------------------

#[test]
fn registry_loads_caches_and_evicts() {
    let p = snapshot_file("serve_reg_a.cbqs", 21);
    let mut reg = ModelRegistry::new();
    assert!(reg.is_empty());

    let a = reg.load("w4", &p).unwrap();
    assert_eq!(a.meta.cfg.name, "tiny");
    assert_eq!(a.name, "w4");
    assert!(a.file_bytes > 0);
    assert_eq!(reg.len(), 1);

    // second load of the same name is a cache hit (same Arc)
    let b = reg.load("w4", &p).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    assert_eq!(reg.len(), 1);

    // same name, different path: refused, cache not clobbered
    let p2 = snapshot_file("serve_reg_b.cbqs", 22);
    let err = reg.load("w4", &p2).unwrap_err();
    assert!(format!("{err:#}").contains("refusing"), "{err:#}");
    assert!(std::sync::Arc::ptr_eq(&reg.get("w4").unwrap(), &a));

    // a second name loads alongside
    reg.load("w4-b", &p2).unwrap();
    assert_eq!(reg.names(), vec!["w4".to_string(), "w4-b".to_string()]);

    assert!(reg.get("nope").is_err());
    assert!(reg.evict("w4"));
    assert!(!reg.evict("w4"));
    assert!(reg.get("w4").is_err());

    std::fs::remove_file(p).ok();
    std::fs::remove_file(p2).ok();
}

#[test]
fn registry_propagates_snapshot_validation() {
    let p = snapshot_file("serve_reg_bad.cbqs", 23);
    // flip a bit inside a tensor payload (located via the v2 offset table;
    // a blind mid-file flip could land in CRC-exempt alignment padding)
    let rec = snapshot::inspect(&p)
        .unwrap()
        .tensors
        .iter()
        .find(|t| t.name == "embed")
        .unwrap()
        .clone();
    let mut raw = std::fs::read(&p).unwrap();
    raw[rec.offset as usize + rec.bytes / 2] ^= 0x08;
    std::fs::write(&p, &raw).unwrap();
    let mut reg = ModelRegistry::new();
    let err = reg.load("bad", &p).unwrap_err();
    assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    assert!(reg.is_empty());
    std::fs::remove_file(p).ok();
}

// -- batcher over the standard mix ------------------------------------------

/// Mock executor with a fixed per-dispatch overhead model: every dispatch
/// "costs" one unit regardless of fill, which is exactly why coalescing
/// wins on the fixed-shape executables. Dispatch counting sits behind an
/// atomic because `RowExecutor::execute` takes `&self` (the batcher may
/// run dispatches concurrently).
struct Mock {
    batch: usize,
    seq: usize,
    dispatches: std::sync::atomic::AtomicUsize,
}

impl Mock {
    fn new(batch: usize, seq: usize) -> Self {
        Self { batch, seq, dispatches: std::sync::atomic::AtomicUsize::new(0) }
    }

    fn dispatches(&self) -> usize {
        self.dispatches.load(std::sync::atomic::Ordering::SeqCst)
    }
}

impl RowExecutor for Mock {
    fn batch_rows(&self) -> usize {
        self.batch
    }
    fn seq(&self) -> usize {
        self.seq
    }
    fn execute(&self, rows: &[WorkRow]) -> anyhow::Result<Vec<RowOut>> {
        assert!(!rows.is_empty() && rows.len() <= self.batch);
        self.dispatches.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        Ok(rows
            .iter()
            .map(|r| RowOut {
                nll: r
                    .targets
                    .iter()
                    .zip(&r.mask)
                    .map(|(&t, &m)| (t % 17) as f32 * 0.1 * m)
                    .sum(),
                count: r.mask.iter().sum(),
            })
            .collect())
    }
}

#[test]
fn standard_mix_batched_vs_sequential_same_answers_fewer_dispatches() {
    let seq = 96;
    let requests = batcher::standard_mix(seq, 24, 6, 4);
    assert_eq!(requests.len(), 34);
    let total_rows: usize = requests.iter().map(|r| r.rows.len()).sum();
    assert_eq!(total_rows, 24 + 6 * 2 + 4);

    let mb = Mock::new(4, seq);
    let (resp_b, stats_b) = Batcher::coalescing(&mb).run(&mb, &requests).unwrap();
    let ms = Mock::new(4, seq);
    let (resp_s, stats_s) = Batcher::sequential().run(&ms, &requests).unwrap();
    assert_eq!(mb.dispatches(), stats_b.dispatches);
    assert_eq!(ms.dispatches(), stats_s.dispatches);

    // batched packs 4 rows/dispatch; sequential pays one dispatch per row
    assert_eq!(stats_b.dispatches, total_rows.div_ceil(4));
    assert_eq!(stats_s.dispatches, total_rows);
    assert_eq!(stats_b.rows, total_rows);
    assert_eq!(stats_s.rows, total_rows);
    assert_eq!(stats_b.tokens, total_rows * seq);
    assert!(stats_b.occupancy() > 0.99);
    assert!(stats_s.occupancy() < 0.26);

    // scheduling must not change any answer
    assert_eq!(resp_b.len(), resp_s.len());
    for (a, b) in resp_b.iter().zip(&resp_s) {
        match (a, b) {
            (Response::Ppl { nll: n1, count: c1 }, Response::Ppl { nll: n2, count: c2 }) => {
                assert_eq!(n1, n2);
                assert_eq!(c1, c2);
            }
            (
                Response::Choice { pick: p1, scores: s1, .. },
                Response::Choice { pick: p2, scores: s2, .. },
            ) => {
                assert_eq!(p1, p2);
                assert_eq!(s1, s2);
            }
            (Response::Hidden { tokens: t1 }, Response::Hidden { tokens: t2 }) => {
                assert_eq!(t1, t2)
            }
            _ => panic!("response kinds diverged between schedules"),
        }
    }
}

#[test]
fn ppl_requests_are_deterministic_held_out_segments() {
    let a = batcher::ppl_requests(cbq::calib::corpus::Style::C4, 8, 96);
    let b = batcher::ppl_requests(cbq::calib::corpus::Style::C4, 8, 96);
    assert_eq!(a.len(), 8);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.rows[0].inputs, y.rows[0].inputs);
        assert!(matches!(x.kind, RequestKind::Ppl));
        // full perplexity mask
        assert!(x.rows[0].mask.iter().all(|&m| m == 1.0));
    }
    // wiki stream differs from c4
    let w = batcher::ppl_requests(cbq::calib::corpus::Style::Wiki, 8, 96);
    assert_ne!(a[0].rows[0].inputs, w[0].rows[0].inputs);
}

#[test]
fn choice_requests_mask_prompts_and_keep_candidate_counts() {
    let reqs = batcher::choice_requests(cbq::calib::TaskKind::Perturbed, 5, 96);
    assert_eq!(reqs.len(), 5);
    for r in &reqs {
        let RequestKind::Choice { correct } = &r.kind else {
            panic!("wrong kind")
        };
        assert!(*correct < r.rows.len());
        assert_eq!(r.rows.len(), 2);
        for row in &r.rows {
            // prompt positions masked out, the 16-token continuation scored
            // (prompt_len = 97 - SEGMENT_LEN/2 = 81 => ones at s >= 80)
            assert_eq!(row.mask.iter().filter(|&&m| m == 0.0).count(), 80);
            assert_eq!(row.mask.iter().filter(|&&m| m == 1.0).count(), 16);
        }
    }
}

#[test]
fn empty_request_rows_are_rejected() {
    let m = Mock::new(4, 8);
    let reqs = vec![Request { kind: RequestKind::Ppl, rows: vec![] }];
    assert!(Batcher::coalescing(&m).run(&m, &reqs).is_err());
}

// -- executor error paths ----------------------------------------------------

/// Executor that returns one result too few for every dispatch.
struct WrongCount {
    batch: usize,
    seq: usize,
}

impl RowExecutor for WrongCount {
    fn batch_rows(&self) -> usize {
        self.batch
    }
    fn seq(&self) -> usize {
        self.seq
    }
    fn execute(&self, rows: &[WorkRow]) -> anyhow::Result<Vec<RowOut>> {
        Ok(vec![RowOut::default(); rows.len() - 1])
    }
}

/// Executor that always fails, counting how many dispatches reached it.
struct AlwaysFails {
    batch: usize,
    seq: usize,
    calls: std::sync::atomic::AtomicUsize,
}

impl RowExecutor for AlwaysFails {
    fn batch_rows(&self) -> usize {
        self.batch
    }
    fn seq(&self) -> usize {
        self.seq
    }
    fn execute(&self, _rows: &[WorkRow]) -> anyhow::Result<Vec<RowOut>> {
        self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        anyhow::bail!("executor exploded")
    }
}

fn single_row_requests(n: u32, seq: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let toks: Vec<u32> = (0..seq as u32 + 1).map(|k| (i + k) % 31).collect();
            Request { kind: RequestKind::Ppl, rows: vec![WorkRow::from_tokens(&toks, 0)] }
        })
        .collect()
}

/// A wrong result count must fail the serial and the concurrent dispatch
/// path with the same error — result validation is shared, so the paths
/// cannot drift.
#[test]
fn wrong_result_count_fails_serial_and_concurrent_identically() {
    let seq = 4;
    // 12 single-row requests at batch 4: every chunk has exactly 4 rows,
    // so both schedules produce the same (deterministic) message
    let reqs = single_row_requests(12, seq);

    let m = WrongCount { batch: 4, seq };
    let err_serial = Batcher::coalescing(&m).run(&m, &reqs).unwrap_err();
    let err_concurrent =
        Batcher::coalescing(&m).with_dispatch(4).run(&m, &reqs).unwrap_err();

    let s1 = format!("{err_serial:#}");
    let s2 = format!("{err_concurrent:#}");
    assert!(s1.contains("executor returned 3 results for 4 rows"), "{s1}");
    assert_eq!(s1, s2, "serial and concurrent dispatch must report the same error");
}

/// A failing dispatch must stop the remaining lanes promptly: no hang, no
/// partial `Response::Ok`, and far fewer executor calls than chunks.
#[test]
fn failure_stops_concurrent_lanes_promptly_without_partial_results() {
    let seq = 4;
    let lanes = 4;
    // batch 1 => 40 chunks; every call fails, so each lane can execute at
    // most one chunk before it returns and flags the rest down
    let reqs = single_row_requests(40, seq);
    let m = AlwaysFails { batch: 1, seq, calls: std::sync::atomic::AtomicUsize::new(0) };
    let err = Batcher::coalescing(&m).with_dispatch(lanes).run(&m, &reqs).unwrap_err();
    assert!(format!("{err:#}").contains("exploded"), "{err:#}");
    let calls = m.calls.load(std::sync::atomic::Ordering::SeqCst);
    assert!(
        (1..=lanes).contains(&calls),
        "failed flag must stop lanes promptly: {calls} calls for 40 chunks"
    );
}

/// The serial path fails on the first chunk — exactly one executor call.
#[test]
fn failure_stops_serial_run_on_first_chunk() {
    let seq = 4;
    let reqs = single_row_requests(12, seq);
    let m = AlwaysFails { batch: 4, seq, calls: std::sync::atomic::AtomicUsize::new(0) };
    let err = Batcher::coalescing(&m).run(&m, &reqs).unwrap_err();
    assert!(format!("{err:#}").contains("exploded"), "{err:#}");
    assert_eq!(m.calls.load(std::sync::atomic::Ordering::SeqCst), 1);
}

#[test]
fn dispatch_concurrency_preserves_answers_and_accounting() {
    // the serve test the issue asks for: drive the batcher with
    // --dispatch 4 semantics and check (a) responses identical to serial,
    // (b) completed + rejected == submitted, with and without a queue cap
    let seq = 96;
    let requests = batcher::standard_mix(seq, 24, 6, 4);
    let serial = Mock::new(4, seq);
    let (resp_serial, stats_serial) =
        Batcher::coalescing(&serial).run(&serial, &requests).unwrap();
    let par = Mock::new(4, seq);
    let (resp_par, stats_par) = Batcher::coalescing(&par)
        .with_dispatch(4)
        .run(&par, &requests)
        .unwrap();
    assert_eq!(resp_par, resp_serial, "dispatch 4 changed answers");
    assert_eq!(stats_par.dispatches, stats_serial.dispatches);
    assert_eq!(stats_par.rows, stats_serial.rows);
    assert_eq!(stats_par.dispatch_lanes, 4);
    assert!(stats_par.peak_in_flight >= 1 && stats_par.peak_in_flight <= 4);
    assert!(stats_par.lane_occupancy() <= 1.0 + 1e-9);

    // capped admission under concurrency: every request accounted exactly once
    let capped = Mock::new(4, seq);
    let (resp_cap, stats_cap) = Batcher::coalescing(&capped)
        .with_queue_cap(16)
        .with_dispatch(4)
        .run(&capped, &requests)
        .unwrap();
    let completed = resp_cap.iter().filter(|r| !matches!(r, Response::Rejected)).count();
    assert_eq!(completed + stats_cap.rejected, requests.len());
    assert!(stats_cap.rejected > 0, "cap of 16 rows must reject part of the mix");
    assert_eq!(stats_cap.rows, 16);
}
