//! Small dense linear algebra substrate (f64 for stability) — what GPTQ's
//! Hessian inverse needs: Cholesky factorization, triangular solves, and a
//! damped inverse. Sizes here are fan-in x fan-in (<= 384), so simple O(n^3)
//! loops are more than fast enough and keep the crate dependency-free.

use anyhow::{ensure, Result};

/// Row-major square f64 matrix.
#[derive(Clone, Debug)]
pub struct Mat {
    /// Matrix order.
    pub n: usize,
    /// Row-major elements, `n * n` of them.
    pub data: Vec<f64>,
}

impl Mat {
    /// All-zeros n x n matrix.
    pub fn zeros(n: usize) -> Self {
        Self { n, data: vec![0.0; n * n] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Element `[i, j]`.
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    /// Set element `[i, j]`.
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.n + j] = v;
    }

    /// Add `v` to every diagonal element (damping).
    pub fn add_diag(&mut self, v: f64) {
        for i in 0..self.n {
            self.data[i * self.n + i] += v;
        }
    }

    /// Mean of the diagonal.
    pub fn mean_diag(&self) -> f64 {
        (0..self.n).map(|i| self.at(i, i)).sum::<f64>() / self.n as f64
    }

    /// In-place lower Cholesky: returns L with `L L^T = A`. Fails on
    /// non-positive-definite input.
    pub fn cholesky(&self) -> Result<Mat> {
        let n = self.n;
        let mut l = Mat::zeros(n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = self.at(i, j);
                for k in 0..j {
                    s -= l.at(i, k) * l.at(j, k);
                }
                if i == j {
                    ensure!(s > 0.0, "cholesky: not PD at {i} (pivot {s})");
                    l.set(i, j, s.sqrt());
                } else {
                    l.set(i, j, s / l.at(j, j));
                }
            }
        }
        Ok(l)
    }

    /// `A^{-1}` via Cholesky (A symmetric positive definite).
    pub fn spd_inverse(&self) -> Result<Mat> {
        let l = self.cholesky()?;
        let n = self.n;
        let mut inv = Mat::zeros(n);
        // solve A x = e_j for each basis vector
        for j in 0..n {
            let mut y = vec![0.0f64; n];
            // forward L y = e_j
            for i in 0..n {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..i {
                    s -= l.at(i, k) * y[k];
                }
                y[i] = s / l.at(i, i);
            }
            // backward L^T x = y
            for i in (0..n).rev() {
                let mut s = y[i];
                for k in i + 1..n {
                    s -= l.at(k, i) * inv.at(k, j);
                }
                inv.set(i, j, s / l.at(i, i));
            }
        }
        Ok(inv)
    }

    /// Dense n x n matrix product.
    pub fn matmul(&self, b: &Mat) -> Mat {
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out.data[i * n + j] += a * b.at(k, j);
                }
            }
        }
        out
    }
}

/// Gram matrix `X^T X` accumulated over row-batches of activations
/// (the GPTQ Hessian `H = 2 X X^T` up to a constant that cancels).
pub fn gram_accumulate(h: &mut Mat, x_rows: &[f32], cols: usize) {
    debug_assert_eq!(x_rows.len() % cols, 0);
    for row in x_rows.chunks_exact(cols) {
        for i in 0..cols {
            let xi = row[i] as f64;
            if xi == 0.0 {
                continue;
            }
            for j in 0..cols {
                h.data[i * cols + j] += xi * row[j] as f64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize) -> Mat {
        // A = B B^T + n I with B deterministic
        let mut b = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                b.set(i, j, ((i * 31 + j * 17) % 13) as f64 / 13.0 - 0.4);
            }
        }
        let mut a = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b.at(i, k) * b.at(j, k);
                }
                a.set(i, j, s);
            }
        }
        a.add_diag(n as f64);
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd(8);
        let l = a.cholesky().unwrap();
        for i in 0..8 {
            for j in 0..8 {
                let mut s = 0.0;
                for k in 0..8 {
                    s += l.at(i, k) * l.at(j, k);
                }
                assert!((s - a.at(i, j)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let a = spd(12);
        let inv = a.spd_inverse().unwrap();
        let prod = a.matmul(&inv);
        for i in 0..12 {
            for j in 0..12 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - want).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::eye(3);
        a.set(2, 2, -1.0);
        assert!(a.cholesky().is_err());
    }

    #[test]
    fn gram_matches_manual() {
        let mut h = Mat::zeros(2);
        gram_accumulate(&mut h, &[1.0, 2.0, 3.0, 4.0], 2);
        // rows (1,2),(3,4): X^T X = [[10,14],[14,20]]
        assert_eq!(h.data, vec![10.0, 14.0, 14.0, 20.0]);
    }
}
