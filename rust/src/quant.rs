//! Shared fake-quantization math — bit-exact with the L1 Pallas kernels
//! (python/compile/kernels/ref.py documents the semantics).
//!
//! The Rust side needs its own implementation for (a) the RTN / GPTQ
//! baselines, (b) finalizing CBQ's learned parameters into quantized
//! weights after optimization, and (c) the analytic memory/size accounting
//! the paper's efficiency tables report.

use crate::tensor::Tensor;

/// Scale floor shared by every quantizer (and the snapshot dequant).
pub const EPS: f32 = 1e-8;
/// AdaRound stretch parameters (Eq. 8) — fixed by the paper.
pub const ZETA: f32 = 1.1;
/// Rectified-sigmoid stretch lower bound (AdaRound gamma).
pub const GAMMA: f32 = -0.1;

/// Canonical per-block linear names, in binding order.
pub const LINEARS: [&str; 7] = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"];

/// Per-output-channel symmetric scale init: `max|W_col| / qmax`.
pub fn init_scales(w: &Tensor, qmax: f32) -> Tensor {
    let (_k, n) = (w.rows(), w.cols());
    let mut s = vec![0.0f32; n];
    for j in 0..n {
        let m = w.col_iter(j).fold(0.0f32, |a, v| a.max(v.abs()));
        s[j] = (m / qmax).max(1e-6);
    }
    Tensor::new(vec![n], s)
}

/// Fake-quantize with nearest rounding: `clip(round(W/s), lo, hi) * s`.
pub fn fake_quant_rtn(w: &Tensor, s: &Tensor, qmax: f32) -> Tensor {
    let (k, n) = (w.rows(), w.cols());
    let mut out = vec![0.0f32; k * n];
    let (lo, hi) = (-qmax - 1.0, qmax);
    for i in 0..k {
        for j in 0..n {
            let sc = s.data[j].max(EPS);
            let q = (w.at2(i, j) / sc).round().clamp(lo, hi);
            out[i * n + j] = q * sc;
        }
    }
    Tensor::new(vec![k, n], out)
}

/// The rectified sigmoid h(V) of Eq. 8.
pub fn rect_sigmoid(v: f32) -> f32 {
    let sig = 1.0 / (1.0 + (-v).exp());
    (sig * (ZETA - GAMMA) + GAMMA).clamp(0.0, 1.0)
}

/// Materialize rho = h(A1 @ A2) for a linear (LoRA-Rounding, Eq. 11),
/// with the effective-rank projection already applied to A1/A2.
pub fn lora_rho(a1: &Tensor, a2: &Tensor) -> Tensor {
    a1.matmul(a2).map(rect_sigmoid)
}

/// Hardening dead-zone: a learned rho within this band of 0.5 is treated as
/// "no opinion" and falls back to nearest rounding. LoRA-Rounding starts at
/// rho = 0.5 exactly (A2 = 0, Sec. 3.2); under short calibration schedules
/// individual offsets may have barely moved — hardening those to ceil/floor
/// on the sign of a 1e-3 nudge would randomize rounding and *lose* to RTN.
/// Only offsets the optimizer actually pushed past the band override the
/// nearest-rounding default.
pub const RHO_DEADZONE: f32 = 0.1;

/// Finalize learned quantization: `clip(floor(W/s) + rho_hard, lo, hi) * s`.
pub fn finalize_weights(w: &Tensor, s: &Tensor, rho: Option<&Tensor>, qmax: f32) -> Tensor {
    let (k, n) = (w.rows(), w.cols());
    let mut out = vec![0.0f32; k * n];
    let (lo, hi) = (-qmax - 1.0, qmax);
    for i in 0..k {
        for j in 0..n {
            let sc = s.data[j].max(EPS);
            let v = w.at2(i, j) / sc;
            let nearest = if v - v.floor() >= 0.5 { 1.0 } else { 0.0 };
            let r = match rho {
                Some(r) => {
                    let rv = r.at2(i, j);
                    if (rv - 0.5).abs() <= RHO_DEADZONE {
                        nearest
                    } else if rv > 0.5 {
                        1.0
                    } else {
                        0.0
                    }
                }
                None => nearest,
            };
            let q = (v.floor() + r).clamp(lo, hi);
            out[i * n + j] = q * sc;
        }
    }
    Tensor::new(vec![k, n], out)
}

/// Quantization MSE of a weight matrix under a given scale vector — used by
/// the OMSE pre-processing baseline's scale search.
pub fn quant_mse(w: &Tensor, s: &Tensor, qmax: f32) -> f32 {
    let q = fake_quant_rtn(w, s, qmax);
    let mut e = 0.0f64;
    for (a, b) in w.data.iter().zip(&q.data) {
        let d = (a - b) as f64;
        e += d * d;
    }
    (e / w.data.len() as f64) as f32
}

/// Per-token (row) activation fake-quant — mirrors ref.fake_quant_act.
/// Used by host-side baselines operating on captured activations.
pub fn fake_quant_act(x: &Tensor, alpha: f32, qmax: f32) -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    let mut out = vec![0.0f32; m * k];
    for i in 0..m {
        let row = x.row(i);
        let mx = row.iter().fold(0.0f32, |a, v| a.max(v.abs()));
        let s = (alpha * mx / qmax).max(EPS);
        for (j, &v) in row.iter().enumerate() {
            out[i * k + j] = (v / s).round().clamp(-qmax - 1.0, qmax) * s;
        }
    }
    Tensor::new(vec![m, k], out)
}

/// Learnable-parameter and optimizer-state accounting (paper Tables 3b/9:
/// "GPU memory"): bytes of learnable state per linear for each rounding
/// mode, including Adam moments (2x).
pub fn learnable_bytes(fan_in: usize, fan_out: usize, rank: usize, mode: RoundBytes) -> usize {
    let learnable = match mode {
        RoundBytes::Nearest => fan_out + 1,                      // s_w + alpha
        RoundBytes::Dense => fan_out + 1 + fan_in * fan_out,     // + dense V
        RoundBytes::Lora(r) => fan_out + 1 + r * (fan_in + fan_out),
    };
    let _ = rank;
    learnable * 4 * 3 // value + Adam m + Adam v
}

#[derive(Clone, Copy, Debug)]
/// Whether a rounding offset is applied to weight codes.
pub enum RoundBytes {
    /// Round-to-nearest: no learnable offset state.
    Nearest,
    /// Dense AdaRound: one offset per weight.
    Dense,
    /// LoRA-Rounding at the given rank.
    Lora(usize),
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(k: usize, n: usize, f: impl Fn(usize, usize) -> f32) -> Tensor {
        let mut d = vec![0.0; k * n];
        for i in 0..k {
            for j in 0..n {
                d[i * n + j] = f(i, j);
            }
        }
        Tensor::new(vec![k, n], d)
    }

    #[test]
    fn rtn_grid() {
        let w = t2(4, 2, |i, j| (i as f32 - 1.5) * 0.1 + j as f32 * 0.01);
        let s = Tensor::new(vec![2], vec![0.1, 0.1]);
        let q = fake_quant_rtn(&w, &s, 7.0);
        for v in &q.data {
            let lev = v / 0.1;
            assert!((lev - lev.round()).abs() < 1e-5);
        }
    }

    #[test]
    fn rtn_respects_clip() {
        let w = t2(2, 1, |i, _| if i == 0 { 100.0 } else { -100.0 });
        let s = Tensor::new(vec![1], vec![0.5]);
        let q = fake_quant_rtn(&w, &s, 7.0);
        assert_eq!(q.data[0], 3.5); // 7 * 0.5
        assert_eq!(q.data[1], -4.0); // -8 * 0.5
    }

    #[test]
    fn init_scales_cover_range() {
        let w = t2(3, 2, |i, j| if i == 0 && j == 1 { -7.0 } else { 0.5 });
        let s = init_scales(&w, 7.0);
        assert!((s.data[1] - 1.0).abs() < 1e-6);
        assert!((s.data[0] - 0.5 / 7.0).abs() < 1e-6);
    }

    #[test]
    fn rect_sigmoid_endpoints() {
        assert_eq!(rect_sigmoid(0.0), 0.5);
        assert_eq!(rect_sigmoid(50.0), 1.0);
        assert_eq!(rect_sigmoid(-50.0), 0.0);
    }

    #[test]
    fn finalize_nearest_equals_rtn_without_rho() {
        let w = t2(8, 4, |i, j| ((i * 7 + j * 3) as f32).sin() * 0.3);
        let s = init_scales(&w, 7.0);
        let a = finalize_weights(&w, &s, None, 7.0);
        let b = fake_quant_rtn(&w, &s, 7.0);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }

    #[test]
    fn finalize_mid_rho_falls_back_to_nearest() {
        let w = t2(4, 2, |i, j| ((i + j) as f32) * 0.07 - 0.1);
        let s = init_scales(&w, 7.0);
        let rho = Tensor::full(&[4, 2], 0.5);
        let a = finalize_weights(&w, &s, Some(&rho), 7.0);
        let b = finalize_weights(&w, &s, None, 7.0);
        assert_eq!(a, b);
    }

    #[test]
    fn finalize_hard_rho_moves_grid() {
        let w = Tensor::new(vec![1, 1], vec![0.14]);
        let s = Tensor::new(vec![1], vec![0.1]);
        let up = finalize_weights(&w, &s, Some(&Tensor::full(&[1, 1], 0.9)), 7.0);
        let dn = finalize_weights(&w, &s, Some(&Tensor::full(&[1, 1], 0.1)), 7.0);
        assert!((up.data[0] - 0.2).abs() < 1e-6);
        assert!((dn.data[0] - 0.1).abs() < 1e-6);
    }

    #[test]
    fn lora_bytes_much_smaller_than_dense() {
        let dense = learnable_bytes(4096, 4096, 5, RoundBytes::Dense);
        let lora = learnable_bytes(4096, 4096, 5, RoundBytes::Lora(5));
        assert!(lora * 100 < dense);
    }
}
