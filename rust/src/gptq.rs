//! GPTQ baseline (Frantar et al. 2022): Hessian-aware column-sequential
//! weight quantization with error compensation, driven by the calibration
//! activations captured through the `capture_*` executable.
//!
//! Layout note: our linears are `W[in, out]` with per-*output*-channel
//! scales, so GPTQ walks the *input* index `i`, quantizing the row `W[i, :]`
//! and propagating the compensated error to rows `j > i` via the Cholesky
//! factor of the inverse Hessian `H^{-1}`, `H = X^T X + lambda I`.

use anyhow::Result;

use crate::linalg::{gram_accumulate, Mat};
use crate::quant::{init_scales, EPS};
use crate::tensor::Tensor;

/// Accumulates the per-linear Gram matrix `X^T X` over calibration batches.
pub struct GptqHessian {
    /// Accumulated X^T X Gram matrix (f64).
    pub gram: Mat,
    /// Calibration rows folded in so far.
    pub rows_seen: usize,
}

impl GptqHessian {
    /// Empty accumulator for a `fan_in`-wide linear.
    pub fn new(fan_in: usize) -> Self {
        Self { gram: Mat::zeros(fan_in), rows_seen: 0 }
    }

    /// Fold a captured `[rows, fan_in]` activation matrix in.
    pub fn accumulate(&mut self, x: &Tensor) {
        assert_eq!(x.cols(), self.gram.n);
        gram_accumulate(&mut self.gram, &x.data, x.cols());
        self.rows_seen += x.rows();
    }
}

/// GPTQ-quantize one linear in place. Returns the per-output-channel scales
/// used (callers store them for eval-time bookkeeping).
///
/// `percdamp`-style damping: `lambda = damp * mean(diag(H))` (GPTQ default
/// 0.01) keeps the Cholesky stable on rank-deficient calibration sets.
pub fn gptq_quantize(w: &mut Tensor, hessian: &GptqHessian, qmax: f32, damp: f64) -> Result<Tensor> {
    let k = w.rows();
    let n = w.cols();
    assert_eq!(k, hessian.gram.n);

    let scales = init_scales(w, qmax);
    let (lo, hi) = (-qmax - 1.0, qmax);

    let mut h = hessian.gram.clone();
    // dead inputs (never activated) would make H singular: give them unit
    // curvature so their weights quantize independently.
    for i in 0..k {
        if h.at(i, i) == 0.0 {
            h.set(i, i, 1.0);
        }
    }
    let lambda = damp * h.mean_diag().max(1e-12);
    h.add_diag(lambda);

    // U = chol(H^{-1})^T, upper-triangular: d_i = U[i,i], update row U[i, j>i]
    let hinv = h.spd_inverse()?;
    let l = hinv.cholesky()?;

    let mut err = vec![0.0f32; n];
    for i in 0..k {
        let d = l.at(i, i) as f32; // == U[i,i]
        for c in 0..n {
            let s = scales.data[c].max(EPS);
            let v = w.at2(i, c);
            let q = (v / s).round().clamp(lo, hi) * s;
            w.set2(i, c, q);
            err[c] = (v - q) / d;
        }
        // propagate compensated error to the not-yet-quantized rows
        for j in i + 1..k {
            let f = l.at(j, i) as f32; // == U[i,j]
            if f == 0.0 {
                continue;
            }
            let row = w.row_mut(j);
            for (rv, &e) in row.iter_mut().zip(&err) {
                *rv -= f * e;
            }
        }
    }
    Ok(scales)
}

/// Plain RTN on the same layout — the degenerate GPTQ (no compensation),
/// used both as the Table-1 "RTN" baseline and in unit tests.
pub fn rtn_quantize(w: &mut Tensor, qmax: f32) -> Tensor {
    let scales = init_scales(w, qmax);
    let q = crate::quant::fake_quant_rtn(w, &scales, qmax);
    *w = q;
    scales
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xorshift_data(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s ^= s >> 12;
                s ^= s << 25;
                s ^= s >> 27;
                let u = (s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32;
                (u - 0.5) * 2.0 * scale
            })
            .collect()
    }

    fn setup(k: usize, n: usize, rows: usize) -> (Tensor, GptqHessian, Tensor) {
        let w = Tensor::new(vec![k, n], xorshift_data(k * n, 7, 0.5));
        let x = Tensor::new(vec![rows, k], xorshift_data(rows * k, 99, 1.0));
        let mut h = GptqHessian::new(k);
        h.accumulate(&x);
        (w, h, x)
    }

    fn output_mse(x: &Tensor, w_fp: &Tensor, w_q: &Tensor) -> f32 {
        let y1 = x.matmul(w_fp);
        let y2 = x.matmul(w_q);
        let mut e = 0.0;
        for (a, b) in y1.data.iter().zip(&y2.data) {
            e += (a - b) * (a - b);
        }
        e / y1.data.len() as f32
    }

    #[test]
    fn gptq_beats_rtn_on_output_mse() {
        let (w0, h, x) = setup(24, 16, 256);
        let mut w_rtn = w0.clone();
        rtn_quantize(&mut w_rtn, 1.0); // 2-bit: plenty of error to shuffle
        let mut w_gptq = w0.clone();
        gptq_quantize(&mut w_gptq, &h, 1.0, 0.01).unwrap();
        let e_rtn = output_mse(&x, &w0, &w_rtn);
        let e_gptq = output_mse(&x, &w0, &w_gptq);
        assert!(
            e_gptq < e_rtn,
            "gptq {e_gptq} should beat rtn {e_rtn} on calibration data"
        );
    }

    #[test]
    fn gptq_outputs_on_grid() {
        let (mut w, h, _x) = setup(12, 8, 64);
        let scales = gptq_quantize(&mut w, &h, 7.0, 0.01).unwrap();
        for i in 0..w.rows() {
            for c in 0..w.cols() {
                let lev = w.at2(i, c) / scales.data[c].max(EPS);
                assert!((lev - lev.round()).abs() < 1e-3, "off-grid at {i},{c}: {lev}");
                assert!(lev.round() >= -8.0 && lev.round() <= 7.0);
            }
        }
    }

    #[test]
    fn handles_dead_inputs() {
        let k = 10;
        let mut w = Tensor::new(vec![k, 4], xorshift_data(k * 4, 3, 0.3));
        // activations never touch input 5
        let mut x = Tensor::new(vec![128, k], xorshift_data(128 * k, 11, 1.0));
        for r in 0..128 {
            x.set2(r, 5, 0.0);
        }
        let mut h = GptqHessian::new(k);
        h.accumulate(&x);
        gptq_quantize(&mut w, &h, 7.0, 0.01).unwrap();
        assert!(w.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn high_bits_near_lossless() {
        let (w0, h, x) = setup(16, 8, 128);
        let mut w = w0.clone();
        gptq_quantize(&mut w, &h, 127.0, 0.01).unwrap();
        assert!(output_mse(&x, &w0, &w) < 1e-4);
    }
}
