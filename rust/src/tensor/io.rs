//! CBQW binary tensor container reader/writer — the weight interchange with
//! the Python build path (python/compile/iobin.py documents the layout).

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Result};

use super::Tensor;

const MAGIC: &[u8; 4] = b"CBQW";
const VERSION: u32 = 1;

pub fn read_tensors(path: impl AsRef<Path>) -> Result<BTreeMap<String, Tensor>> {
    let mut r = BufReader::new(File::open(path.as_ref())?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "bad magic {:?}", magic);
    let version = read_u32(&mut r)?;
    ensure!(version == VERSION, "unsupported version {version}");
    let n = read_u32(&mut r)? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        let mut hdr = [0u8; 2];
        r.read_exact(&mut hdr)?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut r)? as usize);
        }
        let count: usize = dims.iter().product::<usize>().max(1);
        let mut raw = vec![0u8; count * 4];
        r.read_exact(&mut raw)?;
        match dtype {
            0 => {
                let data = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                out.insert(name, Tensor::new(dims, data));
            }
            1 => {
                // i32 tensors are converted to f32 on read; none of the
                // weight files currently carry them.
                let data = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f32)
                    .collect();
                out.insert(name, Tensor::new(dims, data));
            }
            d => bail!("unknown dtype {d} for {name}"),
        }
    }
    Ok(out)
}

pub fn write_tensors(
    path: impl AsRef<Path>,
    tensors: &BTreeMap<String, Tensor>,
) -> Result<()> {
    let mut w = BufWriter::new(File::create(path.as_ref())?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        w.write_all(&[0u8, t.dims.len() as u8])?;
        for &d in &t.dims {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        for &v in &t.data {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("a.b".to_string(), Tensor::new(vec![2, 3], vec![1., -2., 3., 4., 5., 6.5]));
        m.insert("scalar".to_string(), Tensor::scalar(7.25));
        let p = std::env::temp_dir().join("cbqw_roundtrip_test.bin");
        write_tensors(&p, &m).unwrap();
        let back = read_tensors(&p).unwrap();
        assert_eq!(back, m);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let p = std::env::temp_dir().join("cbqw_bad_magic.bin");
        std::fs::write(&p, b"NOPE____").unwrap();
        assert!(read_tensors(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
