//! Binary tensor containers.
//!
//! * `CBQW` — the f32 weight interchange with the Python build path
//!   (python/compile/iobin.py documents the layout): [`read_tensors`] /
//!   [`write_tensors`].
//! * The shared *entry codec* ([`Entry`], [`write_entry`], [`read_entry`])
//!   that both CBQW and the `CBQS` quantized-model snapshot container
//!   (crate::snapshot) use. CBQS adds a packed-integer dtype
//!   ([`PackedTensor`]): weight codes stored at their true bit-width
//!   (2/4/8-bit bitpacked), not fake-quant f32.
//!
//! The readers are hardened: duplicate tensor names, truncated payloads,
//! dimension-product overflow, and absurd header values are rejected with
//! errors instead of silent overwrites or panics.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{bail, ensure, Result};

use super::Tensor;

const MAGIC: &[u8; 4] = b"CBQW";
const VERSION: u32 = 1;

/// Header sanity cap (hardening): longest tensor name any CBQ container
/// may carry.
pub const MAX_NAME_LEN: usize = 4096;
/// Header sanity cap (hardening): highest tensor rank any CBQ container
/// may carry.
pub const MAX_NDIM: usize = 8;

/// Entry dtype tag: f32 tensor (payload = `count` little-endian floats).
pub const DTYPE_F32: u8 = 0;
/// Entry dtype tag: legacy i32 tensor (CBQW v1 only; readers convert to
/// f32 exactly as the original CBQW reader did).
pub const DTYPE_I32: u8 = 1;
/// Entry dtype tag: bitpacked integer codes ([`PackedTensor`]).
pub const DTYPE_PACKED: u8 = 2;

// ---------------------------------------------------------------------------
// packed integer tensors
// ---------------------------------------------------------------------------

/// Integer codes bitpacked at their true bit-width `bits` (1..=8),
/// offset-binary: stored code `u = q + 2^(bits-1)` for signed grid code
/// `q in [-2^(bits-1), 2^(bits-1)-1]`. Bits are packed LSB-first into bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedTensor {
    /// Logical tensor shape.
    pub dims: Vec<usize>,
    /// Bits per code (1..=8).
    pub bits: u8,
    /// The bitpacked payload, `byte_len(bits, len())` bytes.
    pub data: Vec<u8>,
}

impl PackedTensor {
    /// Number of logical elements.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    /// Is the element count zero?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Packed payload size for `count` codes at `bits` width.
    pub fn byte_len(bits: u8, count: usize) -> usize {
        (count * bits as usize).div_ceil(8)
    }

    /// Pack signed grid codes. Errors if any code is outside the signed
    /// `bits`-bit range.
    pub fn pack(codes: &[i32], dims: Vec<usize>, bits: u8) -> Result<Self> {
        ensure!((1..=8).contains(&bits), "packed bits must be 1..=8, got {bits}");
        let count: usize = dims.iter().product();
        ensure!(count == codes.len(), "dims {dims:?} != {} codes", codes.len());
        let half = 1i32 << (bits - 1);
        let mut data = vec![0u8; Self::byte_len(bits, count)];
        let mut bitpos = 0usize;
        for &q in codes {
            ensure!(
                (-half..half).contains(&q),
                "code {q} outside signed {bits}-bit range [{}, {}]",
                -half,
                half - 1
            );
            let u = (q + half) as u32;
            for b in 0..bits as usize {
                if (u >> b) & 1 == 1 {
                    data[(bitpos + b) / 8] |= 1 << ((bitpos + b) % 8);
                }
            }
            bitpos += bits as usize;
        }
        Ok(Self { dims, bits, data })
    }

    /// Unpack back to signed grid codes.
    pub fn unpack(&self) -> Vec<i32> {
        let half = 1i32 << (self.bits - 1);
        let count = self.len();
        let mut out = Vec::with_capacity(count);
        let mut bitpos = 0usize;
        for _ in 0..count {
            let mut u = 0u32;
            for b in 0..self.bits as usize {
                let bit = (self.data[(bitpos + b) / 8] >> ((bitpos + b) % 8)) & 1;
                u |= (bit as u32) << b;
            }
            bitpos += self.bits as usize;
            out.push(u as i32 - half);
        }
        out
    }
}

/// One named tensor in a container.
#[derive(Clone, Debug, PartialEq)]
pub enum Entry {
    /// A plain f32 tensor.
    F32(Tensor),
    /// Bitpacked integer codes.
    Packed(PackedTensor),
}

// ---------------------------------------------------------------------------
// byte-level reader (hardened)
// ---------------------------------------------------------------------------

/// Bounds-checked reader over an in-memory buffer: every read is validated
/// against the remaining length, so truncated files fail with an error
/// instead of a panic or a short read.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Start reading at the beginning of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Current read position (offset from the start of the buffer). The
    /// CBQS v1 compatibility path uses this to reconstruct per-tensor
    /// payload offsets that the v1 frame never recorded.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Has the whole buffer been consumed?
    pub fn is_done(&self) -> bool {
        self.pos == self.buf.len()
    }

    /// Consume and return the next `n` bytes (errors on truncation).
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.remaining(),
            "truncated payload: need {n} bytes, {} remain",
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian u32.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian u64 (CBQS v2 offsets/lengths).
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Read a little-endian i32 (CBQS v2 group ids).
    pub fn i32(&mut self) -> Result<i32> {
        Ok(self.u32()? as i32)
    }
}

/// Overflow-checked dimension product.
fn checked_count(dims: &[usize]) -> Result<usize> {
    let mut count = 1usize;
    for &d in dims {
        count = count
            .checked_mul(d)
            .ok_or_else(|| anyhow::anyhow!("dimension product overflow: {dims:?}"))?;
    }
    Ok(count)
}

// ---------------------------------------------------------------------------
// entry codec (shared by CBQW and CBQS)
// ---------------------------------------------------------------------------

/// Append one named entry: `[name_len u32][name][dtype u8][ndim u8]
/// [dims u32...][payload]`. f32 payloads are `count` little-endian floats;
/// packed payloads are `[bits u8][byte_len u32][bytes]`.
pub fn write_entry(out: &mut Vec<u8>, name: &str, entry: &Entry) -> Result<()> {
    ensure!(name.len() <= MAX_NAME_LEN, "tensor name too long ({})", name.len());
    out.extend_from_slice(&(name.len() as u32).to_le_bytes());
    out.extend_from_slice(name.as_bytes());
    match entry {
        Entry::F32(t) => {
            ensure!(t.dims.len() <= MAX_NDIM, "rank {} too high for {name}", t.dims.len());
            ensure!(
                t.dims.iter().all(|&d| d > 0) || t.dims.is_empty(),
                "zero-sized dim in {name}: {:?}",
                t.dims
            );
            out.push(DTYPE_F32);
            out.push(t.dims.len() as u8);
            for &d in &t.dims {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Entry::Packed(p) => {
            ensure!(p.dims.len() <= MAX_NDIM, "rank {} too high for {name}", p.dims.len());
            ensure!((1..=8).contains(&p.bits), "bad packed bits {}", p.bits);
            out.push(DTYPE_PACKED);
            out.push(p.dims.len() as u8);
            for &d in &p.dims {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            out.push(p.bits);
            out.extend_from_slice(&(p.data.len() as u32).to_le_bytes());
            out.extend_from_slice(&p.data);
        }
    }
    Ok(())
}

/// Parse one named entry written by [`write_entry`] (also accepts the CBQW
/// legacy i32 dtype, converting to f32 as the v1 reader did).
pub fn read_entry(r: &mut ByteReader) -> Result<(String, Entry)> {
    let name_len = r.u32()? as usize;
    ensure!(name_len <= MAX_NAME_LEN, "tensor name length {name_len} exceeds cap");
    let name = String::from_utf8(r.take(name_len)?.to_vec())?;
    let dtype = r.u8()?;
    let ndim = r.u8()? as usize;
    ensure!(ndim <= MAX_NDIM, "rank {ndim} exceeds cap for {name}");
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        dims.push(r.u32()? as usize);
    }
    ensure!(dims.iter().all(|&d| d > 0), "zero-sized dim in {name}: {dims:?}");
    let count = checked_count(&dims)?.max(1);
    match dtype {
        DTYPE_F32 | DTYPE_I32 => {
            ensure!(
                count.checked_mul(4).is_some(),
                "payload size overflow for {name}: {dims:?}"
            );
            let raw = r.take(count * 4)?;
            let data: Vec<f32> = if dtype == DTYPE_F32 {
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect()
            } else {
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f32)
                    .collect()
            };
            Ok((name, Entry::F32(Tensor::new(dims, data))))
        }
        DTYPE_PACKED => {
            let bits = r.u8()?;
            ensure!((1..=8).contains(&bits), "bad packed bits {bits} for {name}");
            let byte_len = r.u32()? as usize;
            let want = count
                .checked_mul(bits as usize)
                .map(|b| b.div_ceil(8))
                .ok_or_else(|| anyhow::anyhow!("packed size overflow for {name}: {dims:?}"))?;
            ensure!(
                byte_len == want,
                "packed payload of {name}: {byte_len} bytes, want {want}"
            );
            let data = r.take(byte_len)?.to_vec();
            Ok((name, Entry::Packed(PackedTensor { dims, bits, data })))
        }
        d => bail!("unknown dtype {d} for {name}"),
    }
}

// ---------------------------------------------------------------------------
// CBQW container (f32 weight interchange, format v1 unchanged)
// ---------------------------------------------------------------------------

/// Read a `CBQW` f32 weight container (hardened: duplicates, truncation
/// and overflow are rejected).
pub fn read_tensors(path: impl AsRef<Path>) -> Result<BTreeMap<String, Tensor>> {
    let raw = std::fs::read(path.as_ref())?;
    let mut r = ByteReader::new(&raw);
    let magic = r.take(4)?;
    ensure!(magic == MAGIC, "bad magic {:?}", magic);
    let version = r.u32()?;
    ensure!(version == VERSION, "unsupported version {version}");
    let n = r.u32()? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let (name, entry) = read_entry(&mut r)?;
        let t = match entry {
            Entry::F32(t) => t,
            Entry::Packed(_) => {
                bail!("packed tensor `{name}` in a CBQW container (use snapshot::load)")
            }
        };
        ensure!(out.insert(name.clone(), t).is_none(), "duplicate tensor name `{name}`");
    }
    Ok(out)
}

/// Write a `CBQW` f32 weight container (the Python-interchange format).
pub fn write_tensors(
    path: impl AsRef<Path>,
    tensors: &BTreeMap<String, Tensor>,
) -> Result<()> {
    let mut payload = Vec::new();
    for (name, t) in tensors {
        write_entry(&mut payload, name, &Entry::F32(t.clone()))?;
    }
    let mut w = BufWriter::new(File::create(path.as_ref())?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    w.write_all(&payload)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("a.b".to_string(), Tensor::new(vec![2, 3], vec![1., -2., 3., 4., 5., 6.5]));
        m.insert("scalar".to_string(), Tensor::scalar(7.25));
        let p = std::env::temp_dir().join("cbqw_roundtrip_test.bin");
        write_tensors(&p, &m).unwrap();
        let back = read_tensors(&p).unwrap();
        assert_eq!(back, m);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let p = std::env::temp_dir().join("cbqw_bad_magic.bin");
        std::fs::write(&p, b"NOPE____").unwrap();
        assert!(read_tensors(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_duplicate_names() {
        // hand-build a container with the same name twice
        let t = Tensor::scalar(1.0);
        let mut payload = Vec::new();
        write_entry(&mut payload, "dup", &Entry::F32(t.clone())).unwrap();
        write_entry(&mut payload, "dup", &Entry::F32(t)).unwrap();
        let mut raw = Vec::new();
        raw.extend_from_slice(MAGIC);
        raw.extend_from_slice(&VERSION.to_le_bytes());
        raw.extend_from_slice(&2u32.to_le_bytes());
        raw.extend_from_slice(&payload);
        let p = std::env::temp_dir().join("cbqw_dup_test.bin");
        std::fs::write(&p, &raw).unwrap();
        let err = read_tensors(&p).unwrap_err();
        assert!(format!("{err:#}").contains("duplicate"), "{err:#}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_truncated_payload() {
        let mut m = BTreeMap::new();
        m.insert("w".to_string(), Tensor::new(vec![4, 4], vec![0.5; 16]));
        let p = std::env::temp_dir().join("cbqw_trunc_test.bin");
        write_tensors(&p, &m).unwrap();
        let mut raw = std::fs::read(&p).unwrap();
        raw.truncate(raw.len() - 7);
        std::fs::write(&p, &raw).unwrap();
        let err = read_tensors(&p).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_dim_overflow() {
        // header claims dims [2^31, 2^31, 2^31, 4]: usize product overflows
        let mut raw = Vec::new();
        raw.extend_from_slice(MAGIC);
        raw.extend_from_slice(&VERSION.to_le_bytes());
        raw.extend_from_slice(&1u32.to_le_bytes());
        raw.extend_from_slice(&1u32.to_le_bytes()); // name_len
        raw.push(b'x');
        raw.push(0); // dtype f32
        raw.push(4); // ndim
        for _ in 0..3 {
            raw.extend_from_slice(&0x8000_0000u32.to_le_bytes());
        }
        raw.extend_from_slice(&4u32.to_le_bytes());
        let p = std::env::temp_dir().join("cbqw_overflow_test.bin");
        std::fs::write(&p, &raw).unwrap();
        let err = read_tensors(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("overflow") || msg.contains("truncated"), "{msg}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_absurd_name_len() {
        let mut raw = Vec::new();
        raw.extend_from_slice(MAGIC);
        raw.extend_from_slice(&VERSION.to_le_bytes());
        raw.extend_from_slice(&1u32.to_le_bytes());
        raw.extend_from_slice(&u32::MAX.to_le_bytes()); // name_len
        let p = std::env::temp_dir().join("cbqw_namelen_test.bin");
        std::fs::write(&p, &raw).unwrap();
        assert!(read_tensors(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn pack_unpack_exact() {
        for bits in [2u8, 3, 4, 8] {
            let half = 1i32 << (bits - 1);
            let codes: Vec<i32> = (0..97).map(|i| (i % (2 * half)) - half).collect();
            let p = PackedTensor::pack(&codes, vec![97], bits).unwrap();
            assert_eq!(p.data.len(), PackedTensor::byte_len(bits, 97));
            assert_eq!(p.unpack(), codes, "bits={bits}");
        }
    }

    #[test]
    fn pack_rejects_out_of_range() {
        assert!(PackedTensor::pack(&[8], vec![1], 4).is_err()); // w4 range is [-8, 7]
        assert!(PackedTensor::pack(&[-9], vec![1], 4).is_err());
        assert!(PackedTensor::pack(&[7, -8], vec![2], 4).is_ok());
    }

    #[test]
    fn packed_entry_roundtrip() {
        let p = PackedTensor::pack(&[-2, -1, 0, 1, -2, 1], vec![2, 3], 2).unwrap();
        let mut buf = Vec::new();
        write_entry(&mut buf, "codes", &Entry::Packed(p.clone())).unwrap();
        let mut r = ByteReader::new(&buf);
        let (name, back) = read_entry(&mut r).unwrap();
        assert_eq!(name, "codes");
        assert_eq!(back, Entry::Packed(p));
        assert!(r.is_done());
    }
}
