//! Minimal host-side f32 tensor: the substrate for everything the
//! coordinator computes outside PJRT (CFP statistics, GPTQ, weight
//! finalization, Adam state, Hessian probes).
//!
//! Row-major [`Storage`] + shape. Storage has two representations behind
//! one copy-on-write API:
//!
//! * **Owned** — `Arc<Vec<T>>`: cloning a tensor (and hence a
//!   [`crate::runtime::Value`]) shares the underlying buffer, so pinning
//!   model weights into a backend or binding them into several serve
//!   engines keeps **one** resident copy per process. The first mutation of
//!   a shared buffer clones it (`Arc::make_mut`), preserving value
//!   semantics everywhere else.
//! * **Mapped** — a read-only view into a shared [`mmap::Mmap`] of a CBQS
//!   snapshot file: zero heap bytes, pages fault in on demand, so tensors
//!   of a model larger than RAM can be bound without ever materializing
//!   them. Constructed only through [`Storage::from_mapped`], which
//!   enforces the [`Pod`] element contract, bounds, alignment and host
//!   endianness; the first mutation promotes the view to an owned buffer
//!   (the same copy-on-write rule as shared owned storage).

pub mod io;

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::Arc;

/// Element types whose byte representation can be reinterpreted directly
/// from a little-endian on-disk byte range (no padding, no invalid bit
/// patterns, no drop glue).
///
/// # Safety
/// Implementors must be plain-old-data: `Copy`, with every bit pattern of
/// `size_of::<Self>()` bytes a valid value. The CBQ containers store f32 /
/// i32 / raw bytes little-endian, which matches these types' in-memory
/// layout on little-endian hosts (big-endian hosts never take the mapped
/// path — [`Storage::from_mapped`] refuses and callers decode into owned
/// buffers instead).
pub unsafe trait Pod: Copy {}

// SAFETY: all three are plain-old-data with no invalid bit patterns.
unsafe impl Pod for f32 {}
unsafe impl Pod for i32 {}
unsafe impl Pod for u8 {}

enum Repr<T> {
    /// Heap-owned, shared, copy-on-write.
    Owned(Arc<Vec<T>>),
    /// Borrowed-from-file: `len` elements of `T` starting `offset` bytes
    /// into the shared mapping. Invariant (upheld by `from_mapped`): the
    /// range is in bounds, the pointer is aligned for `T`, `T: Pod`, and
    /// the host is little-endian.
    Mapped { map: Arc<mmap::Mmap>, offset: usize, len: usize },
}

/// Shared, copy-on-write element buffer (owned or memory-mapped).
///
/// * Reads go through `Deref<Target = [T]>` — indexing, slicing, iterators
///   and `&storage`-as-`&[T]` coercion all work as they did on `Vec<T>`.
/// * Writes go through `DerefMut`: unique owned buffers mutate in place (an
///   atomic refcount check), shared owned buffers are cloned first
///   (`Arc::make_mut`), and mapped views are promoted to owned copies.
///   Kernel hot paths operate on locally-owned buffers, so the clone only
///   triggers where sharing semantics actually require it.
pub struct Storage<T = f32>(Repr<T>);

impl<T> Storage<T> {
    /// Wrap an owned buffer.
    pub fn new(data: Vec<T>) -> Self {
        Self(Repr::Owned(Arc::new(data)))
    }

    /// Number of live shares of this buffer (diagnostics / sharing tests).
    /// For mapped storage this counts shares of the underlying file
    /// mapping.
    pub fn ref_count(&self) -> usize {
        match &self.0 {
            Repr::Owned(a) => Arc::strong_count(a),
            Repr::Mapped { map, .. } => Arc::strong_count(map),
        }
    }

    /// Do `a` and `b` view the same memory (same base pointer and length)?
    /// True for clones of one owned allocation and for mapped views of the
    /// same byte range of one mapping.
    pub fn ptr_eq(a: &Self, b: &Self) -> bool {
        std::ptr::eq(a.as_slice().as_ptr(), b.as_slice().as_ptr())
            && a.as_slice().len() == b.as_slice().len()
    }

    /// Is this a borrowed-from-file mapped view (as opposed to an owned
    /// heap buffer)?
    pub fn is_mapped(&self) -> bool {
        matches!(self.0, Repr::Mapped { .. })
    }

    /// Heap bytes this storage keeps resident: `len * size_of::<T>()` for
    /// owned buffers, **0** for mapped views (their pages belong to the
    /// file cache and are reclaimable under memory pressure). The serving
    /// layer's residency accounting sums this over pinned tensors.
    pub fn heap_bytes(&self) -> usize {
        match &self.0 {
            Repr::Owned(a) => a.len() * std::mem::size_of::<T>(),
            Repr::Mapped { .. } => 0,
        }
    }

    fn as_slice(&self) -> &[T] {
        match &self.0 {
            Repr::Owned(a) => a.as_slice(),
            Repr::Mapped { map, offset, len } => {
                let ptr = unsafe { map.as_bytes().as_ptr().add(*offset) };
                // SAFETY: from_mapped checked bounds, alignment, T: Pod and
                // little-endianness; the map Arc keeps the region alive for
                // the lifetime of &self.
                unsafe { std::slice::from_raw_parts(ptr as *const T, *len) }
            }
        }
    }
}

impl<T: Pod> Storage<T> {
    /// Construct a zero-copy view of `elems` elements starting at
    /// `byte_offset` in `map`.
    ///
    /// Returns `None` — callers then decode into an owned buffer instead —
    /// when the range is out of bounds, the resulting pointer is not
    /// aligned for `T`, or the host is big-endian (the on-disk layout is
    /// little-endian; reinterpreting would silently byte-swap values).
    pub fn from_mapped(map: Arc<mmap::Mmap>, byte_offset: usize, elems: usize) -> Option<Self> {
        if cfg!(target_endian = "big") {
            return None;
        }
        let bytes = elems.checked_mul(std::mem::size_of::<T>())?;
        let end = byte_offset.checked_add(bytes)?;
        if end > map.len() {
            return None;
        }
        let ptr = unsafe { map.as_bytes().as_ptr().add(byte_offset) };
        if (ptr as usize) % std::mem::align_of::<T>() != 0 {
            return None;
        }
        Some(Self(Repr::Mapped { map, offset: byte_offset, len: elems }))
    }
}

impl<T> Clone for Storage<T> {
    fn clone(&self) -> Self {
        // refcount bump in both representations, no data copy
        match &self.0 {
            Repr::Owned(a) => Self(Repr::Owned(a.clone())),
            Repr::Mapped { map, offset, len } => {
                Self(Repr::Mapped { map: map.clone(), offset: *offset, len: *len })
            }
        }
    }
}

impl<T> Deref for Storage<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Clone> DerefMut for Storage<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        if let Repr::Mapped { .. } = self.0 {
            // copy-on-write promotion: materialize the mapped view
            let owned: Vec<T> = self.as_slice().to_vec();
            self.0 = Repr::Owned(Arc::new(owned));
        }
        match &mut self.0 {
            Repr::Owned(a) => Arc::make_mut(a).as_mut_slice(),
            Repr::Mapped { .. } => unreachable!("mapped storage promoted above"),
        }
    }
}

impl<T> From<Vec<T>> for Storage<T> {
    fn from(v: Vec<T>) -> Self {
        Self::new(v)
    }
}

impl<T: PartialEq> PartialEq for Storage<T> {
    fn eq(&self, other: &Self) -> bool {
        self[..] == other[..]
    }
}

impl<T: PartialEq> PartialEq<Vec<T>> for Storage<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self[..] == other[..]
    }
}

impl<T: PartialEq> PartialEq<Storage<T>> for Vec<T> {
    fn eq(&self, other: &Storage<T>) -> bool {
        self[..] == other[..]
    }
}

impl<'a, T> IntoIterator for &'a Storage<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.0.iter()
    }
}

impl<'a, T: Clone> IntoIterator for &'a mut Storage<T> {
    type Item = &'a mut T;
    type IntoIter = std::slice::IterMut<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.deref_mut().iter_mut()
    }
}

impl<T: fmt::Debug> fmt::Debug for Storage<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self[..], f)
    }
}

/// Row-major f32 tensor: shape + shared copy-on-write [`Storage`].
#[derive(Clone, PartialEq)]
pub struct Tensor {
    /// Dimension sizes, outermost first (empty = scalar).
    pub dims: Vec<usize>,
    /// The element buffer (owned or memory-mapped; see [`Storage`]).
    pub data: Storage<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.dims, self.data.len())
    }
}

impl Tensor {
    /// Construct from an owned buffer; panics if `dims` and `data` disagree.
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "shape {:?} != data len {}",
            dims,
            data.len()
        );
        Self { dims, data: Storage::new(data) }
    }

    /// Construct sharing an existing buffer (no copy).
    pub fn from_storage(dims: Vec<usize>, data: Storage<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data }
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(dims: &[usize]) -> Self {
        Self { dims: dims.to_vec(), data: Storage::new(vec![0.0; dims.iter().product()]) }
    }

    /// Constant-filled tensor of the given shape.
    pub fn full(dims: &[usize], v: f32) -> Self {
        Self { dims: dims.to_vec(), data: Storage::new(vec![v; dims.iter().product()]) }
    }

    /// 0-d tensor holding one value.
    pub fn scalar(v: f32) -> Self {
        Self { dims: vec![], data: Storage::new(vec![v]) }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Is the element count zero?
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of dimensions (0 for scalars).
    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// First element — for 0-d/1-element tensors (losses, counters).
    pub fn item(&self) -> f32 {
        self.data[0]
    }

    /// Reinterpret the same elements under a new shape (same length).
    pub fn reshape(mut self, dims: Vec<usize>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), self.data.len());
        self.dims = dims;
        self
    }

    /// 2-D accessors ---------------------------------------------------

    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.dims[0]
    }

    /// Column count of a 2-D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.dims[1]
    }

    /// Element `[i, j]` of a 2-D tensor.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.dims[1] + j]
    }

    /// Set element `[i, j]` of a 2-D tensor.
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.dims[1] + j] = v;
    }

    /// Row `i` of a 2-D tensor as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.dims[1];
        &self.data[i * c..(i + 1) * c]
    }

    /// Mutable row `i` of a 2-D tensor.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.dims[1];
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Iterate column `j` of a 2-D tensor.
    pub fn col_iter(&self, j: usize) -> impl Iterator<Item = f32> + '_ {
        let c = self.dims[1];
        self.data.iter().skip(j).step_by(c).copied()
    }

    /// Scale column `j` of a 2-D tensor in place.
    pub fn scale_col(&mut self, j: usize, s: f32) {
        let c = self.dims[1];
        for i in 0..self.dims[0] {
            self.data[i * c + j] *= s;
        }
    }

    /// Scale row `i` of a 2-D tensor in place.
    pub fn scale_row(&mut self, i: usize, s: f32) {
        for v in self.row_mut(i) {
            *v *= s;
        }
    }

    /// whole-tensor ops ------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        let data: Vec<f32> = self.data.iter().map(|&v| f(v)).collect();
        Self { dims: self.dims.clone(), data: Storage::new(data) }
    }

    /// `self[i] = f(self[i], other[i])` elementwise (shapes must match).
    pub fn zip_mut(&mut self, other: &Tensor, f: impl Fn(f32, f32) -> f32) {
        assert_eq!(self.dims, other.dims);
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a = f(*a, b);
        }
    }

    /// Largest absolute element (0 for empty tensors).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Sum of all elements.
    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// Arithmetic mean of all elements (0 for empty tensors).
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() { 0.0 } else { self.sum() / self.data.len() as f32 }
    }

    /// Sum of squared elements.
    pub fn sq_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum()
    }

    /// `A[m,k] @ B[k,n]` — host-side small dense matmul (GPTQ updates,
    /// LoRA V materialization). The hot path never goes through this.
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(b.rank(), 2);
        let (m, k) = (self.dims[0], self.dims[1]);
        let (k2, n) = (b.dims[0], b.dims[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out[i * n..(i + 1) * n];
            for (p, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = b.row(p);
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += a * bv;
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }

    /// Transpose of a 2-D tensor.
    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.dims[0], self.dims[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(vec![n, m], out)
    }
}

/// Int32 tensor (token ids, masks as counts). Kept separate from `Tensor`
/// so dtype mistakes are compile errors, not runtime surprises.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    /// Dimension sizes, outermost first.
    pub dims: Vec<usize>,
    /// The element buffer (owned or memory-mapped; see [`Storage`]).
    pub data: Storage<i32>,
}

impl TensorI32 {
    /// Construct from an owned buffer; panics if `dims` and `data` disagree.
    pub fn new(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data: Storage::new(data) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let i = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose2().transpose2(), a);
    }

    #[test]
    fn row_col_ops() {
        let mut a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        a.scale_col(1, 10.0);
        assert_eq!(a.data, vec![1., 20., 3., 40.]);
        a.scale_row(0, 0.5);
        assert_eq!(a.data, vec![0.5, 10., 3., 40.]);
        assert_eq!(a.col_iter(0).collect::<Vec<_>>(), vec![0.5, 3.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn clone_shares_storage_until_mutated() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let mut b = a.clone();
        assert!(Storage::ptr_eq(&a.data, &b.data), "clone must share the buffer");
        assert_eq!(a.data.ref_count(), 2);
        // first write detaches b (copy-on-write); a is untouched
        b.set2(0, 0, 9.0);
        assert!(!Storage::ptr_eq(&a.data, &b.data));
        assert_eq!(a.at2(0, 0), 1.0);
        assert_eq!(b.at2(0, 0), 9.0);
        assert_eq!(a.data.ref_count(), 1);
    }

    #[test]
    fn unique_storage_mutates_in_place() {
        let mut a = Tensor::new(vec![3], vec![1., 2., 3.]);
        let p = a.data.as_ptr();
        a.data[1] = 7.0;
        assert_eq!(a.data.as_ptr(), p, "unique buffer must not reallocate on write");
        assert_eq!(a.data, vec![1., 7., 3.]);
    }

    #[test]
    fn from_storage_shares() {
        let a = Tensor::new(vec![4], vec![1., 2., 3., 4.]);
        let b = Tensor::from_storage(vec![2, 2], a.data.clone());
        assert!(Storage::ptr_eq(&a.data, &b.data));
        assert_eq!(b.at2(1, 0), 3.0);
    }

    #[test]
    fn mapped_storage_zero_copy_then_cow_promotion() {
        let vals: Vec<f32> = (0..16).map(|i| i as f32 * 0.5 - 3.0).collect();
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let p = std::env::temp_dir()
            .join(format!("cbq_tensor_map_{}.bin", std::process::id()));
        std::fs::write(&p, &bytes).unwrap();
        // mmap may be unavailable (CBQ_NO_MMAP / exotic platform); the
        // mapped representation only exists on the mapped path, so the
        // assertions are conditional on the map coming up.
        if let Ok(m) = mmap::Mmap::open(&p) {
            let map = Arc::new(m);
            let s = Storage::<f32>::from_mapped(map.clone(), 0, 16).unwrap();
            assert!(s.is_mapped());
            assert_eq!(s.heap_bytes(), 0, "mapped views keep no heap bytes");
            assert_eq!(&s[..], &vals[..], "mapped reads must be bit-exact");
            let shared = s.clone();
            assert!(Storage::ptr_eq(&s, &shared), "clones view the same bytes");

            // bounds and alignment violations are refused, not UB
            assert!(Storage::<f32>::from_mapped(map.clone(), 1, 4).is_none());
            assert!(Storage::<f32>::from_mapped(map.clone(), 0, 17).is_none());

            // first write promotes to an owned copy; the file view and any
            // other share are untouched
            let mut t = Tensor::from_storage(vec![4, 4], s);
            t.set2(0, 0, 9.0);
            assert!(!t.data.is_mapped(), "write must promote to owned");
            assert!(t.data.heap_bytes() > 0);
            assert_eq!(t.at2(0, 0), 9.0);
            assert_eq!(shared[0], vals[0], "other shares still read the map");
        }
        std::fs::remove_file(p).ok();
    }
}
