//! Minimal host-side f32 tensor: the substrate for everything the
//! coordinator computes outside PJRT (CFP statistics, GPTQ, weight
//! finalization, Adam state, Hessian probes).
//!
//! Row-major [`Storage`] + shape. Storage is `Arc`-backed with copy-on-
//! write: cloning a tensor (and hence a [`crate::runtime::Value`]) shares
//! the underlying buffer, so pinning model weights into a backend or
//! binding them into several serve engines keeps **one** resident copy per
//! process. The first mutation of a shared buffer clones it
//! (`Arc::make_mut`), preserving value semantics everywhere else.

pub mod io;

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::Arc;

/// Shared, copy-on-write element buffer.
///
/// * Reads go through `Deref<Target = [T]>` — indexing, slicing, iterators
///   and `&storage`-as-`&[T]` coercion all work as they did on `Vec<T>`.
/// * Writes go through `DerefMut`, which calls `Arc::make_mut`: unique
///   buffers mutate in place (an atomic refcount check), shared buffers are
///   cloned first. Kernel hot paths operate on locally-owned buffers, so
///   the clone only triggers where sharing semantics actually require it.
pub struct Storage<T = f32>(Arc<Vec<T>>);

impl<T> Storage<T> {
    pub fn new(data: Vec<T>) -> Self {
        Self(Arc::new(data))
    }

    /// Number of live shares of this buffer (diagnostics / sharing tests).
    pub fn ref_count(&self) -> usize {
        Arc::strong_count(&self.0)
    }

    /// Do `a` and `b` share one allocation?
    pub fn ptr_eq(a: &Self, b: &Self) -> bool {
        Arc::ptr_eq(&a.0, &b.0)
    }
}

impl<T> Clone for Storage<T> {
    fn clone(&self) -> Self {
        Self(self.0.clone()) // refcount bump, no data copy
    }
}

impl<T> Deref for Storage<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.0.as_slice()
    }
}

impl<T: Clone> DerefMut for Storage<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        Arc::make_mut(&mut self.0).as_mut_slice()
    }
}

impl<T> From<Vec<T>> for Storage<T> {
    fn from(v: Vec<T>) -> Self {
        Self::new(v)
    }
}

impl<T: PartialEq> PartialEq for Storage<T> {
    fn eq(&self, other: &Self) -> bool {
        self[..] == other[..]
    }
}

impl<T: PartialEq> PartialEq<Vec<T>> for Storage<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self[..] == other[..]
    }
}

impl<T: PartialEq> PartialEq<Storage<T>> for Vec<T> {
    fn eq(&self, other: &Storage<T>) -> bool {
        self[..] == other[..]
    }
}

impl<'a, T> IntoIterator for &'a Storage<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.0.iter()
    }
}

impl<'a, T: Clone> IntoIterator for &'a mut Storage<T> {
    type Item = &'a mut T;
    type IntoIter = std::slice::IterMut<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.deref_mut().iter_mut()
    }
}

impl<T: fmt::Debug> fmt::Debug for Storage<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self[..], f)
    }
}

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Storage<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.dims, self.data.len())
    }
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "shape {:?} != data len {}",
            dims,
            data.len()
        );
        Self { dims, data: Storage::new(data) }
    }

    /// Construct sharing an existing buffer (no copy).
    pub fn from_storage(dims: Vec<usize>, data: Storage<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data }
    }

    pub fn zeros(dims: &[usize]) -> Self {
        Self { dims: dims.to_vec(), data: Storage::new(vec![0.0; dims.iter().product()]) }
    }

    pub fn full(dims: &[usize], v: f32) -> Self {
        Self { dims: dims.to_vec(), data: Storage::new(vec![v; dims.iter().product()]) }
    }

    pub fn scalar(v: f32) -> Self {
        Self { dims: vec![], data: Storage::new(vec![v]) }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// First element — for 0-d/1-element tensors (losses, counters).
    pub fn item(&self) -> f32 {
        self.data[0]
    }

    pub fn reshape(mut self, dims: Vec<usize>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), self.data.len());
        self.dims = dims;
        self
    }

    /// 2-D accessors ---------------------------------------------------

    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.dims[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.dims[1]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.dims[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.dims[1] + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.dims[1];
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.dims[1];
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col_iter(&self, j: usize) -> impl Iterator<Item = f32> + '_ {
        let c = self.dims[1];
        self.data.iter().skip(j).step_by(c).copied()
    }

    /// Scale column `j` of a 2-D tensor in place.
    pub fn scale_col(&mut self, j: usize, s: f32) {
        let c = self.dims[1];
        for i in 0..self.dims[0] {
            self.data[i * c + j] *= s;
        }
    }

    /// Scale row `i` of a 2-D tensor in place.
    pub fn scale_row(&mut self, i: usize, s: f32) {
        for v in self.row_mut(i) {
            *v *= s;
        }
    }

    /// whole-tensor ops ------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        let data: Vec<f32> = self.data.iter().map(|&v| f(v)).collect();
        Self { dims: self.dims.clone(), data: Storage::new(data) }
    }

    pub fn zip_mut(&mut self, other: &Tensor, f: impl Fn(f32, f32) -> f32) {
        assert_eq!(self.dims, other.dims);
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a = f(*a, b);
        }
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() { 0.0 } else { self.sum() / self.data.len() as f32 }
    }

    pub fn sq_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum()
    }

    /// `A[m,k] @ B[k,n]` — host-side small dense matmul (GPTQ updates,
    /// LoRA V materialization). The hot path never goes through this.
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(b.rank(), 2);
        let (m, k) = (self.dims[0], self.dims[1]);
        let (k2, n) = (b.dims[0], b.dims[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out[i * n..(i + 1) * n];
            for (p, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = b.row(p);
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += a * bv;
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }

    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.dims[0], self.dims[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(vec![n, m], out)
    }
}

/// Int32 tensor (token ids, masks as counts). Kept separate from `Tensor`
/// so dtype mistakes are compile errors, not runtime surprises.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub dims: Vec<usize>,
    pub data: Storage<i32>,
}

impl TensorI32 {
    pub fn new(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data: Storage::new(data) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let i = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose2().transpose2(), a);
    }

    #[test]
    fn row_col_ops() {
        let mut a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        a.scale_col(1, 10.0);
        assert_eq!(a.data, vec![1., 20., 3., 40.]);
        a.scale_row(0, 0.5);
        assert_eq!(a.data, vec![0.5, 10., 3., 40.]);
        assert_eq!(a.col_iter(0).collect::<Vec<_>>(), vec![0.5, 3.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn clone_shares_storage_until_mutated() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let mut b = a.clone();
        assert!(Storage::ptr_eq(&a.data, &b.data), "clone must share the buffer");
        assert_eq!(a.data.ref_count(), 2);
        // first write detaches b (copy-on-write); a is untouched
        b.set2(0, 0, 9.0);
        assert!(!Storage::ptr_eq(&a.data, &b.data));
        assert_eq!(a.at2(0, 0), 1.0);
        assert_eq!(b.at2(0, 0), 9.0);
        assert_eq!(a.data.ref_count(), 1);
    }

    #[test]
    fn unique_storage_mutates_in_place() {
        let mut a = Tensor::new(vec![3], vec![1., 2., 3.]);
        let p = a.data.as_ptr();
        a.data[1] = 7.0;
        assert_eq!(a.data.as_ptr(), p, "unique buffer must not reallocate on write");
        assert_eq!(a.data, vec![1., 7., 3.]);
    }

    #[test]
    fn from_storage_shares() {
        let a = Tensor::new(vec![4], vec![1., 2., 3., 4.]);
        let b = Tensor::from_storage(vec![2, 2], a.data.clone());
        assert!(Storage::ptr_eq(&a.data, &b.data));
        assert_eq!(b.at2(1, 0), 3.0);
    }
}
