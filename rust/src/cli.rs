//! Tiny argument parser (clap is not vendored in this build environment).
//! Grammar: `[global flags] <command> [--key value | --key=value | --switch]*`.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

#[derive(Debug, Default)]
/// Parsed command line: one command plus `--key value` pairs and bare switches.
pub struct Args {
    command: Option<String>,
    kv: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse an argv stream (the grammar in the module docs).
    pub fn parse(argv: impl Iterator<Item = String>) -> Self {
        let mut out = Args::default();
        let items: Vec<String> = argv.collect();
        let mut i = 0;
        while i < items.len() {
            let a = &items[i];
            if let Some(key) = a.strip_prefix("--") {
                // --key=value binds tighter than the lookahead form
                if let Some((k, v)) = key.split_once('=') {
                    out.kv.insert(k.to_string(), v.to_string());
                    i += 1;
                    continue;
                }
                // a flag with a value unless the next token is missing or
                // itself a flag (then it's a switch)
                if i + 1 < items.len() && !items[i + 1].starts_with("--") {
                    out.kv.insert(key.to_string(), items[i + 1].clone());
                    i += 2;
                } else {
                    out.switches.push(key.to_string());
                    i += 1;
                }
            } else {
                if out.command.is_none() {
                    out.command = Some(a.clone());
                }
                i += 1;
            }
        }
        out
    }

    /// The (first) positional command token, if any.
    pub fn command(&self) -> Option<&str> {
        self.command.as_deref()
    }

    /// Value of `--key`, if bound.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    /// Was `--key` given as a bare switch?
    pub fn flag(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    /// `--key` as usize, with a default when absent.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got `{v}`")),
            None => Ok(default),
        }
    }

    /// `--key` as u64, with a default when absent.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got `{v}`")),
            None => Ok(default),
        }
    }

    /// `--key` as f32, with a default when absent.
    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} expects a float, got `{v}`")),
            None => Ok(default),
        }
    }

    /// `--key` as f64, with a default when absent.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} expects a float, got `{v}`")),
            None => Ok(default),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_kv_switches() {
        let a = args("--artifacts /tmp/x quantize --model s --w 4 --star --epochs 3");
        assert_eq!(a.command(), Some("quantize"));
        assert_eq!(a.get("artifacts"), Some("/tmp/x"));
        assert_eq!(a.get("model"), Some("s"));
        assert_eq!(a.get_usize("w", 0).unwrap(), 4);
        assert!(a.flag("star"));
        assert!(!a.flag("missing"));
        assert_eq!(a.get_usize("absent", 7).unwrap(), 7);
    }

    #[test]
    fn trailing_switch() {
        let a = args("eval --verbose");
        assert_eq!(a.command(), Some("eval"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn bad_int_errors() {
        let a = args("x --n abc");
        assert!(a.get_usize("n", 1).is_err());
        assert!(a.get_u64("n", 1).is_err());
    }

    #[test]
    fn f64_values_parse_with_default() {
        let a = args("serve-bench --slo-p99-ms 2.5");
        assert_eq!(a.get_f64("slo-p99-ms", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_f64("absent", 7.5).unwrap(), 7.5);
        assert!(args("x --n abc").get_f64("n", 1.0).is_err());
    }

    #[test]
    fn u64_values_parse_beyond_u32() {
        let a = args("serve-bench --trace-seed 9007199254740993");
        assert_eq!(a.get_u64("trace-seed", 7).unwrap(), 9007199254740993);
        assert_eq!(a.get_u64("absent", 7).unwrap(), 7);
    }

    #[test]
    fn equals_syntax() {
        let a = args("export --model=s --w=4 --out=/tmp/a.cbqs --verbose");
        assert_eq!(a.command(), Some("export"));
        assert_eq!(a.get("model"), Some("s"));
        assert_eq!(a.get_usize("w", 0).unwrap(), 4);
        assert_eq!(a.get("out"), Some("/tmp/a.cbqs"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_value_may_contain_equals_and_dashes() {
        let a = args("serve-bench --json=path=with=equals --snapshot=--odd--");
        assert_eq!(a.command(), Some("serve-bench"));
        assert_eq!(a.get("json"), Some("path=with=equals"));
        assert_eq!(a.get("snapshot"), Some("--odd--"));
    }

    #[test]
    fn mixed_spacing_and_equals() {
        let a = args("quantize --w 2 --a=16 --star --calib=8");
        assert_eq!(a.get_usize("w", 0).unwrap(), 2);
        assert_eq!(a.get_usize("a", 0).unwrap(), 16);
        assert_eq!(a.get_usize("calib", 0).unwrap(), 8);
        assert!(a.flag("star"));
    }
}
