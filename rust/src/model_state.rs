//! Host-side model parameter state: the weights the coordinator owns,
//! pre-processes (CFP / SmoothQuant / OS / truncation), quantizes and feeds
//! to the AOT executables.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::quant::LINEARS;
use crate::runtime::ModelCfg;
use crate::tensor::Tensor;

/// One transformer block's parameters.
#[derive(Clone, Debug)]
pub struct BlockParams {
    /// Pre-attention RMS-norm weights `[d]`.
    pub attn_norm: Tensor,
    /// Pre-MLP RMS-norm weights `[d]`.
    pub mlp_norm: Tensor,
    /// wq, wk, wv, wo, wgate, wup, wdown — keyed by name.
    pub linears: BTreeMap<String, Tensor>,
}

impl BlockParams {
    /// The named linear's weight matrix.
    pub fn linear(&self, name: &str) -> &Tensor {
        &self.linears[name]
    }

    /// Mutable access to the named linear's weight matrix.
    pub fn linear_mut(&mut self, name: &str) -> &mut Tensor {
        self.linears.get_mut(name).unwrap()
    }
}

/// Full model parameters (FP master copy + a mutable working copy during
/// pre-processing/quantization).
#[derive(Clone, Debug)]
pub struct ModelParams {
    /// Token embedding table `[vocab, d]`.
    pub embed: Tensor,
    /// Final RMS-norm weights `[d]`.
    pub final_norm: Tensor,
    /// LM head `[d, vocab]`.
    pub head: Tensor,
    /// Per-block parameters, in layer order.
    pub blocks: Vec<BlockParams>,
}

impl ModelParams {
    /// Assemble from a named tensor map (a CBQW file) per the config.
    pub fn from_tensors(map: &BTreeMap<String, Tensor>, cfg: &ModelCfg) -> Result<Self> {
        let get = |k: &str| -> Result<Tensor> {
            map.get(k).cloned().ok_or_else(|| anyhow!("missing weight {k}"))
        };
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let mut linears = BTreeMap::new();
            for l in LINEARS {
                linears.insert(l.to_string(), get(&format!("blocks.{i}.{l}"))?);
            }
            blocks.push(BlockParams {
                attn_norm: get(&format!("blocks.{i}.attn_norm"))?,
                mlp_norm: get(&format!("blocks.{i}.mlp_norm"))?,
                linears,
            });
        }
        Ok(Self {
            embed: get("embed")?,
            final_norm: get("final_norm")?,
            head: get("head")?,
            blocks,
        })
    }

    /// Embedding lookup — the only model compute the host performs
    /// (a row gather; everything else runs through the HLO executables).
    pub fn embed_tokens(&self, tokens: &[i32], batch: usize, seq: usize) -> Tensor {
        embed_lookup(&self.embed, tokens, batch, seq)
    }
}

/// Row-gather an embedding table into a `[batch, seq, d]` activation. Free
/// function so callers holding a bare embed tensor (the mmap serving path
/// reads it zero-copy from the snapshot, never building a full
/// [`ModelParams`]) share one implementation with [`ModelParams::embed_tokens`].
pub fn embed_lookup(embed: &Tensor, tokens: &[i32], batch: usize, seq: usize) -> Tensor {
    let d = embed.cols();
    let mut data = Vec::with_capacity(batch * seq * d);
    for &t in tokens {
        let row = embed.row(t as usize);
        data.extend_from_slice(row);
    }
    Tensor::new(vec![batch, seq, d], data)
}

/// Per-linear activation statistics from calibration capture: per-input-
/// channel max |X| (the SmoothQuant/OS/CFP-activation feed) plus mean
/// absolute value (diagnostics / Fig. 3).
#[derive(Clone, Debug, Default)]
pub struct ActStats {
    /// block -> linear name -> per-channel max |X_i|
    pub channel_max: Vec<BTreeMap<String, Vec<f32>>>,
    /// block -> linear name -> per-channel mean |X_i|
    pub channel_mean: Vec<BTreeMap<String, Vec<f32>>>,
}

impl ActStats {
    /// Empty stats for `n_blocks` blocks.
    pub fn new(n_blocks: usize) -> Self {
        Self {
            channel_max: vec![BTreeMap::new(); n_blocks],
            channel_mean: vec![BTreeMap::new(); n_blocks],
        }
    }

    /// Accumulate a captured [M, K] activation matrix for (block, linear).
    pub fn accumulate(&mut self, block: usize, linear: &str, x: &Tensor) {
        let k = x.cols();
        let maxv = self.channel_max[block]
            .entry(linear.to_string())
            .or_insert_with(|| vec![0.0; k]);
        let meanv = self.channel_mean[block]
            .entry(linear.to_string())
            .or_insert_with(|| vec![0.0; k]);
        let m = x.rows() as f32;
        for row in x.data.chunks_exact(k) {
            for (j, &v) in row.iter().enumerate() {
                let a = v.abs();
                if a > maxv[j] {
                    maxv[j] = a;
                }
                meanv[j] += a / m;
            }
        }
    }

    /// Per-channel max |X| captured for (block, linear).
    pub fn max_of(&self, block: usize, linear: &str) -> &[f32] {
        &self.channel_max[block][linear]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn act_stats_accumulate() {
        let mut st = ActStats::new(1);
        st.accumulate(0, "wq", &Tensor::new(vec![2, 3], vec![1., -5., 0., 2., 3., -1.]));
        assert_eq!(st.max_of(0, "wq"), &[2.0, 5.0, 1.0]);
        st.accumulate(0, "wq", &Tensor::new(vec![1, 3], vec![-9., 0., 0.]));
        assert_eq!(st.max_of(0, "wq"), &[9.0, 5.0, 1.0]);
    }

    #[test]
    fn embed_gather() {
        let mut map: BTreeMap<String, Tensor> = BTreeMap::new();
        map.insert("embed".into(), Tensor::new(vec![4, 2], vec![0., 1., 2., 3., 4., 5., 6., 7.]));
        // minimal: direct construct
        let mp = ModelParams {
            embed: map["embed"].clone(),
            final_norm: Tensor::zeros(&[2]),
            head: Tensor::zeros(&[2, 4]),
            blocks: vec![],
        };
        let h = mp.embed_tokens(&[3, 0, 1, 2], 2, 2);
        assert_eq!(h.dims, vec![2, 2, 2]);
        assert_eq!(h.data, vec![6., 7., 0., 1., 2., 3., 4., 5.]);
    }
}
