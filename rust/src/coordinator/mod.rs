//! The paper's system contribution, as the L3 coordinator:
//!
//! * **CBD** (Sec. 3.1) — sliding windows of `window` transformer blocks
//!   with `overlap`, jointly optimized against the full-precision model's
//!   block-boundary hidden states;
//! * **LoRA-Rounding** (Sec. 3.2) — low-rank rounding offsets optimized
//!   jointly with the step sizes, with the effective-rank projection and
//!   beta-annealed regularizer schedule;
//! * the **RTN / GPTQ** baselines and the capture-driven pre-processing
//!   stage (CFP & friends) that precede reconstruction.
//!
//! All model compute runs through the executable surface of a
//! [`Backend`] (PJRT-compiled AOT HLO, or the native CPU interpreter);
//! this module owns state, scheduling, optimization and bookkeeping.

pub mod qstate;

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::calib::{self, Batch};
use crate::cfp::apply as preproc;
use crate::config::{Method, QuantJob, RoundingMode};
use crate::gptq::{gptq_quantize, GptqHessian};
use crate::model_state::{ActStats, ModelParams};
use crate::quant::{self, LINEARS};
use crate::runtime::{Artifacts, Backend, Bindings, ModelCfg};
use crate::tensor::Tensor;

pub use qstate::LinearQ;

/// A fully-quantized model: baked (fake-quantized) weights + the activation
/// quantization state eval needs.
pub struct QuantizedModel {
    /// Model tensors with weights baked to their quantized grid.
    pub params: ModelParams,
    /// Per-block, per-linear learned quantization state (keyed by linear
    /// name).
    pub qstate: Vec<BTreeMap<String, LinearQ>>,
    /// Weight/activation bit widths the model was quantized at.
    pub bits: crate::config::BitSpec,
    /// Rounding scheme the weights were baked with.
    pub rounding: RoundingMode,
}

/// Greedy covering of an `n_layers` block chain with the largest exported
/// window executables: the dispatch plan used by both quantized eval
/// (`Pipeline::forward_hidden`) and the serving engine (`serve`). Returns
/// `(start_block, width)` steps; falls back to width 1 when no exported
/// window fits the remainder.
pub fn window_plan(windows: &[usize], n_layers: usize) -> Vec<(usize, usize)> {
    let mut sorted: Vec<usize> = windows.iter().copied().filter(|&w| w > 0).collect();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    let mut plan = Vec::new();
    let mut k = 0usize;
    while k < n_layers {
        let remaining = n_layers - k;
        let w = sorted.iter().copied().find(|&w| w <= remaining).unwrap_or(1);
        plan.push((k, w));
        k += w;
    }
    plan
}

/// Everything a bench table row reports.
#[derive(Clone, Debug)]
pub struct QuantSummary {
    /// Row label (method + bit widths, e.g. `cbq_w4a16`).
    pub label: String,
    /// perplexity per corpus style name
    pub ppl: BTreeMap<String, f64>,
    /// Wall-clock seconds the quantization run took.
    pub quant_seconds: f64,
    /// learnable + optimizer state bytes at the peak window
    pub state_bytes: usize,
    /// activation cache bytes (hidden-state caches for the window)
    pub act_cache_bytes: usize,
    /// mean reconstruction loss per window (diagnostics / ablations)
    pub window_losses: Vec<f32>,
    /// Outlier weights truncated by the CFP pre-processing stage.
    pub preproc_weights_truncated: usize,
    /// Channels rescaled by the CFP pre-processing stage.
    pub preproc_channels_scaled: usize,
}

/// Quantization driver: owns the calibration data flow, window schedule and
/// optimizer loop over one model's exported executables.
pub struct Pipeline<'a> {
    /// Exported artifact bundle (executables, weights, window set).
    pub art: &'a Artifacts,
    /// Execution backend (PJRT over AOT artifacts, or the native CPU
    /// interpreter) — all model compute dispatches through this trait.
    pub rt: &'a dyn Backend,
    /// Shape/config of the model being quantized.
    pub cfg: ModelCfg,
    /// Artifact-bundle name of that config (e.g. `t`, `s`).
    pub cfg_name: String,
    /// Full-precision reference parameters (the reconstruction target).
    pub fp: ModelParams,
}

impl<'a> Pipeline<'a> {
    /// Load the named config's weights off `art` and wrap them with the
    /// backend into a ready-to-run pipeline.
    pub fn new(art: &'a Artifacts, rt: &'a dyn Backend, cfg_name: &str) -> Result<Self> {
        let cfg = art.cfg(cfg_name)?.clone();
        let weights = art.weights(cfg_name)?;
        let fp = ModelParams::from_tensors(&weights, &cfg)?;
        Ok(Self { art, rt, cfg, cfg_name: cfg_name.to_string(), fp })
    }

    // ------------------------------------------------------------------
    // binding builders (flatten_spec contract, see python/compile/model.py)
    // ------------------------------------------------------------------

    /// Bind one block's weight tensors under the `blocks.{j}.*` names.
    pub fn bind_block_weights(b: &mut Bindings, j: usize, blk: &crate::model_state::BlockParams) {
        b.set(format!("blocks.{j}.attn_norm"), blk.attn_norm.clone());
        b.set(format!("blocks.{j}.mlp_norm"), blk.mlp_norm.clone());
        for l in LINEARS {
            b.set(format!("blocks.{j}.{l}"), blk.linears[l].clone());
        }
    }

    /// Bind one block's quantization state (`qblocks.{j}.*`): step sizes,
    /// clip, rounding factors and the w/a enable scalars.
    #[allow(clippy::too_many_arguments)]
    pub fn bind_qblock(
        b: &mut Bindings,
        j: usize,
        q: &BTreeMap<String, LinearQ>,
        qmax_a: f32,
        w_en: f32,
        a_en: f32,
        dense: bool,
    ) {
        for l in LINEARS {
            let lq = &q[l];
            let p = format!("qblocks.{j}.{l}");
            b.set(format!("{p}.s_w"), lq.s_w.clone());
            b.scalar(format!("{p}.alpha"), lq.alpha);
            if dense {
                b.set(
                    format!("{p}.v"),
                    lq.v_dense.clone().expect("dense mode requires v_dense"),
                );
            } else {
                b.set(format!("{p}.a1"), lq.a1.clone());
                b.set(format!("{p}.a2"), lq.a2.clone());
            }
            b.set(format!("{p}.v0"), lq.v0.clone());
            b.scalar(format!("{p}.qmax_w"), lq.qmax_w);
            b.scalar(format!("{p}.qmax_a"), qmax_a);
            b.scalar(format!("{p}.w_en"), w_en);
            b.scalar(format!("{p}.a_en"), a_en);
        }
    }

    /// Bind the `globals.*` scalars every executable expects (LoRA gate,
    /// beta anneal, effective-rank gamma, loss-term weights).
    pub fn bind_globals(b: &mut Bindings, use_lora: f32, beta: f32, gamma_c: f32, l2: f32, kld: f32) {
        b.scalar("globals.use_lora", use_lora);
        b.scalar("globals.beta", beta);
        b.scalar("globals.gamma_c", gamma_c);
        b.scalar("globals.l2_w", l2);
        b.scalar("globals.kld_w", kld);
    }

    /// Default qstate for a span of blocks (used both by training init and
    /// by the FP/eval paths that only need benign placeholder values).
    pub fn init_qstate(
        &self,
        params: &ModelParams,
        bits: &crate::config::BitSpec,
        rank: usize,
        mode: RoundingMode,
    ) -> Vec<BTreeMap<String, LinearQ>> {
        params
            .blocks
            .iter()
            .enumerate()
            .map(|(bi, blk)| {
                LINEARS
                    .iter()
                    .map(|&l| {
                        let lq = LinearQ::init(
                            &blk.linears[l],
                            bits.weight_bits(bi, l),
                            self.cfg.rank_pad,
                            rank,
                            mode,
                        );
                        (l.to_string(), lq)
                    })
                    .collect()
            })
            .collect()
    }

    /// Run one window-sized forward (loss vs target ignored unless needed);
    /// returns h_out.
    #[allow(clippy::too_many_arguments)]
    pub fn window_forward(
        &self,
        exec: &str,
        blocks: &[crate::model_state::BlockParams],
        qblocks: &[BTreeMap<String, LinearQ>],
        h_in: &Tensor,
        target: &Tensor,
        qmax_a: f32,
        w_en: f32,
        a_en: f32,
    ) -> Result<(Tensor, f32)> {
        let mut b = Bindings::new();
        b.set("h_in", h_in.clone());
        b.set("target", target.clone());
        for (j, blk) in blocks.iter().enumerate() {
            Self::bind_block_weights(&mut b, j, blk);
            Self::bind_qblock(&mut b, j, &qblocks[j], qmax_a, w_en, a_en, false);
        }
        Self::bind_globals(&mut b, 0.0, 2.0, 0.0, 1.0, 1.0);
        let out = self.rt.run(exec, b.inner())?;
        Ok((out["h_out"].clone(), out["loss"].item()))
    }

    /// FP hidden states at every block boundary for every calibration batch:
    /// `fp_hidden[k][batch]` is the input to block k (k = n_layers => final).
    pub fn fp_hidden_states(&self, calib: &[Batch]) -> Result<Vec<Vec<Tensor>>> {
        let exec = format!("win_fwd_w1_{}", self.cfg_name);
        let qs = self.init_qstate(&self.fp, &crate::config::BitSpec::w4a16(), 5, RoundingMode::Nearest);
        let mut all = vec![Vec::with_capacity(calib.len())];
        for batch in calib {
            let x = batch.inputs();
            all[0].push(self.fp.embed_tokens(&x.data, batch.batch, batch.seq));
        }
        for k in 0..self.cfg.n_layers {
            let mut next = Vec::with_capacity(calib.len());
            for h in &all[k] {
                let zeros = Tensor::zeros(&h.dims);
                let (h_out, _) = self.window_forward(
                    &exec,
                    &self.fp.blocks[k..k + 1],
                    &qs[k..k + 1],
                    h,
                    &zeros,
                    32767.0,
                    0.0,
                    0.0,
                )?;
                next.push(h_out);
            }
            all.push(next);
        }
        Ok(all)
    }

    /// Capture per-linear input statistics with given weights, propagating
    /// given hidden states (FP path: weights unquantized).
    pub fn capture_stats(
        &self,
        params: &ModelParams,
        calib: &[Batch],
        fp_hidden: &[Vec<Tensor>],
    ) -> Result<ActStats> {
        let exec = format!("capture_{}", self.cfg_name);
        let qs = self.init_qstate(params, &crate::config::BitSpec::w4a16(), 5, RoundingMode::Nearest);
        let mut stats = ActStats::new(self.cfg.n_layers);
        for k in 0..self.cfg.n_layers {
            for (bi, _batch) in calib.iter().enumerate() {
                let h = &fp_hidden[k][bi];
                let mut b = Bindings::new();
                b.set("h_in", h.clone());
                b.set("target", Tensor::zeros(&h.dims));
                Self::bind_block_weights(&mut b, 0, &params.blocks[k]);
                Self::bind_qblock(&mut b, 0, &qs[k], 32767.0, 0.0, 0.0, false);
                Self::bind_globals(&mut b, 0.0, 2.0, 0.0, 1.0, 1.0);
                let out = self.rt.run(&exec, b.inner())?;
                for l in LINEARS {
                    stats.accumulate(k, l, &out[&format!("captures.{l}")]);
                }
            }
        }
        Ok(stats)
    }

    // ------------------------------------------------------------------
    // top-level quantization entry
    // ------------------------------------------------------------------

    /// Quantize the model per `job` (RTN / GPTQ / CBD reconstruction) and
    /// report the bench-row summary alongside the baked model.
    pub fn run(&mut self, job: &QuantJob) -> Result<(QuantizedModel, QuantSummary)> {
        let t0 = Instant::now();
        let calib = calib::calibration(job.calib_sequences, self.cfg.batch, self.cfg.seq);
        let mut work = self.fp.clone();

        // FP targets + activation statistics (pre-processing feed)
        let fp_hidden = self.fp_hidden_states(&calib)?;
        let stats = self.capture_stats(&self.fp, &calib, &fp_hidden)?;

        // outlier pre-processing (function-preserving => fp_hidden stays valid).
        // Activation-side handling exists to protect *activation* quantization;
        // in weight-only mode (A16) migrating activation magnitude into the
        // weights only makes weight quantization harder, so downgrade to the
        // weight-side part (CFP-Weight) / no-op, mirroring how the paper
        // applies CFP-Activation only under joint W-A settings.
        let effective = if job.bits.act_enabled() {
            job.preproc
        } else {
            match job.preproc {
                crate::config::PreprocMethod::CfpFull => {
                    crate::config::PreprocMethod::CfpWeight
                }
                crate::config::PreprocMethod::CfpActivation
                | crate::config::PreprocMethod::SmoothQuant
                | crate::config::PreprocMethod::OutlierSuppression => {
                    crate::config::PreprocMethod::None
                }
                other => other,
            }
        };
        let report = preproc::apply(effective, &mut work, &stats, job.sq_alpha);

        let (model, window_losses, state_bytes) = match job.method {
            Method::Rtn => (self.run_rtn(work, job)?, Vec::new(), 0),
            Method::Gptq => (self.run_gptq(work, job, &calib)?, Vec::new(), 0),
            Method::Cbq => {
                let (m, losses, bytes) = self.run_cbd(work, job, &calib, &fp_hidden)?;
                (m, losses, bytes)
            }
        };
        let quant_seconds = t0.elapsed().as_secs_f64();

        let hidden_bytes =
            self.cfg.batch * self.cfg.seq * self.cfg.d_model * 4 * (job.window + 1);
        let summary = QuantSummary {
            label: job.label(),
            ppl: BTreeMap::new(), // filled by eval
            quant_seconds,
            state_bytes,
            act_cache_bytes: hidden_bytes * calib.len(),
            window_losses,
            preproc_weights_truncated: report.weights_truncated,
            preproc_channels_scaled: report.channels_scaled,
        };
        Ok((model, summary))
    }

    fn run_rtn(&self, mut work: ModelParams, job: &QuantJob) -> Result<QuantizedModel> {
        let qstate = self.init_qstate(&work, &job.bits, job.rank, RoundingMode::Nearest);
        for (bi, blk) in work.blocks.iter_mut().enumerate() {
            for l in LINEARS {
                let qmax = job.bits.qmax_w(bi, l);
                let w = blk.linear_mut(l);
                let s = quant::init_scales(w, qmax);
                *w = quant::fake_quant_rtn(w, &s, qmax);
            }
        }
        Ok(QuantizedModel { params: work, qstate, bits: job.bits.clone(), rounding: RoundingMode::Nearest })
    }

    fn run_gptq(
        &self,
        mut work: ModelParams,
        job: &QuantJob,
        calib: &[Batch],
    ) -> Result<QuantizedModel> {
        let qstate = self.init_qstate(&work, &job.bits, job.rank, RoundingMode::Nearest);
        let capture = format!("capture_{}", self.cfg_name);
        let fwd = format!("win_fwd_w1_{}", self.cfg_name);
        // current hidden per batch (through already-quantized prefix)
        let mut hidden: Vec<Tensor> = calib
            .iter()
            .map(|b| work.embed_tokens(&b.inputs().data, b.batch, b.seq))
            .collect();
        for k in 0..self.cfg.n_layers {
            // 1. capture linear inputs of block k under the current prefix
            let mut hessians: BTreeMap<&str, GptqHessian> = LINEARS
                .iter()
                .map(|&l| (l, GptqHessian::new(self.cfg.linear_shape(l).0)))
                .collect();
            for h in &hidden {
                let mut b = Bindings::new();
                b.set("h_in", h.clone());
                b.set("target", Tensor::zeros(&h.dims));
                Self::bind_block_weights(&mut b, 0, &work.blocks[k]);
                Self::bind_qblock(&mut b, 0, &qstate[k], 32767.0, 0.0, 0.0, false);
                Self::bind_globals(&mut b, 0.0, 2.0, 0.0, 1.0, 1.0);
                let out = self.rt.run(&capture, b.inner())?;
                for l in LINEARS {
                    hessians.get_mut(l).unwrap().accumulate(&out[&format!("captures.{l}")]);
                }
            }
            // 2. GPTQ-quantize every linear of block k
            for l in LINEARS {
                let qmax = job.bits.qmax_w(k, l);
                gptq_quantize(work.blocks[k].linear_mut(l), &hessians[l], qmax, 0.01)?;
            }
            // 3. propagate hidden through the quantized block
            for h in hidden.iter_mut() {
                let zeros = Tensor::zeros(&h.dims);
                let (h_out, _) = self.window_forward(
                    &fwd,
                    &work.blocks[k..k + 1],
                    &qstate[k..k + 1],
                    h,
                    &zeros,
                    32767.0,
                    0.0,
                    0.0,
                )?;
                *h = h_out;
            }
        }
        Ok(QuantizedModel { params: work, qstate, bits: job.bits.clone(), rounding: RoundingMode::Nearest })
    }

    // ------------------------------------------------------------------
    // CBD: the cross-block sliding-window reconstruction (Sec. 3.1-3.3)
    // ------------------------------------------------------------------

    #[allow(clippy::type_complexity)]
    fn run_cbd(
        &self,
        mut work: ModelParams,
        job: &QuantJob,
        calib: &[Batch],
        fp_hidden: &[Vec<Tensor>],
    ) -> Result<(QuantizedModel, Vec<f32>, usize)> {
        let l_total = self.cfg.n_layers;
        let w = job.window.min(l_total);
        let overlap = job.overlap.min(w.saturating_sub(1));
        let step = w - overlap;
        let dense = matches!(job.rounding, RoundingMode::DenseAdaRound);
        let grad_exec = if dense {
            format!("win_grad_dense_w{w}_{}", self.cfg_name)
        } else {
            format!("win_grad_w{w}_{}", self.cfg_name)
        };
        if self.rt.spec(&grad_exec).is_err() {
            return Err(anyhow!(
                "no exported artifact for window={w} (exec {grad_exec}); available windows: {:?}",
                self.art.manifest.windows.get(&self.cfg_name)
            ));
        }
        let fwd1 = format!("win_fwd_w1_{}", self.cfg_name);

        let mut qstate = self.init_qstate(&work, &job.bits, job.rank, job.rounding);
        let qmax_a = job.bits.qmax_a();
        let a_en = if job.bits.act_enabled() { 1.0 } else { 0.0 };
        let use_lora = if matches!(job.rounding, RoundingMode::Nearest) { 0.0 } else { 1.0 };

        // window start schedule: k*step, with a final clamped window so the
        // last blocks always get optimized.
        let mut starts: Vec<usize> = (0..).map(|k| k * step).take_while(|s| s + w <= l_total).collect();
        if starts.last().map(|&s| s + w < l_total).unwrap_or(true) {
            starts.push(l_total - w);
        }

        // quantized-path hidden states at the current frontier block
        let mut frontier = 0usize;
        let mut q_hidden: Vec<Tensor> = fp_hidden[0].clone();
        let mut window_losses = Vec::new();

        for &s in &starts {
            // advance the quantized-path inputs to block s
            while frontier < s {
                for h in q_hidden.iter_mut() {
                    let zeros = Tensor::zeros(&h.dims);
                    let (h_out, _) = self.window_forward(
                        &fwd1,
                        &work.blocks[frontier..frontier + 1],
                        &qstate[frontier..frontier + 1],
                        h,
                        &zeros,
                        qmax_a,
                        1.0,
                        a_en,
                    )?;
                    *h = h_out;
                }
                frontier += 1;
            }
            // optimize window [s, s+w)
            let total_steps = (job.epochs * calib.len()).max(1);
            let mut step_idx = 0usize;
            let mut loss_sum = 0.0f32;
            let mut loss_n = 0usize;
            for _epoch in 0..job.epochs {
                for (bi, _batch) in calib.iter().enumerate() {
                    // beta anneal 20 -> 2 across the window's steps (Eq. 12)
                    let frac = step_idx as f32 / total_steps as f32;
                    let beta = 20.0 - 18.0 * frac;
                    // Two-phase schedule (the paper's late-phase
                    // "DeltaW = |DeltaW|" forcing, adapted to the V0
                    // warm-start): the soft phase trains the rounding
                    // offsets (A1/A2) on the soft surrogate; the hard phase
                    // switches the forward to hard rounding and trains the
                    // step sizes. s_w must NOT train during the soft phase:
                    // the V0 = frac(W/s_w-at-init) identity makes s_w = init
                    // a loss attractor there (any movement re-introduces
                    // soft error), which would pin the scales.
                    let hard_phase = frac >= 1.0 - job.hard_frac && use_lora > 0.0;
                    let step_lora = if hard_phase { 0.0 } else { use_lora };
                    let soft_phase_lora = !hard_phase && use_lora > 0.0;
                    step_idx += 1;

                    let mut b = Bindings::new();
                    b.set("h_in", q_hidden[bi].clone());
                    b.set("target", fp_hidden[s + w][bi].clone());
                    for (j, blk) in work.blocks[s..s + w].iter().enumerate() {
                        Self::bind_block_weights(&mut b, j, blk);
                        Self::bind_qblock(&mut b, j, &qstate[s + j], qmax_a, 1.0, a_en, dense);
                    }
                    Self::bind_globals(
                        &mut b,
                        step_lora,
                        beta,
                        job.gamma_c,
                        job.l2_weight,
                        job.kld_weight,
                    );
                    let out = self.rt.run(&grad_exec, b.inner())?;
                    loss_sum += out["loss"].item();
                    loss_n += 1;
                    for j in 0..w {
                        for l in LINEARS {
                            let g = |p: &str| out.get(&format!("grads.{j}.{l}.{p}")).cloned();
                            let (g1, g2, gv) = if hard_phase {
                                (None, None, None)
                            } else {
                                (g("a1"), g("a2"), g("v"))
                            };
                            let lr_s = if soft_phase_lora { 0.0 } else { job.lr_s_w };
                            let lq = qstate[s + j].get_mut(l).unwrap();
                            lq.step(
                                &g("s_w").ok_or_else(|| anyhow!("missing grad s_w"))?,
                                g("alpha").map(|t| t.item()).unwrap_or(0.0),
                                g1.as_ref(),
                                g2.as_ref(),
                                gv.as_ref(),
                                (lr_s, job.lr_alpha, job.lr_lora),
                                job.rank,
                                job.rounding,
                            );
                            if lr_s > 0.0 {
                                // the grid moved: re-anchor the rounding
                                // baseline to the current scales
                                lq.refresh_v0(&work.blocks[s + j].linears[l]);
                            }
                        }
                    }
                }
            }
            window_losses.push(loss_sum / loss_n.max(1) as f32);
        }

        // peak optimizer state (paper's "GPU memory" analog)
        let state_bytes: usize = (0..w)
            .flat_map(|j| LINEARS.iter().map(move |&l| (j, l)))
            .map(|(j, l)| qstate[j][l].state_bytes(job.rounding, job.rank))
            .sum();

        // finalize: bake fake-quantized weights with hardened rounding
        // (rho anchored to the final scales)
        for (bi, blk) in work.blocks.iter_mut().enumerate() {
            for l in LINEARS {
                let w_cur = blk.linears[l].clone();
                let lq = qstate[bi].get_mut(l).unwrap();
                lq.refresh_v0(&w_cur);
                let rho = lq.rho(job.rounding);
                let w_t = blk.linear_mut(l);
                *w_t = quant::finalize_weights(w_t, &lq.s_w, rho.as_ref(), lq.qmax_w);
            }
        }
        Ok((
            QuantizedModel {
                params: work,
                qstate,
                bits: job.bits.clone(),
                rounding: job.rounding,
            },
            window_losses,
            state_bytes,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_plan_greedy_covering() {
        // prefers the largest window, covers exactly, falls back to 1
        assert_eq!(window_plan(&[1, 2, 4], 8), vec![(0, 4), (4, 4)]);
        assert_eq!(window_plan(&[1, 2, 4], 6), vec![(0, 4), (4, 2)]);
        assert_eq!(window_plan(&[1, 2, 4], 7), vec![(0, 4), (4, 2), (6, 1)]);
        assert_eq!(window_plan(&[4], 3), vec![(0, 1), (1, 1), (2, 1)]);
        assert_eq!(window_plan(&[], 2), vec![(0, 1), (1, 1)]);
        // every plan covers [0, n) exactly once, in order
        for n in 1..20usize {
            for ws in [vec![1], vec![1, 2], vec![1, 2, 4, 8], vec![3, 5]] {
                let plan = window_plan(&ws, n);
                let mut at = 0usize;
                for (s, w) in plan {
                    assert_eq!(s, at);
                    assert!(w >= 1);
                    at += w;
                }
                assert_eq!(at, n);
            }
        }
    }

    #[test]
    fn window_schedule_covers_all_blocks() {
        // mirror of the scheduling logic: every block must fall in >= 1 window
        for l_total in [4usize, 8, 12] {
            for w in [1usize, 2, 4] {
                for overlap in 0..w {
                    let step = w - overlap;
                    let mut starts: Vec<usize> =
                        (0..).map(|k| k * step).take_while(|s| s + w <= l_total).collect();
                    if starts.last().map(|&s| s + w < l_total).unwrap_or(true) {
                        starts.push(l_total - w);
                    }
                    let mut covered = vec![false; l_total];
                    for &s in &starts {
                        for c in covered.iter_mut().skip(s).take(w) {
                            *c = true;
                        }
                    }
                    assert!(
                        covered.iter().all(|&c| c),
                        "uncovered blocks at L={l_total} w={w} ov={overlap}: {starts:?}"
                    );
                    // monotone non-decreasing starts
                    assert!(starts.windows(2).all(|p| p[0] <= p[1]));
                }
            }
        }
    }
}
