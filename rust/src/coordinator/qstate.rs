//! Learnable quantization state per linear + the Adam optimizer that the
//! coordinator applies to the gradients coming back from the `win_grad_*`
//! executables (the L2 graphs compute gradients; L3 owns all state).

use crate::config::RoundingMode;
use crate::quant::{self, GAMMA, ZETA};
use crate::tensor::Tensor;

/// V0 with rectified-sigmoid(V0) == frac(W/s_w) — the AdaRound warm-start
/// (mirrors python model._v0_init).
pub fn v0_init(w: &Tensor, s_w: &Tensor) -> Tensor {
    let (k, n) = (w.rows(), w.cols());
    let mut out = vec![0.0f32; k * n];
    for i in 0..k {
        for j in 0..n {
            let s = s_w.data[j].max(1e-8);
            let v = w.at2(i, j) / s;
            let frac = v - v.floor();
            let p = ((frac - GAMMA) / (ZETA - GAMMA)).clamp(1e-4, 1.0 - 1e-4);
            out[i * n + j] = (p / (1.0 - p)).ln();
        }
    }
    Tensor::new(vec![k, n], out)
}

/// Adam moments for one parameter tensor.
#[derive(Clone, Debug)]
pub struct Adam {
    /// First-moment (mean) estimate, one slot per parameter element.
    pub m: Vec<f32>,
    /// Second-moment (uncentered variance) estimate.
    pub v: Vec<f32>,
    /// Step count for bias correction.
    pub t: u32,
}

impl Adam {
    /// Fresh zeroed moments for an `n`-element parameter.
    pub fn new(n: usize) -> Self {
        Self { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// One bias-corrected Adam update of `param` in place from `grad`.
    pub fn step(&mut self, param: &mut [f32], grad: &[f32], lr: f32) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        self.t += 1;
        let bc1 = 1.0 - B1.powi(self.t as i32);
        let bc2 = 1.0 - B2.powi(self.t as i32);
        for ((p, g), (m, v)) in param
            .iter_mut()
            .zip(grad)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            *m = B1 * *m + (1.0 - B1) * g;
            *v = B2 * *v + (1.0 - B2) * g * g;
            let mh = *m / bc1;
            let vh = *v / bc2;
            *p -= lr * mh / (vh.sqrt() + EPS);
        }
    }
}

/// Learnable state for one quantized linear.
#[derive(Clone, Debug)]
pub struct LinearQ {
    /// Learned per-column weight step sizes, shape `[fan_out]`.
    pub s_w: Tensor,
    /// Learned activation clip multiplier (paper's per-linear alpha).
    pub alpha: f32,
    /// Left LoRA factor (padded rank R; columns >= `rank` kept at zero).
    pub a1: Tensor,
    /// Right LoRA factor (padded rank R; rows >= `rank` kept at zero).
    pub a2: Tensor,
    /// AdaRound warm-start constant: rho(init) = h(V0) = frac(W / s_w), so
    /// soft-quantized weights equal the FP weights at step 0 and the LoRA
    /// product learns a low-rank delta (see python model._rho).
    pub v0: Tensor,
    /// Dense rounding matrix (only for RoundingMode::DenseAdaRound).
    pub v_dense: Option<Tensor>,
    /// Weight bit width this linear quantizes to (2, 4 or 8).
    pub bits_w: u8,
    /// Quantizer clamp bound derived from `bits_w` (`2^(bits-1) - 1`).
    pub qmax_w: f32,
    adam_s: Adam,
    adam_alpha: Adam,
    adam_a1: Adam,
    adam_a2: Adam,
    adam_v: Option<Adam>,
}

impl LinearQ {
    /// Paper init: s_w = max|W_col|/qmax, alpha = 1, A1 ~ N(0, 0.01), A2 = 0
    /// (rho starts at 0.5). A1's deterministic pseudo-gaussian matches the
    /// python init in spirit (exact values don't matter — A2 = 0 makes the
    /// product zero either way).
    pub fn init(
        w: &Tensor,
        bits_w: u8,
        rank_pad: usize,
        rank: usize,
        mode: RoundingMode,
    ) -> Self {
        let (fan_in, fan_out) = (w.rows(), w.cols());
        let qmax_w = crate::config::qmax(bits_w);
        let s_w = quant::init_scales(w, qmax_w);
        let mut a1 = Tensor::zeros(&[fan_in, rank_pad]);
        let mut seed = 0x12345678u64;
        for (i, v) in a1.data.iter_mut().enumerate() {
            seed ^= seed >> 12;
            seed ^= seed << 25;
            seed ^= seed >> 27;
            let u = (seed.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32
                / (1u64 << 24) as f32;
            let col = i % rank_pad;
            // effective-rank projection applied at init too
            *v = if col < rank { (u - 0.5) * 0.02 } else { 0.0 };
        }
        let a2 = Tensor::zeros(&[rank_pad, fan_out]);
        let v_dense = matches!(mode, RoundingMode::DenseAdaRound)
            .then(|| Tensor::zeros(&[fan_in, fan_out]));
        let v0 = v0_init(w, &s_w);
        Self {
            adam_s: Adam::new(s_w.len()),
            adam_alpha: Adam::new(1),
            adam_a1: Adam::new(a1.len()),
            adam_a2: Adam::new(a2.len()),
            adam_v: v_dense.as_ref().map(|v| Adam::new(v.len())),
            s_w,
            alpha: 1.0,
            a1,
            a2,
            v0,
            v_dense,
            bits_w,
            qmax_w,
        }
    }

    /// Rebuild eval-ready state from snapshot contents (crate::snapshot):
    /// learned scales, activation clip and LoRA factors are restored
    /// exactly; `v0` is re-derived from the dequantized weights (it only
    /// matters for *training*, which a restored model never resumes — the
    /// Adam moments start fresh for the same reason).
    pub fn restore(
        w_dequant: &Tensor,
        s_w: Tensor,
        alpha: f32,
        a1: Tensor,
        a2: Tensor,
        bits_w: u8,
    ) -> Self {
        let qmax_w = crate::config::qmax(bits_w);
        let v0 = v0_init(w_dequant, &s_w);
        Self {
            adam_s: Adam::new(s_w.len()),
            adam_alpha: Adam::new(1),
            adam_a1: Adam::new(a1.len()),
            adam_a2: Adam::new(a2.len()),
            adam_v: None,
            s_w,
            alpha,
            a1,
            a2,
            v0,
            v_dense: None,
            bits_w,
            qmax_w,
        }
    }

    /// One optimizer step from executable gradients. `rank` enforces the
    /// effective LoRA rank by zeroing the padded columns/rows after the
    /// update (this is how Table 12's rank sweep shares one artifact).
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        g_s: &Tensor,
        g_alpha: f32,
        g_a1: Option<&Tensor>,
        g_a2: Option<&Tensor>,
        g_v: Option<&Tensor>,
        lrs: (f32, f32, f32),
        rank: usize,
        mode: RoundingMode,
    ) {
        let (lr_s, lr_alpha, lr_lora) = lrs;
        self.adam_s.step(&mut self.s_w.data, &g_s.data, lr_s);
        // keep scales positive
        for s in self.s_w.data.iter_mut() {
            *s = s.max(1e-6);
        }
        let mut a = [self.alpha];
        self.adam_alpha.step(&mut a, &[g_alpha], lr_alpha);
        self.alpha = a[0].clamp(0.05, 2.0);
        match mode {
            RoundingMode::Lora => {
                if let (Some(g1), Some(g2)) = (g_a1, g_a2) {
                    self.adam_a1.step(&mut self.a1.data, &g1.data, lr_lora);
                    self.adam_a2.step(&mut self.a2.data, &g2.data, lr_lora);
                    self.project_rank(rank);
                }
            }
            RoundingMode::DenseAdaRound => {
                if let (Some(gv), Some(v), Some(ad)) =
                    (g_v, self.v_dense.as_mut(), self.adam_v.as_mut())
                {
                    ad.step(&mut v.data, &gv.data, lr_lora);
                }
            }
            RoundingMode::Nearest => {}
        }
    }

    /// Re-derive the warm-start offset from the *current* step sizes.
    /// s_w training moves the quantization grid, so the frac(W/s_w)
    /// baseline must follow it — otherwise rounding decisions harden
    /// against a stale grid and land a full step off for every weight
    /// whose fractional position crossed 0.5 (measured: ~30% of entries
    /// after a few scale epochs, ~6 ppl at W4A16 on the `t` model).
    pub fn refresh_v0(&mut self, w: &Tensor) {
        self.v0 = v0_init(w, &self.s_w);
    }

    /// Zero A1 columns >= rank and A2 rows >= rank.
    pub fn project_rank(&mut self, rank: usize) {
        let rp = self.a1.cols();
        if rank >= rp {
            return;
        }
        for i in 0..self.a1.rows() {
            for c in rank..rp {
                self.a1.set2(i, c, 0.0);
            }
        }
        for r in rank..rp {
            for j in 0..self.a2.cols() {
                self.a2.set2(r, j, 0.0);
            }
        }
    }

    /// Materialize the rounding offsets for finalization:
    /// rho = h(V0 + A1 @ A2) (or h(V0 + V_dense)).
    pub fn rho(&self, mode: RoundingMode) -> Option<Tensor> {
        match mode {
            RoundingMode::Nearest => None,
            RoundingMode::Lora => {
                let mut v = self.a1.matmul(&self.a2);
                v.zip_mut(&self.v0, |d, o| d + o);
                Some(v.map(quant::rect_sigmoid))
            }
            RoundingMode::DenseAdaRound => self.v_dense.as_ref().map(|v| {
                let mut vv = v.clone();
                vv.zip_mut(&self.v0, |d, o| d + o);
                vv.map(quant::rect_sigmoid)
            }),
        }
    }

    /// Learnable + optimizer bytes (Tables 3b/9 memory accounting).
    pub fn state_bytes(&self, mode: RoundingMode, rank: usize) -> usize {
        let (fi, fo) = (self.a1.rows(), self.a2.cols());
        quant::learnable_bytes(
            fi,
            fo,
            rank,
            match mode {
                RoundingMode::Nearest => quant::RoundBytes::Nearest,
                RoundingMode::DenseAdaRound => quant::RoundBytes::Dense,
                RoundingMode::Lora => quant::RoundBytes::Lora(rank),
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimizes_quadratic() {
        let mut p = vec![5.0f32];
        let mut a = Adam::new(1);
        for _ in 0..500 {
            let g = vec![2.0 * p[0]];
            a.step(&mut p, &g, 0.05);
        }
        assert!(p[0].abs() < 0.05, "ended at {}", p[0]);
    }

    #[test]
    fn init_matches_paper() {
        let w = Tensor::new(vec![4, 2], vec![0.7, -0.1, 0.2, 0.3, -0.7, 0.0, 0.1, 0.05]);
        let q = LinearQ::init(&w, 4, 8, 5, RoundingMode::Lora);
        assert!((q.s_w.data[0] - 0.1).abs() < 1e-6); // 0.7/7
        assert_eq!(q.alpha, 1.0);
        assert!(q.a2.data.iter().all(|&v| v == 0.0));
        // padded columns zero
        for i in 0..4 {
            for c in 5..8 {
                assert_eq!(q.a1.at2(i, c), 0.0);
            }
        }
        assert!(q.v_dense.is_none());
    }

    #[test]
    fn rank_projection_enforced_after_steps() {
        let w = Tensor::full(&[6, 3], 0.4);
        let mut q = LinearQ::init(&w, 4, 8, 2, RoundingMode::Lora);
        let g1 = Tensor::full(&[6, 8], 0.1);
        let g2 = Tensor::full(&[8, 3], 0.1);
        let gs = Tensor::zeros(&[3]);
        for _ in 0..3 {
            q.step(&gs, 0.0, Some(&g1), Some(&g2), None, (0.0, 0.0, 1e-2), 2, RoundingMode::Lora);
        }
        for i in 0..6 {
            for c in 2..8 {
                assert_eq!(q.a1.at2(i, c), 0.0);
            }
        }
        for r in 2..8 {
            for j in 0..3 {
                assert_eq!(q.a2.at2(r, j), 0.0);
            }
        }
        // active part moved
        assert!(q.a2.at2(0, 0) != 0.0);
    }

    #[test]
    fn scales_stay_positive() {
        let w = Tensor::full(&[2, 2], 0.01);
        let mut q = LinearQ::init(&w, 4, 8, 5, RoundingMode::Nearest);
        let g = Tensor::full(&[2], 100.0);
        for _ in 0..50 {
            q.step(&g, 0.0, None, None, None, (0.1, 0.0, 0.0), 5, RoundingMode::Nearest);
        }
        assert!(q.s_w.data.iter().all(|&s| s >= 1e-6));
    }

    #[test]
    fn dense_mode_allocates_v() {
        // realistic fan-in/out: LoRA's (fi+fo)*r << dense fi*fo
        let w = Tensor::full(&[128, 128], 0.2);
        let q = LinearQ::init(&w, 2, 8, 5, RoundingMode::DenseAdaRound);
        assert!(q.v_dense.is_some());
        assert!(q.state_bytes(RoundingMode::DenseAdaRound, 5)
            > 10 * q.state_bytes(RoundingMode::Lora, 5));
    }
}
