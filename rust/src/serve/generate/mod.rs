//! Token generation: the KV-cached decode path with continuous batching.
//!
//! The eval-serving stack ([`super::batcher`], [`super::scheduler`]) scores
//! *fixed* token rows; this module **generates** tokens autoregressively:
//!
//! * [`GenerateEngine`] wraps a [`ServeEngine`] and drives the backend's
//!   [`Backend::decode_step`] entry point — one new position per sequence
//!   per step, attending over per-sequence [`SeqKv`] caches instead of
//!   re-running the full `[batch, seq]` prefill each token. Decode is
//!   **bitwise-equal** to a full prefill over the same prefix (asserted in
//!   `rust/tests/generate.rs`): every kernel outside attention is
//!   per-position, and incremental attention replicates the forward
//!   pass's exact per-`(sq, sk)` operation order
//!   ([`Attention::attend_one`](crate::runtime::backend::kernels::Attention::attend_one)).
//!   On the native backend with packed pinning (the default), each
//!   per-position linear runs straight from the window's 2/4/8-bit codes
//!   via [`kernels::qmatvec`] — bitwise-equal to the f32 matvec at every
//!   SIMD tier (`CBQ_SIMD`), so packed decode streams match f32 decode
//!   and full prefill token-for-token, bit-for-bit.
//! * [`GenerateEngine::run`] is a **continuous-batching** loop: requests
//!   join and leave the running decode batch *per token step*, not per
//!   batch. Admission, priority scoring (the scheduler's class weights +
//!   weighted aging) and retirement all happen between steps, so a long
//!   Background generation never blocks a newly arrived Interactive
//!   request for more than one token's worth of work.
//! * Determinism inherits the scheduler's recipe: all decisions run on
//!   integer [`Clock`] ticks, service time is *modeled* under
//!   [`SimClock`](super::SimClock) (a fixed tick cost per decode step,
//!   independent of the dispatch lane count), and rows are partitioned
//!   across lanes without changing any per-row arithmetic — so a seeded
//!   trace replays to bitwise-identical token streams at any
//!   `--dispatch` setting.
//!
//! Greedy decoding is intentionally the only sampling mode: argmax keeps
//! the output a pure function of the weights, which is what makes the
//! replay and batch-vs-sequential equivalence tests meaningful.

use std::mem;

use anyhow::{anyhow, ensure, Result};

use super::clock::{ticks_to_secs, Clock};
use super::metrics::{percentile, ServeMetrics};
use super::scheduler::{Lcg, Priority};
use super::ServeEngine;
use crate::model_state::embed_lookup;
use crate::runtime::backend::kernels;
use crate::runtime::{Backend, SeqKv};
use crate::tensor::{Tensor, TensorI32};

/// One generation request: a prompt to continue and a per-request token
/// budget (further capped by [`GenCfg::max_new_tokens`] and the model's
/// sequence length).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GenRequest {
    /// Prompt token ids (must be non-empty and shorter than the model's
    /// `seq`, else the request is rejected at admission).
    pub prompt: Vec<i32>,
    /// Requested number of generated tokens.
    pub max_new_tokens: usize,
}

/// One trace entry: `request` becomes visible `at` ticks after the run
/// starts, with priority `class`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GenArrival {
    /// Arrival tick (offset from run start).
    pub at: u64,
    /// Priority class (reuses the scheduler's classes and weights).
    pub class: Priority,
    /// The generation request.
    pub request: GenRequest,
}

/// Continuous-batching knobs for [`GenerateEngine::run`].
#[derive(Clone, Debug)]
pub struct GenCfg {
    /// Engine-wide cap on generated tokens per request (the CLI's
    /// `--max-new-tokens`).
    pub max_new_tokens: usize,
    /// Maximum sequences decoding concurrently (the batch the decode step
    /// sees; unlike the prefill executables this is not shape-fixed).
    pub slots: usize,
    /// Maximum requests waiting for a slot; arrivals beyond it are
    /// rejected (`None` = unbounded, nothing is ever rejected for load).
    pub queue_cap: Option<usize>,
    /// Decode dispatch lanes: active rows are partitioned into this many
    /// contiguous chunks stepped concurrently. Affects wall time only,
    /// never results or scheduling decisions.
    pub dispatch: usize,
    /// Priority-class base weights, [`Priority::ALL`] order.
    pub weights: [u64; 3],
    /// Score gained per tick of queue age (starvation protection).
    pub aging: u64,
    /// Modeled simulated-clock cost of one decode step (ignored under a
    /// real clock). Lane-count independent by design.
    pub service_ticks_per_step: u64,
}

impl Default for GenCfg {
    fn default() -> Self {
        Self {
            max_new_tokens: 64,
            slots: 4,
            queue_cap: None,
            dispatch: 1,
            weights: [300_000, 200_000, 100_000],
            aging: 1,
            service_ticks_per_step: 1_000,
        }
    }
}

/// Per-step admission accounting: every arrival drained in a step is
/// either admitted to the queue or rejected, never dropped silently —
/// `offered == admitted + rejected` holds for every entry (asserted in
/// `rust/tests/generate.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StepCount {
    /// Arrivals that became due during this step.
    pub offered: usize,
    /// Of those, admitted to the pending queue.
    pub admitted: usize,
    /// Of those, rejected (queue over capacity, or the request cannot
    /// generate: empty prompt, prompt filling the whole context, or a
    /// zero token budget).
    pub rejected: usize,
}

/// Terminal record of one request: the generated tokens with their
/// emission ticks, or a rejection marker.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GenOutcome {
    /// Index of the arrival in the submitted trace.
    pub seq: usize,
    /// Priority class.
    pub class: Priority,
    /// Arrival tick.
    pub arrival: u64,
    /// Tick the request left the queue for a decode slot (for rejected
    /// requests: the tick of rejection).
    pub admitted: u64,
    /// Greedy-decoded tokens, in emission order.
    pub tokens: Vec<i32>,
    /// Emission tick of each token in `tokens`.
    pub token_ticks: Vec<u64>,
    /// Tick the request completed (or was rejected).
    pub finish: u64,
    /// Was the request rejected at admission?
    pub rejected: bool,
}

/// Aggregate statistics of one [`GenerateEngine::run`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GenStats {
    /// Requests offered by the trace.
    pub requests: u64,
    /// Requests that completed with a token stream.
    pub completed: u64,
    /// Requests rejected at admission.
    pub rejected: u64,
    /// Decode steps executed (each advances every active sequence by one
    /// position).
    pub decode_steps: u64,
    /// Tokens emitted across all requests.
    pub tokens: u64,
    /// Clock ticks from run start to the last completion.
    pub wall_ticks: u64,
    /// Per-token latency percentiles, in ticks: a token's latency is the
    /// gap since the previous emission of the same request (for the first
    /// token: since arrival).
    pub tok_p50: u64,
    /// 95th percentile per-token latency (ticks).
    pub tok_p95: u64,
    /// 99th percentile per-token latency (ticks).
    pub tok_p99: u64,
    /// Decode throughput: emitted tokens per wall second (modeled seconds
    /// under a simulated clock).
    pub tokens_per_s: f64,
    /// Dispatch lanes the run used (reporting only — results are
    /// lane-count independent).
    pub dispatch_lanes: usize,
    /// Most sequences ever decoding concurrently.
    pub peak_active: usize,
    /// Per-step admission conservation log.
    pub steps: Vec<StepCount>,
}

/// Trace-generation parameters for [`synth_gen_trace`].
#[derive(Clone, Debug)]
pub struct GenTraceSpec {
    /// Number of arrivals.
    pub requests: usize,
    /// Mean inter-arrival gap in ticks (actual gaps are
    /// `1 + uniform[0, 2*mean)`).
    pub mean_gap: u64,
    /// RNG seed; equal seeds yield equal traces.
    pub seed: u64,
    /// Vocabulary size to draw prompt tokens from.
    pub vocab: usize,
    /// Maximum prompt length (uniform in `1..=max_prompt`).
    pub max_prompt: usize,
    /// Maximum per-request token budget (uniform in
    /// `1..=max_new_tokens`).
    pub max_new_tokens: usize,
}

/// Deterministic synthetic generation trace: seeded arrivals with mixed
/// priority classes (the scheduler's 50/30/20 split), random prompts and
/// token budgets. Equal specs produce equal traces on every platform.
pub fn synth_gen_trace(spec: &GenTraceSpec) -> Vec<GenArrival> {
    let mut rng = Lcg::new(spec.seed);
    let mut at = 0u64;
    let mut out = Vec::with_capacity(spec.requests);
    for _ in 0..spec.requests {
        at += 1 + rng.below(2 * spec.mean_gap.max(1));
        let class = match rng.below(10) {
            0..=4 => Priority::Interactive,
            5..=7 => Priority::Batch,
            _ => Priority::Background,
        };
        let plen = 1 + rng.below(spec.max_prompt.max(1) as u64) as usize;
        let prompt: Vec<i32> =
            (0..plen).map(|_| rng.below(spec.vocab.max(1) as u64) as i32).collect();
        let max_new_tokens = 1 + rng.below(spec.max_new_tokens.max(1) as u64) as usize;
        out.push(GenArrival { at, class, request: GenRequest { prompt, max_new_tokens } });
    }
    out
}

/// Greedy token choice: the lowest-index maximum of `logits` (strict
/// comparison, so ties break toward the smaller token id — deterministic
/// on every platform).
pub fn greedy_pick(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// A request waiting for a decode slot.
struct Pend {
    seq: usize,
    class: Priority,
    at: u64,
    limit: usize,
}

/// A sequence occupying a decode slot.
struct Active {
    seq: usize,
    class: Priority,
    arrival: u64,
    admitted: u64,
    prompt: Vec<i32>,
    /// Tokens fed to the model so far (prefix positions consumed).
    consumed: usize,
    generated: Vec<i32>,
    token_ticks: Vec<u64>,
    limit: usize,
    kv: SeqKv,
}

impl Active {
    /// The token this sequence feeds at its next decode position.
    fn next_token(&self) -> i32 {
        if self.consumed < self.prompt.len() {
            self.prompt[self.consumed]
        } else {
            self.generated[self.consumed - self.prompt.len()]
        }
    }
}

/// Token-generation engine over a bound [`ServeEngine`]: runs the pinned
/// window plan position-by-position via [`Backend::decode_step`] and
/// greedy-decodes from the snapshot's LM head.
///
/// Requires a backend with an incremental decode path (the native
/// interpreter); on PJRT the first decode step returns its unsupported
/// error.
pub struct GenerateEngine<'a, 'rt> {
    eng: &'a ServeEngine<'rt>,
    final_norm: Tensor,
    head: Tensor,
}

impl<'a, 'rt> GenerateEngine<'a, 'rt> {
    /// Wrap `eng`, materializing the final-norm and LM-head tensors the
    /// logit computation needs (zero-copy under `--mmap`).
    pub fn new(eng: &'a ServeEngine<'rt>) -> Result<Self> {
        let final_norm = eng.snap.model.final_norm()?;
        let head = eng.snap.model.head()?;
        Ok(Self { eng, final_norm, head })
    }

    fn cfg(&self) -> &crate::runtime::ModelCfg {
        &self.eng.snap.meta.cfg
    }

    /// LM logits for one hidden row: final RMS-norm then the head matmul.
    /// Both the decode path and the prefill reference go through this one
    /// function, so logit equality reduces to hidden-state equality.
    fn logits_row(&self, h: &[f32]) -> Vec<f32> {
        let d = h.len();
        let normed = kernels::rmsnorm(h, d, &self.final_norm.data);
        kernels::matmul(&normed, 1, d, &self.head.data, self.head.cols())
    }

    /// Advance every row one position through the full pinned window plan,
    /// partitioned into `lanes` contiguous row chunks stepped concurrently.
    /// Each row's arithmetic is independent of the batch around it, so the
    /// result is bitwise-identical for every lane count.
    fn step_batch(&self, toks: &[i32], kvs: &mut [SeqKv], lanes: usize) -> Result<Vec<f32>> {
        let d = self.cfg().d_model;
        let rows = toks.len();
        ensure!(rows == kvs.len(), "{rows} tokens but {} KV states", kvs.len());
        let h_all = embed_lookup(&self.eng.embed, toks, rows, 1);
        let lanes = lanes.max(1).min(rows);
        let run_chunk = |h_chunk: &[f32], kv_chunk: &mut [SeqKv]| -> Result<Vec<f32>> {
            let r = kv_chunk.len();
            let mut h = Tensor::new(vec![r, 1, d], h_chunk.to_vec());
            for (i, (start, _, _)) in self.eng.plan.iter().enumerate() {
                let pinned = self.eng.step_pinned(i)?;
                h = self.eng.rt.decode_step(&pinned, &h, *start, kv_chunk)?;
            }
            Ok(h.data.to_vec())
        };
        if lanes == 1 {
            return run_chunk(&h_all.data, kvs);
        }
        let chunk = rows.div_ceil(lanes);
        let mut out = vec![0.0f32; rows * d];
        let results: Vec<Result<()>> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for ((h_c, o_c), kv_c) in h_all
                .data
                .chunks(chunk * d)
                .zip(out.chunks_mut(chunk * d))
                .zip(kvs.chunks_mut(chunk))
            {
                let run_chunk = &run_chunk;
                handles.push(s.spawn(move || -> Result<()> {
                    o_c.copy_from_slice(&run_chunk(h_c, kv_c)?);
                    Ok(())
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("decode lane panicked"))))
                .collect()
        });
        for r in results {
            r?;
        }
        Ok(out)
    }

    /// Greedy-decode one request sequentially (batch of one, no
    /// scheduling): the reference the continuous-batching loop is tested
    /// against. Returns the generated tokens.
    pub fn decode_reference(&self, prompt: &[i32], max_new_tokens: usize) -> Result<Vec<i32>> {
        Ok(self.decode_trace(prompt, max_new_tokens)?.0)
    }

    /// Like [`decode_reference`](Self::decode_reference), but also returns
    /// the logit vector behind each emitted token — the hook the
    /// bitwise-vs-prefill test compares against
    /// [`prefill_logits`](Self::prefill_logits).
    pub fn decode_trace(
        &self,
        prompt: &[i32],
        max_new_tokens: usize,
    ) -> Result<(Vec<i32>, Vec<Vec<f32>>)> {
        let cfg = self.cfg();
        ensure!(!prompt.is_empty(), "cannot decode from an empty prompt");
        let d = cfg.d_model;
        let limit = max_new_tokens.min(cfg.seq.saturating_sub(prompt.len()));
        let mut kvs = vec![SeqKv::new(cfg.n_layers, cfg.n_heads, cfg.head_dim)];
        let mut tokens = Vec::with_capacity(limit);
        let mut logits_log = Vec::with_capacity(limit);
        let mut fed = 0usize;
        self.eng.prefetch_window(0); // warm the first window (lazy engines)
        while tokens.len() < limit {
            let tok =
                if fed < prompt.len() { prompt[fed] } else { tokens[fed - prompt.len()] };
            let h = self.step_batch(&[tok], &mut kvs, 1)?;
            fed += 1;
            if fed >= prompt.len() {
                let logits = self.logits_row(&h[..d]);
                tokens.push(greedy_pick(&logits));
                logits_log.push(logits);
            }
        }
        Ok((tokens, logits_log))
    }

    /// Reference logits from a **full prefill** over `prefix`: pad to the
    /// fixed `[batch, seq]` shape, run the prefill executables, and read
    /// the hidden state at the prefix's last position (causal attention
    /// makes the padding invisible to it). The decode path must match
    /// this bitwise at every step.
    pub fn prefill_logits(&self, prefix: &[i32]) -> Result<Vec<f32>> {
        let cfg = self.cfg();
        ensure!(
            !prefix.is_empty() && prefix.len() <= cfg.seq,
            "prefill prefix must be 1..={} tokens, got {}",
            cfg.seq,
            prefix.len()
        );
        let mut toks = vec![0i32; cfg.batch * cfg.seq];
        toks[..prefix.len()].copy_from_slice(prefix);
        let h = self.eng.forward_hidden(&TensorI32::new(vec![cfg.batch, cfg.seq], toks))?;
        let d = cfg.d_model;
        let off = (prefix.len() - 1) * d;
        Ok(self.logits_row(&h.data[off..off + d]))
    }

    /// Effective token budget of `a`: the per-request ask, capped by the
    /// engine-wide limit and the context room left after the prompt. Zero
    /// means the request cannot generate and is rejected at admission.
    fn gen_limit(&self, a: &GenArrival, cfg: &GenCfg) -> usize {
        if a.request.prompt.is_empty() {
            return 0;
        }
        a.request
            .max_new_tokens
            .min(cfg.max_new_tokens)
            .min(self.cfg().seq.saturating_sub(a.request.prompt.len()))
    }

    /// Run a trace through the continuous-batching decode loop.
    ///
    /// Per step: (1) drain due arrivals — each is admitted to the pending
    /// queue or rejected (capacity / non-viable request), recorded in
    /// [`GenStats::steps`]; (2) promote the highest-scoring pending
    /// requests (class weight + aging, ties by arrival order) into free
    /// decode slots; (3) advance every active sequence one position via
    /// [`Backend::decode_step`], chunked across `cfg.dispatch` lanes;
    /// (4) emit a greedy token for every sequence past its prompt and
    /// retire finished ones. Under a simulated clock each step costs
    /// exactly `cfg.service_ticks_per_step` ticks regardless of lane
    /// count, so replays are bitwise-identical for any `dispatch`.
    ///
    /// Returns the outcomes sorted by trace index plus aggregate stats.
    pub fn run(
        &self,
        arrivals: &[GenArrival],
        cfg: &GenCfg,
        clock: &dyn Clock,
    ) -> Result<(Vec<GenOutcome>, GenStats)> {
        self.run_with_metrics(arrivals, cfg, clock, None)
    }

    /// [`Self::run`], additionally recording into `metrics`: admission
    /// counters, decode steps as dispatches/cycles, emitted tokens, and
    /// per-class histograms (queue = arrival → slot, service = slot →
    /// finish, latency = per-token emission gaps). Recording happens after
    /// the decode loop finishes, so the hot path is untouched and results
    /// are identical with or without a metrics instance.
    pub fn run_with_metrics(
        &self,
        arrivals: &[GenArrival],
        cfg: &GenCfg,
        clock: &dyn Clock,
        metrics: Option<&ServeMetrics>,
    ) -> Result<(Vec<GenOutcome>, GenStats)> {
        ensure!(cfg.slots >= 1, "continuous batching needs at least one decode slot");
        let d = self.cfg().d_model;
        // stable arrival order: by tick, ties by trace index
        let mut order: Vec<usize> = (0..arrivals.len()).collect();
        order.sort_by_key(|&i| (arrivals[i].at, i));
        let mut next_arr = 0usize;
        let mut pending: Vec<Pend> = Vec::new();
        let mut active: Vec<Active> = Vec::new();
        let mut outcomes: Vec<GenOutcome> = Vec::new();
        let mut stats = GenStats {
            requests: arrivals.len() as u64,
            dispatch_lanes: cfg.dispatch.max(1),
            ..GenStats::default()
        };
        loop {
            if next_arr == order.len() && pending.is_empty() && active.is_empty() {
                break;
            }
            // overlap the first planned window's file I/O with this step's
            // admission/promotion bookkeeping (lazy engines only; the
            // per-access prefetch chain inside step_pinned covers the rest
            // of the plan, wrap-around included)
            self.eng.prefetch_window(0);
            let mut now = clock.now();
            if active.is_empty() && pending.is_empty() {
                // idle: jump to the next arrival
                let at = arrivals[order[next_arr]].at;
                if at > now {
                    clock.wait_until(at);
                    now = clock.now().max(at);
                }
            }
            // 1) admission: every due arrival is admitted or rejected now
            let mut step = StepCount::default();
            while next_arr < order.len() && arrivals[order[next_arr]].at <= now {
                let idx = order[next_arr];
                next_arr += 1;
                let a = &arrivals[idx];
                step.offered += 1;
                let limit = self.gen_limit(a, cfg);
                let over_cap = cfg.queue_cap.is_some_and(|cap| pending.len() >= cap);
                if limit == 0 || over_cap {
                    step.rejected += 1;
                    outcomes.push(GenOutcome {
                        seq: idx,
                        class: a.class,
                        arrival: a.at,
                        admitted: now,
                        tokens: Vec::new(),
                        token_ticks: Vec::new(),
                        finish: now,
                        rejected: true,
                    });
                } else {
                    step.admitted += 1;
                    pending.push(Pend { seq: idx, class: a.class, at: a.at, limit });
                }
            }
            stats.steps.push(step);
            // 2) promotion: highest score first, ties by trace index
            let free = cfg.slots.saturating_sub(active.len());
            if free > 0 && !pending.is_empty() {
                let score = |p: &Pend| {
                    cfg.weights[p.class.index()]
                        .saturating_add(cfg.aging.saturating_mul(now.saturating_sub(p.at)))
                };
                pending.sort_by(|x, y| score(y).cmp(&score(x)).then(x.seq.cmp(&y.seq)));
                for p in pending.drain(..free.min(pending.len())) {
                    let prompt = arrivals[p.seq].request.prompt.clone();
                    let mc = self.cfg();
                    active.push(Active {
                        seq: p.seq,
                        class: p.class,
                        arrival: p.at,
                        admitted: now,
                        prompt,
                        consumed: 0,
                        generated: Vec::new(),
                        token_ticks: Vec::new(),
                        limit: p.limit,
                        kv: SeqKv::new(mc.n_layers, mc.n_heads, mc.head_dim),
                    });
                }
            }
            stats.peak_active = stats.peak_active.max(active.len());
            if active.is_empty() {
                continue;
            }
            // 3) one decode position for every active sequence
            let toks: Vec<i32> = active.iter().map(Active::next_token).collect();
            let mut kvs: Vec<SeqKv> =
                active.iter_mut().map(|a| mem::take(&mut a.kv)).collect();
            let hidden = self.step_batch(&toks, &mut kvs, cfg.dispatch)?;
            for (a, kv) in active.iter_mut().zip(kvs) {
                a.kv = kv;
            }
            stats.decode_steps += 1;
            let done = if clock.is_simulated() {
                let dn = now + cfg.service_ticks_per_step.max(1);
                clock.wait_until(dn);
                dn
            } else {
                clock.now()
            };
            // 4) emit + retire
            let drained = mem::take(&mut active);
            for (r, mut a) in drained.into_iter().enumerate() {
                a.consumed += 1;
                if a.consumed >= a.prompt.len() {
                    let logits = self.logits_row(&hidden[r * d..(r + 1) * d]);
                    a.generated.push(greedy_pick(&logits));
                    a.token_ticks.push(done);
                    stats.tokens += 1;
                }
                if a.generated.len() >= a.limit {
                    stats.completed += 1;
                    outcomes.push(GenOutcome {
                        seq: a.seq,
                        class: a.class,
                        arrival: a.arrival,
                        admitted: a.admitted,
                        tokens: a.generated,
                        token_ticks: a.token_ticks,
                        finish: done,
                        rejected: false,
                    });
                } else {
                    active.push(a);
                }
            }
        }
        stats.rejected = outcomes.iter().filter(|o| o.rejected).count() as u64;
        stats.wall_ticks = clock.now();
        let mut lats: Vec<u64> = Vec::with_capacity(stats.tokens as usize);
        for o in &outcomes {
            let mut prev = o.arrival;
            for &t in &o.token_ticks {
                lats.push(t.saturating_sub(prev));
                prev = t;
            }
        }
        lats.sort_unstable();
        stats.tok_p50 = percentile(&lats, 0.50);
        stats.tok_p95 = percentile(&lats, 0.95);
        stats.tok_p99 = percentile(&lats, 0.99);
        let secs = ticks_to_secs(stats.wall_ticks);
        stats.tokens_per_s = if secs > 0.0 { stats.tokens as f64 / secs } else { 0.0 };
        if let Some(m) = metrics {
            m.add_offered(stats.requests);
            m.add_admitted(stats.requests - stats.rejected);
            m.add_rejected(stats.rejected);
            m.add_dispatches(stats.decode_steps);
            m.add_cycles(stats.decode_steps);
            m.add_tokens(stats.tokens);
            for o in &outcomes {
                if o.rejected {
                    continue;
                }
                m.record_queue(o.class, o.admitted.saturating_sub(o.arrival));
                m.record_service(o.class, o.finish.saturating_sub(o.admitted));
                let mut prev = o.arrival;
                for &t in &o.token_ticks {
                    m.record_latency(o.class, t.saturating_sub(prev));
                    prev = t;
                }
            }
        }
        outcomes.sort_by_key(|o| o.seq);
        Ok((outcomes, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_pick_is_lowest_index_argmax() {
        assert_eq!(greedy_pick(&[0.1, 0.9, 0.9, 0.2]), 1);
        assert_eq!(greedy_pick(&[3.0]), 0);
        assert_eq!(greedy_pick(&[-1.0, -2.0, -1.0]), 0);
    }

    #[test]
    fn synth_gen_trace_is_seed_deterministic_and_bounded() {
        let spec = GenTraceSpec {
            requests: 40,
            mean_gap: 500,
            seed: 7,
            vocab: 31,
            max_prompt: 5,
            max_new_tokens: 6,
        };
        let a = synth_gen_trace(&spec);
        let b = synth_gen_trace(&spec);
        assert_eq!(a, b, "equal seeds must replay equal traces");
        assert_eq!(a.len(), 40);
        let mut prev = 0u64;
        for arr in &a {
            assert!(arr.at > prev, "arrivals strictly increase");
            prev = arr.at;
            assert!((1..=5).contains(&arr.request.prompt.len()));
            assert!((1..=6).contains(&arr.request.max_new_tokens));
            assert!(arr.request.prompt.iter().all(|&t| (0..31).contains(&t)));
        }
        let c = synth_gen_trace(&GenTraceSpec { seed: 8, ..spec });
        assert_ne!(a, c, "different seeds should diverge");
    }

    #[test]
    fn percentile_matches_scheduler_definition() {
        let v = [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100];
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.95), 100);
        assert_eq!(percentile(&[7], 0.5), 7);
        assert_eq!(percentile(&[], 0.99), 0);
    }
}
