//! Virtual-time abstraction for the live scheduler.
//!
//! Every scheduling decision reads time through the [`Clock`] trait in
//! integer ticks (1 tick = 1 microsecond). [`SimClock`] makes the whole
//! arrival loop deterministic: time only moves when the scheduler advances
//! it — to the next arrival while idle, or by the *modeled* service cost of
//! a drain cycle — so a seeded trace replays to bit-identical decisions; no
//! wall clock ever enters the decision path. [`RealClock`] maps the same
//! trait onto `Instant` for actual live serving, where `wait_until` sleeps
//! and service cost is whatever the executor really took.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Scheduler time base: one tick is one microsecond.
pub const TICKS_PER_SEC: u64 = 1_000_000;

/// Ticks -> seconds (for reporting only; decisions stay in integer ticks).
pub fn ticks_to_secs(t: u64) -> f64 {
    t as f64 / TICKS_PER_SEC as f64
}

/// A monotonic tick source the scheduler can also *wait* on.
pub trait Clock: Sync {
    /// Ticks elapsed since the clock's epoch.
    fn now(&self) -> u64;
    /// Block (real) or jump (simulated) until `now() >= t`. A `t` in the
    /// past is a no-op; `now` never goes backwards.
    fn wait_until(&self, t: u64);
    /// Simulated clocks advance by a service *model* instead of measured
    /// wall time — the property that makes replays deterministic.
    fn is_simulated(&self) -> bool;
}

/// Wall-clock ticks from a fixed epoch (construction time).
pub struct RealClock {
    epoch: Instant,
}

impl RealClock {
    /// Start the epoch now.
    pub fn new() -> Self {
        Self { epoch: Instant::now() }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn wait_until(&self, t: u64) {
        let now = self.now();
        if t > now {
            std::thread::sleep(Duration::from_micros(t - now));
        }
    }

    fn is_simulated(&self) -> bool {
        false
    }
}

/// Virtual time: starts at 0 and moves only via `wait_until`. Backed by an
/// atomic so the scheduler can share `&dyn Clock` across threads, though
/// all decision-path reads happen from the single arrival loop.
#[derive(Default)]
pub struct SimClock {
    now: AtomicU64,
}

impl SimClock {
    /// Fresh virtual clock at tick 0.
    pub fn new() -> Self {
        Self::default()
    }
}

impl Clock for SimClock {
    fn now(&self) -> u64 {
        self.now.load(Ordering::SeqCst)
    }

    fn wait_until(&self, t: u64) {
        // fetch_max: a target in the past never rewinds the clock
        self.now.fetch_max(t, Ordering::SeqCst);
    }

    fn is_simulated(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_jumps_and_never_rewinds() {
        let c = SimClock::new();
        assert_eq!(c.now(), 0);
        assert!(c.is_simulated());
        c.wait_until(500);
        assert_eq!(c.now(), 500);
        c.wait_until(100); // past: no-op
        assert_eq!(c.now(), 500);
        c.wait_until(501);
        assert_eq!(c.now(), 501);
    }

    #[test]
    fn real_clock_is_monotonic_and_waits() {
        let c = RealClock::new();
        let a = c.now();
        // 2ms in ticks
        c.wait_until(a + 2_000);
        let b = c.now();
        assert!(b >= a + 2_000, "wait_until returned early: {a} -> {b}");
        assert!(!c.is_simulated());
    }

    #[test]
    fn tick_conversion() {
        assert_eq!(ticks_to_secs(TICKS_PER_SEC), 1.0);
        assert_eq!(ticks_to_secs(0), 0.0);
        assert!((ticks_to_secs(250_000) - 0.25).abs() < 1e-12);
    }
}
