//! Live-arrival priority scheduler over the request batcher.
//!
//! `Batcher::run` drains a burst that already arrived; production traffic
//! *arrives over time*. This module adds the arrival loop: requests carry
//! an arrival tick and a [`Priority`] class, admission capacity is
//! **re-credited** as drain cycles complete (the bounded queue limits rows
//! *currently waiting*, not rows-per-burst as `Batcher::with_queue_cap`
//! does), and each cycle drains the highest-scoring pending requests into
//! one `Batcher::run` call.
//!
//! Scheduling score: `class weight + aging * wait_ticks`. Higher classes go
//! first, but any waiting request's score grows without bound, so no class
//! starves: a Background arrival overtakes a fresh Interactive one after
//! `(w_interactive - w_background) / aging` ticks. Per cycle the scheduler
//! drains a strict *prefix* of the score order (the top request always
//! goes, then more while they fit the row budget), which keeps the
//! ordering invariant exact: everything dispatched in a cycle outranks
//! everything left pending at that cycle's decision time.
//!
//! Determinism: all decisions read time through [`Clock`] ticks. Under
//! [`super::clock::SimClock`] the loop advances time itself — to the next
//! arrival while idle, then by a fixed modeled cost per window dispatch —
//! so a seeded trace replays to bitwise-identical responses and identical
//! admission/ordering decisions for any dispatch lane count; there is no
//! wall clock anywhere in the decision path. `rust/tests/scheduler.rs`
//! asserts exactly that, plus conservation and starvation-freedom
//! invariants over seeded traces.
//!
//! Observability + SLO control: [`Scheduler::run_with_metrics`] records
//! admission counters, per-class queue/service/latency histograms and
//! alert events into a shared [`ServeMetrics`], and — when
//! [`SchedulerCfg::slo_p99_ticks`] is set — drives an [`SloController`]
//! that sheds Background arrivals (and stops aging pending Background)
//! while the Interactive p99 estimate violates its target, recovering
//! with hysteresis. All controller inputs are modeled ticks and histogram
//! deltas, both lane-count independent, so the shed/recover alert
//! sequence replays bitwise under [`super::clock::SimClock`] at any
//! `dispatch` — `rust/tests/scheduler.rs` asserts that too. With the SLO
//! disabled (the default) the decision path is byte-identical to the
//! pre-metrics scheduler.

use anyhow::{ensure, Result};

use super::batcher::{
    Batcher, ClassLat, Request, RequestKind, Response, RowExecutor, ServeStats, WorkRow,
};
use super::clock::{ticks_to_secs, Clock};
use super::metrics::{percentile, AlertKind, ServeMetrics, SloCfg, SloController};

/// Request priority classes, highest first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive traffic (highest base score).
    Interactive,
    /// Throughput traffic (default class).
    Batch,
    /// Best-effort traffic (lowest base score; aging prevents starvation).
    Background,
}

impl Priority {
    /// All classes, highest priority first (the weight-array order).
    pub const ALL: [Priority; 3] = [Priority::Interactive, Priority::Batch, Priority::Background];

    /// Position of this class in [`Priority::ALL`] / the weight array.
    pub fn index(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
            Priority::Background => 2,
        }
    }

    /// Lower-case class name used in reports and JSON.
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
            Priority::Background => "background",
        }
    }
}

/// One trace entry: `request` becomes visible `at` ticks after the run
/// starts (offsets, not absolute times, so the same trace replays under
/// any clock).
#[derive(Clone, Debug)]
pub struct Arrival {
    /// Arrival offset in ticks from run start.
    pub at: u64,
    /// Priority class of the request.
    pub class: Priority,
    /// The request itself.
    pub request: Request,
}

/// Scheduler configuration. Defaults: unlimited queue, serial dispatch,
/// 3:2:1 class weights with 1 score/tick aging (Background overtakes a
/// fresh Interactive after 200ms of simulated waiting), 1ms modeled
/// service per window dispatch.
#[derive(Clone, Debug)]
pub struct SchedulerCfg {
    /// Bound on rows *currently queued* (`None` = unlimited). Unlike
    /// `Batcher::with_queue_cap` (per offered burst), capacity here is
    /// re-credited when a drain cycle dispatches the rows.
    pub queue_cap: Option<usize>,
    /// Max rows drained per cycle; 0 = four executor batches. The default
    /// is deliberately independent of `dispatch`: cycle composition (and
    /// with it every admission/ordering decision) must not change with the
    /// lane count. Raise it explicitly to feed more than four lanes.
    pub drain_rows: usize,
    /// Dispatch lanes handed to the inner batcher per cycle.
    pub dispatch: usize,
    /// Base score per class, in [`Priority::ALL`] order (Interactive,
    /// Batch, Background). Must be non-increasing to mean anything.
    pub weights: [u64; 3],
    /// Score gained per tick of waiting (0 = strict priority, may starve).
    pub aging: u64,
    /// Modeled ticks per window dispatch under a simulated clock. A real
    /// clock ignores this and uses measured time.
    pub service_ticks_per_dispatch: u64,
    /// Interactive end-to-end p99 SLO target in ticks (`--slo-p99-ms`).
    /// `None` (the default) disables the SLO controller entirely — no
    /// shedding, no SLO alerts, decisions byte-identical to earlier
    /// revisions. When set, an [`SloController`] watches the Interactive
    /// latency histogram and sheds Background load on violation.
    pub slo_p99_ticks: Option<u64>,
    /// Minimum Interactive latency samples per controller evaluation
    /// window (smaller deltas keep accumulating).
    pub slo_min_samples: u64,
    /// Consecutive healthy controller windows required before shedding
    /// stops (recovery hysteresis).
    pub slo_recover_cycles: u32,
    /// Push a [`MetricsSnapshot`](super::metrics::MetricsSnapshot) into
    /// the metrics instance at least this many ticks apart
    /// (`--metrics-interval`); `None` disables periodic snapshots.
    pub metrics_interval_ticks: Option<u64>,
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        Self {
            queue_cap: None,
            drain_rows: 0,
            dispatch: 1,
            weights: [300_000, 200_000, 100_000],
            aging: 1,
            service_ticks_per_dispatch: 1_000,
            slo_p99_ticks: None,
            slo_min_samples: 8,
            slo_recover_cycles: 3,
            metrics_interval_ticks: None,
        }
    }
}

/// One entry per trace request: what the scheduler decided and when.
/// Tests replay traces and assert invariants over this log.
#[derive(Clone, Debug, PartialEq)]
pub struct Decision {
    /// Trace index of the request this decision is about.
    pub seq: usize,
    /// Priority class of the request.
    pub class: Priority,
    /// Arrival in clock ticks (absolute, i.e. run start + trace offset).
    pub arrival: u64,
    /// Rows the request spans.
    pub rows: usize,
    /// Whether admission accepted the request.
    pub admitted: bool,
    /// Whether the SLO controller shed the request at admission (a shed
    /// request is never admitted and its response stays
    /// [`Response::Rejected`]; distinct from a queue-capacity reject).
    pub shed: bool,
    /// Drain cycle that dispatched it; `usize::MAX` if never dispatched
    /// (rejected requests stay that way).
    pub cycle: usize,
    /// Tick the drain cycle picked the request up.
    pub dispatch_time: u64,
    /// Tick its cycle's service completed.
    pub complete_time: u64,
}

/// Everything a live run produces: responses in trace order (rejected
/// slots hold [`Response::Rejected`]), aggregate stats with per-class
/// latency folded in, and the full decision log.
#[derive(Clone, Debug)]
pub struct LiveOutcome {
    /// One response per trace request, in trace order.
    pub responses: Vec<Response>,
    /// Aggregate throughput + per-class latency stats.
    pub stats: ServeStats,
    /// The full decision log, in trace order.
    pub decisions: Vec<Decision>,
    /// Number of drain cycles the run took.
    pub cycles: usize,
}

/// The live arrival loop: admits trace arrivals against a re-credited row
/// budget and drains by priority score each cycle.
pub struct Scheduler<'c> {
    /// Scheduling parameters (weights, aging, budgets).
    pub cfg: SchedulerCfg,
    clock: &'c dyn Clock,
}

impl<'c> Scheduler<'c> {
    /// Build a scheduler over `clock` (simulated or real) with `cfg`.
    pub fn new(clock: &'c dyn Clock, cfg: SchedulerCfg) -> Self {
        Self { cfg, clock }
    }

    fn score(&self, d: &Decision, now: u64, shed_bg: bool) -> u64 {
        // while the SLO controller sheds, pending Background stops aging
        // (ages down relative to everyone else): Interactive/Batch drain
        // first until the tail recovers
        let age = if shed_bg && d.class == Priority::Background {
            0
        } else {
            now.saturating_sub(d.arrival)
        };
        self.cfg.weights[d.class.index()].saturating_add(self.cfg.aging.saturating_mul(age))
    }

    /// Run the trace to completion: every arrival is admitted, shed, or
    /// rejected exactly once, and every admitted request is dispatched.
    pub fn run(&self, exec: &dyn RowExecutor, trace: &[Arrival]) -> Result<LiveOutcome> {
        self.run_with_metrics(exec, trace, None)
    }

    /// [`Self::run`], recording into `metrics` (counters, per-class
    /// histograms, alerts, periodic snapshots) and driving the SLO
    /// controller when [`SchedulerCfg::slo_p99_ticks`] is set. With
    /// `None`, a throwaway local instance absorbs the recording — the
    /// decision path is identical either way.
    pub fn run_with_metrics(
        &self,
        exec: &dyn RowExecutor,
        trace: &[Arrival],
        metrics: Option<&ServeMetrics>,
    ) -> Result<LiveOutcome> {
        for w in trace.windows(2) {
            ensure!(w[0].at <= w[1].at, "trace arrivals must be time-sorted");
        }
        let lanes = self.cfg.dispatch.max(1);
        let batcher = Batcher::coalescing(exec).with_dispatch(lanes);
        let cap_rows = exec.batch_rows().max(1);
        // lane-count-independent default: decisions must be identical for
        // any `dispatch`, so the budget must not scale with `lanes`
        let drain_rows =
            if self.cfg.drain_rows == 0 { cap_rows * 4 } else { self.cfg.drain_rows };

        // with no caller-supplied metrics a throwaway instance absorbs the
        // recording, so the decision path never branches on `metrics`
        let own = ServeMetrics::new();
        let m = metrics.unwrap_or(&own);
        let mut ctl = self.cfg.slo_p99_ticks.map(|t| {
            let mut c = SloController::new(SloCfg {
                p99_target_ticks: t.max(1),
                min_samples: self.cfg.slo_min_samples.max(1),
                recover_cycles: self.cfg.slo_recover_cycles.max(1),
            });
            // re-baseline on whatever the metrics instance already holds:
            // historical samples must not count toward the first window
            c.prime(m);
            c
        });
        let snap_iv = self.cfg.metrics_interval_ticks.map(|iv| iv.max(1));
        let mut stale_active = false;
        let mut collapse_active = false;

        let start = self.clock.now();
        let mut next_snap = snap_iv.map(|iv| start + iv);
        let mut decisions: Vec<Decision> = trace
            .iter()
            .enumerate()
            .map(|(i, a)| Decision {
                seq: i,
                class: a.class,
                arrival: start + a.at,
                rows: a.request.rows.len(),
                admitted: false,
                shed: false,
                cycle: usize::MAX,
                dispatch_time: 0,
                complete_time: 0,
            })
            .collect();
        let mut responses = vec![Response::Rejected; trace.len()];
        // seq ids of admitted, not-yet-dispatched requests
        let mut pending: Vec<usize> = Vec::new();
        let mut queued_rows = 0usize;
        let mut next_ev = 0usize;
        let mut agg =
            ServeStats { requests: trace.len(), dispatch_lanes: lanes, ..Default::default() };
        let mut cycles = 0usize;

        while next_ev < trace.len() || !pending.is_empty() {
            if pending.is_empty() {
                // idle: jump (sim) / sleep (real) to the next arrival
                self.clock.wait_until(start + trace[next_ev].at);
            }
            let now = self.clock.now();

            // admit every arrival due by `now`, whole-request-or-not,
            // against the rows currently queued (re-credited below)
            while next_ev < trace.len() && start + trace[next_ev].at <= now {
                let a = &trace[next_ev];
                let rows = a.request.rows.len();
                ensure!(rows > 0, "trace request {next_ev} has no rows");
                m.add_offered(1);
                // SLO shedding comes before capacity: a shed request never
                // occupies queue rows, and is counted apart from rejects
                let shedding = ctl.as_ref().map(|c| c.shedding()).unwrap_or(false);
                if shedding && a.class == Priority::Background {
                    decisions[next_ev].shed = true;
                    agg.shed += 1;
                    m.add_shed(1);
                    next_ev += 1;
                    continue;
                }
                let admit = match self.cfg.queue_cap {
                    Some(c) => queued_rows + rows <= c,
                    None => true,
                };
                if admit {
                    decisions[next_ev].admitted = true;
                    pending.push(next_ev);
                    queued_rows += rows;
                    m.add_admitted(1);
                } else {
                    agg.rejected += 1;
                    m.add_rejected(1);
                }
                next_ev += 1;
            }
            if pending.is_empty() {
                continue;
            }

            // queue-staleness alert: rising edge when the oldest pending
            // request has waited more than 2x the p99 target
            if let Some(target) = self.cfg.slo_p99_ticks {
                let oldest = pending.iter().map(|&s| decisions[s].arrival).min().unwrap_or(now);
                let age = now.saturating_sub(oldest);
                let stale = age > 2 * target.max(1);
                if stale && !stale_active {
                    m.alert(
                        AlertKind::QueueStale,
                        now,
                        format!("oldest pending waited {age}t > 2x p99 target {target}t"),
                    );
                }
                stale_active = stale;
            }

            // rank pending by score (desc), then seq (asc): a deterministic
            // total order — ties never depend on queue insertion history
            let shed_bg = ctl.as_ref().map(|c| c.shedding()).unwrap_or(false);
            pending.sort_by(|&a, &b| {
                self.score(&decisions[b], now, shed_bg)
                    .cmp(&self.score(&decisions[a], now, shed_bg))
                    .then(a.cmp(&b))
            });
            // drain a strict prefix: the top request always goes (even if
            // it alone exceeds the budget — the batcher chunks it), then
            // more while they fit; stopping at the first non-fit keeps
            // "dispatched this cycle outranks everything left" exact
            let mut used = 0usize;
            let mut n_take = 0usize;
            for &seq in pending.iter() {
                let r = decisions[seq].rows;
                if n_take > 0 && used + r > drain_rows {
                    break;
                }
                n_take += 1;
                used += r;
                if used >= drain_rows {
                    break;
                }
            }
            let selected: Vec<usize> = pending.drain(..n_take).collect();
            // occupancy-collapse alert: rising edge when a cycle drains
            // under a quarter of one executor batch while work is pending
            // (oversized requests fragmenting the strict-prefix drain)
            let collapsed = !pending.is_empty() && used * 4 < cap_rows;
            if collapsed && !collapse_active {
                m.alert(
                    AlertKind::OccupancyCollapse,
                    now,
                    format!(
                        "drained {used} rows (< 1/4 of batch {cap_rows}) with {} pending",
                        pending.len()
                    ),
                );
            }
            collapse_active = collapsed;
            let reqs: Vec<Request> =
                selected.iter().map(|&s| trace[s].request.clone()).collect();
            let (resp, st) = batcher.run(exec, &reqs)?;

            // service time: modeled under simulation (deterministic — a
            // pure function of the dispatch count, which is itself
            // lane-independent), measured under a real clock
            if self.clock.is_simulated() {
                let ticks = (st.dispatches as u64).max(1)
                    * self.cfg.service_ticks_per_dispatch.max(1);
                self.clock.wait_until(now + ticks);
            }
            let done = self.clock.now().max(now + 1);

            for (&seq, r) in selected.iter().zip(resp) {
                responses[seq] = r;
                let d = &mut decisions[seq];
                d.cycle = cycles;
                d.dispatch_time = now;
                d.complete_time = done;
                queued_rows -= d.rows; // re-credit admission capacity
                m.record_queue(d.class, now.saturating_sub(d.arrival));
                m.record_service(d.class, done.saturating_sub(now));
                m.record_latency(d.class, done.saturating_sub(d.arrival));
            }
            cycles += 1;
            m.add_dispatches(st.dispatches as u64);
            m.add_tokens(st.tokens as u64);
            m.add_cycles(1);
            if let Some(c) = ctl.as_mut() {
                if let Some((kind, detail)) = c.evaluate(m) {
                    m.alert(kind, done, detail);
                }
            }
            if let (Some(iv), Some(ns)) = (snap_iv, next_snap) {
                if done >= ns {
                    m.push_snapshot(done);
                    next_snap = Some(done + iv);
                }
            }

            agg.dispatches += st.dispatches;
            agg.rows += st.rows;
            agg.row_capacity += st.row_capacity;
            agg.tokens += st.tokens;
            // lane busy-time is *measured* wall time; under a simulated
            // clock wall_seconds is modeled ticks, and mixing the two time
            // bases would make lane_occupancy() meaningless — leave it (and
            // lane_occupancy) at 0 there: "not measured"
            if !self.clock.is_simulated() {
                agg.lane_busy_seconds += st.lane_busy_seconds;
            }
            agg.peak_in_flight = agg.peak_in_flight.max(st.peak_in_flight);
        }

        agg.wall_seconds = ticks_to_secs(self.clock.now().saturating_sub(start));
        agg.class_lat = class_latency(&decisions);
        Ok(LiveOutcome { responses, stats: agg, decisions, cycles })
    }
}

/// Fold the decision log into per-class latency stats (all three classes
/// always present, so reports and CI assertions can key by name).
fn class_latency(decisions: &[Decision]) -> Vec<ClassLat> {
    Priority::ALL
        .iter()
        .map(|&c| {
            let mut queue: Vec<u64> = Vec::new();
            let mut service: Vec<u64> = Vec::new();
            let (mut submitted, mut rejected) = (0usize, 0usize);
            for d in decisions.iter().filter(|d| d.class == c) {
                submitted += 1;
                if !d.admitted {
                    rejected += 1;
                    continue;
                }
                if d.cycle == usize::MAX {
                    continue; // admitted but never drained: impossible on a
                              // completed run, skip defensively
                }
                queue.push(d.dispatch_time.saturating_sub(d.arrival));
                service.push(d.complete_time.saturating_sub(d.dispatch_time));
            }
            queue.sort_unstable();
            service.sort_unstable();
            ClassLat {
                class: c.name().to_string(),
                submitted,
                completed: queue.len(),
                rejected,
                queue_p50_s: ticks_to_secs(percentile(&queue, 0.50)),
                queue_p95_s: ticks_to_secs(percentile(&queue, 0.95)),
                queue_p99_s: ticks_to_secs(percentile(&queue, 0.99)),
                service_p50_s: ticks_to_secs(percentile(&service, 0.50)),
                service_p95_s: ticks_to_secs(percentile(&service, 0.95)),
                service_p99_s: ticks_to_secs(percentile(&service, 0.99)),
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// seeded synthetic arrival traces
// ---------------------------------------------------------------------------

/// Deterministic 64-bit LCG (Knuth MMIX constants) — the only randomness
/// source in the trace generator; no wall clock anywhere.
#[derive(Clone, Debug)]
pub struct Lcg(u64);

impl Lcg {
    /// Seed the generator (the seed is pre-mixed so 0/1/2 diverge).
    pub fn new(seed: u64) -> Self {
        // splash the seed so 0/1/2 don't produce near-identical streams
        Self(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0xD1B5_4A32_D192_ED03))
    }

    /// Next raw 64-bit state.
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0
    }

    /// Uniform in `[0, n)` using the high bits (the strong ones in an LCG).
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        (self.next_u64() >> 33) % n
    }
}

/// Trace-generation parameters for [`synth_trace`].
#[derive(Clone, Debug)]
pub struct TraceSpec {
    /// Seed of the one LCG behind gaps, classes and content.
    pub seed: u64,
    /// Number of arrivals to generate.
    pub requests: usize,
    /// Mean inter-arrival gap in ticks, uniform in `[1, 2*mean]`
    /// (0 = the whole trace arrives at t=0).
    pub mean_gap_ticks: u64,
    /// Row length every request must match (the executor's `seq`).
    pub seq: usize,
    /// Token ids are drawn below this bound (the serving model's vocab).
    pub vocab: u32,
    /// Mix Interactive/Batch/Background 50/30/20 vs all-Batch.
    pub priorities: bool,
}

fn synth_tokens(rng: &mut Lcg, n: usize, vocab: u32) -> Vec<u32> {
    (0..n).map(|_| rng.below(vocab.max(2) as u64) as u32).collect()
}

/// One synthetic request: ~50% perplexity, ~25% choice (two candidate
/// rows), ~25% hidden. Content is a pure function of the LCG state.
pub fn synth_request(rng: &mut Lcg, seq: usize, vocab: u32) -> Request {
    match rng.below(4) {
        0 => Request {
            kind: RequestKind::Hidden,
            rows: vec![WorkRow::from_tokens(&synth_tokens(rng, seq + 1, vocab), 0)],
        },
        1 => {
            let correct = rng.below(2) as usize;
            let rows = (0..2)
                .map(|_| WorkRow::from_tokens(&synth_tokens(rng, seq + 1, vocab), seq / 2))
                .collect();
            Request { kind: RequestKind::Choice { correct }, rows }
        }
        _ => Request {
            kind: RequestKind::Ppl,
            rows: vec![WorkRow::from_tokens(&synth_tokens(rng, seq + 1, vocab), 0)],
        },
    }
}

/// Generate a time-sorted arrival trace. Same spec => bitwise-identical
/// trace: arrivals, classes, and request token content all come from one
/// seeded LCG.
pub fn synth_trace(spec: &TraceSpec) -> Vec<Arrival> {
    let mut rng = Lcg::new(spec.seed);
    let mut at = 0u64;
    let mut out = Vec::with_capacity(spec.requests);
    for _ in 0..spec.requests {
        if spec.mean_gap_ticks > 0 {
            at += 1 + rng.below(2 * spec.mean_gap_ticks);
        }
        let class = if spec.priorities {
            match rng.below(10) {
                0..=4 => Priority::Interactive,
                5..=7 => Priority::Batch,
                _ => Priority::Background,
            }
        } else {
            Priority::Batch
        };
        let request = synth_request(&mut rng, spec.seq, spec.vocab);
        out.push(Arrival { at, class, request });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lcg_is_deterministic_and_bounded() {
        let mut a = Lcg::new(42);
        let mut b = Lcg::new(42);
        for _ in 0..100 {
            let x = a.below(17);
            assert_eq!(x, b.below(17));
            assert!(x < 17);
        }
        let mut c = Lcg::new(43);
        let diverged = (0..10).any(|_| a.next_u64() != c.next_u64());
        assert!(diverged, "different seeds must produce different streams");
        assert_eq!(Lcg::new(1).below(0), 0, "below(0) must not divide by zero");
    }

    #[test]
    fn synth_trace_is_deterministic_sorted_and_well_formed() {
        let spec = TraceSpec {
            seed: 9,
            requests: 40,
            mean_gap_ticks: 250,
            seq: 6,
            vocab: 50,
            priorities: true,
        };
        let a = synth_trace(&spec);
        let b = synth_trace(&spec);
        assert_eq!(a.len(), 40);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.class, y.class);
            assert_eq!(x.request.rows.len(), y.request.rows.len());
            for (rx, ry) in x.request.rows.iter().zip(&y.request.rows) {
                assert_eq!(rx.inputs, ry.inputs);
                assert_eq!(rx.targets, ry.targets);
                assert_eq!(rx.mask, ry.mask);
            }
        }
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at, "trace must be time-sorted");
        }
        for ev in &a {
            for row in &ev.request.rows {
                assert_eq!(row.inputs.len(), 6);
                assert!(row.inputs.iter().all(|&t| (t as u32) < 50));
            }
        }
        // a different seed changes the trace
        let c = synth_trace(&TraceSpec { seed: 10, ..spec });
        let same = a
            .iter()
            .zip(&c)
            .all(|(x, y)| x.at == y.at && x.request.rows[0].inputs == y.request.rows[0].inputs);
        assert!(!same, "seed must matter");
    }

    #[test]
    fn trace_without_priorities_is_all_batch() {
        let spec = TraceSpec {
            seed: 3,
            requests: 16,
            mean_gap_ticks: 100,
            seq: 4,
            vocab: 20,
            priorities: false,
        };
        assert!(synth_trace(&spec).iter().all(|a| a.class == Priority::Batch));
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100];
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.95), 100);
        assert_eq!(percentile(&v, 0.99), 100);
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&[7], 0.5), 7);
        assert_eq!(percentile(&[], 0.99), 0);
    }

    #[test]
    fn priority_index_and_names_align() {
        for (i, p) in Priority::ALL.iter().enumerate() {
            assert_eq!(p.index(), i);
        }
        assert_eq!(Priority::Interactive.name(), "interactive");
        assert_eq!(Priority::Batch.name(), "batch");
        assert_eq!(Priority::Background.name(), "background");
    }
}
