//! Model registry: loads CBQS snapshots by name/path and caches the
//! loaded models for the serving engine.
//!
//! Loading a snapshot eagerly is the expensive part of cold-start
//! (dequantize + qstate reconstruction); the registry makes it a one-time
//! cost per model name, so a serve process can host several quantized
//! variants (W4A16, W2A16*, ...) of the same base architecture side by
//! side and route requests by name.
//!
//! [`LoadMode::Mmap`] is the larger-than-RAM alternative: the snapshot is
//! opened as a [`SnapshotModel::Lazy`] view over a shared memory mapping —
//! cold-start drops to a metadata parse, and engines bound to the model
//! fault windows in on demand (see [`crate::serve::ServeEngine`]). Because
//! the registry caches by name, **every engine sharing a name shares one
//! mapping of the file** (asserted in `rust/tests/mmap.rs`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::clock::{ticks_to_secs, Clock, RealClock};
use crate::snapshot::{self, SnapshotMeta, SnapshotModel};

/// How the registry should load a snapshot file.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum LoadMode {
    /// Decode the whole model into RAM up front (classic behavior).
    #[default]
    Eager,
    /// Memory-map the file and materialize tensors on demand (positional
    /// reads where mapping is unavailable; v1 frames degrade to an
    /// in-memory source). CLI: `cbq serve-bench --mmap`.
    Mmap,
}

/// One resident model.
pub struct LoadedSnapshot {
    /// Registry key.
    pub name: String,
    /// Canonicalized source path.
    pub path: PathBuf,
    /// Parsed header metadata.
    pub meta: SnapshotMeta,
    /// The model in its residency mode (eager or lazy).
    pub model: SnapshotModel,
    /// Snapshot file size in bytes.
    pub file_bytes: u64,
    /// Wall-clock cost of the load (eager: full decode; mmap: metadata
    /// parse + checksum only — the cold-start win the bench measures).
    pub load_seconds: f64,
}

impl LoadedSnapshot {
    /// Was this snapshot opened lazily ([`LoadMode::Mmap`])?
    pub fn is_lazy(&self) -> bool {
        self.model.is_lazy()
    }
}

/// Name-keyed snapshot cache.
#[derive(Default)]
pub struct ModelRegistry {
    models: BTreeMap<String, Arc<LoadedSnapshot>>,
}

impl ModelRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// [`ModelRegistry::load_with`] in [`LoadMode::Eager`].
    pub fn load(&mut self, name: &str, path: impl AsRef<Path>) -> Result<Arc<LoadedSnapshot>> {
        self.load_with(name, path, LoadMode::Eager)
    }

    /// Load `path` under `name`, or return the cached model if `name` is
    /// already resident (the path must then match — two different files
    /// under one name is a routing bug, not a cache hit; the *mode* of the
    /// first load wins, so all engines of a name share one representation
    /// and, for mmap, one mapping). The handle is an `Arc`: engines on any
    /// thread share the one resident copy, and the Arc-backed tensor
    /// storage keeps even pinned backend inputs pointing at the same
    /// buffers.
    pub fn load_with(
        &mut self,
        name: &str,
        path: impl AsRef<Path>,
        mode: LoadMode,
    ) -> Result<Arc<LoadedSnapshot>> {
        // canonicalize so "./m.cbqs" and its absolute path count as the same
        // file; fall back to the raw path when the file does not exist yet
        // (snapshot::load will produce the real error below)
        let raw = path.as_ref().to_path_buf();
        let path = raw.canonicalize().unwrap_or(raw);
        if let Some(hit) = self.models.get(name) {
            if hit.path != path {
                bail!(
                    "model `{name}` already resident from {:?}; refusing to shadow with {:?}",
                    hit.path,
                    path
                );
            }
            return Ok(hit.clone());
        }
        // cold-start timing goes through the serve-layer Clock abstraction
        // (real ticks here; loading is outside the scheduling decision path)
        let clock = RealClock::new();
        let t0 = clock.now();
        let file_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        let (meta, model) = match mode {
            LoadMode::Eager => {
                let snap = snapshot::load(&path)?;
                (snap.meta, SnapshotModel::Eager(snap.model))
            }
            LoadMode::Mmap => {
                let snap = snapshot::load_lazy(&path)?;
                (snap.meta, SnapshotModel::Lazy(snap.model))
            }
        };
        let loaded = Arc::new(LoadedSnapshot {
            name: name.to_string(),
            path,
            meta,
            model,
            file_bytes,
            load_seconds: ticks_to_secs(clock.now().saturating_sub(t0)),
        });
        self.models.insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Fetch a resident model by name.
    pub fn get(&self, name: &str) -> Result<Arc<LoadedSnapshot>> {
        self.models
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("no model `{name}` in registry (resident: {:?})", self.names()))
    }

    /// Names of every resident model.
    pub fn names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    /// Number of resident models.
    pub fn len(&self) -> usize {
        self.models.len()
    }

    /// Is the registry empty?
    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Drop a resident model; returns whether it was present.
    pub fn evict(&mut self, name: &str) -> bool {
        self.models.remove(name).is_some()
    }
}
