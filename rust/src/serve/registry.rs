//! Model registry: loads CBQS snapshots by name/path and caches the
//! reconstructed models for the serving engine.
//!
//! Loading a snapshot is the expensive part of cold-start (dequantize +
//! qstate reconstruction); the registry makes it a one-time cost per model
//! name, so a serve process can host several quantized variants (W4A16,
//! W2A16*, ...) of the same base architecture side by side and route
//! requests by name.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::clock::{ticks_to_secs, Clock, RealClock};
use crate::snapshot::{self, SnapshotMeta};
use crate::coordinator::QuantizedModel;

/// One resident model.
pub struct LoadedSnapshot {
    pub name: String,
    pub path: PathBuf,
    pub meta: SnapshotMeta,
    pub model: QuantizedModel,
    pub file_bytes: u64,
    pub load_seconds: f64,
}

/// Name-keyed snapshot cache.
#[derive(Default)]
pub struct ModelRegistry {
    models: BTreeMap<String, Arc<LoadedSnapshot>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Load `path` under `name`, or return the cached model if `name` is
    /// already resident (the path must then match — two different files
    /// under one name is a routing bug, not a cache hit). The handle is an
    /// `Arc`: engines on any thread share the one resident copy, and the
    /// Arc-backed tensor storage keeps even pinned backend inputs pointing
    /// at the same buffers.
    pub fn load(&mut self, name: &str, path: impl AsRef<Path>) -> Result<Arc<LoadedSnapshot>> {
        // canonicalize so "./m.cbqs" and its absolute path count as the same
        // file; fall back to the raw path when the file does not exist yet
        // (snapshot::load will produce the real error below)
        let raw = path.as_ref().to_path_buf();
        let path = raw.canonicalize().unwrap_or(raw);
        if let Some(hit) = self.models.get(name) {
            if hit.path != path {
                bail!(
                    "model `{name}` already resident from {:?}; refusing to shadow with {:?}",
                    hit.path,
                    path
                );
            }
            return Ok(hit.clone());
        }
        // cold-start timing goes through the serve-layer Clock abstraction
        // (real ticks here; loading is outside the scheduling decision path)
        let clock = RealClock::new();
        let t0 = clock.now();
        let file_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        let snap = snapshot::load(&path)?;
        let loaded = Arc::new(LoadedSnapshot {
            name: name.to_string(),
            path,
            meta: snap.meta,
            model: snap.model,
            file_bytes,
            load_seconds: ticks_to_secs(clock.now().saturating_sub(t0)),
        });
        self.models.insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    pub fn get(&self, name: &str) -> Result<Arc<LoadedSnapshot>> {
        self.models
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("no model `{name}` in registry (resident: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Drop a resident model; returns whether it was present.
    pub fn evict(&mut self, name: &str) -> bool {
        self.models.remove(name).is_some()
    }
}
