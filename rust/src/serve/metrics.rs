//! Always-on serve metrics: cheap shared counters, fixed-bucket latency
//! histograms, residency gauges, JSON-able alert events, and the SLO
//! controller that closes the loop.
//!
//! Design (modeled on pg-stream's `monitor.rs`): every hot-path update is
//! a single relaxed atomic add — recording a latency sample indexes a
//! power-of-two bucket and bumps one `AtomicU64`, so the layer can stay on
//! in production serving without a measurable tax (CI gates < 5% on serve
//! tokens/s). Everything cold (alerts, snapshots, residency samples) sits
//! behind mutexes touched once per drain cycle at most.
//!
//! All times are [`Clock`](super::clock::Clock) ticks (1 µs). Histogram
//! buckets are powers of two, so a percentile estimate returns the upper
//! bound of the bucket the nearest-rank sample landed in — an
//! overestimate by at most 2x, deterministic, and identical under the
//! simulated and real clocks given the same tick sequence.
//!
//! The [`SloController`] consumes the Interactive *latency* histogram
//! (arrival → complete) in deltas between evaluations: when the rolling
//! window's p99 estimate exceeds the target it flips to shedding (the
//! scheduler then rejects Background arrivals and stops aging Background
//! pending), and it only recovers after `recover_cycles` consecutive
//! healthy windows — hysteresis, so an oscillating tail doesn't flap the
//! admission policy. Every decision is a pure function of histogram
//! deltas, which are themselves lane-count independent under `SimClock`,
//! so a seeded overload trace replays the identical shed/recover alert
//! sequence at any `--dispatch`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use super::scheduler::Priority;
use super::ResidencyStats;

/// Number of histogram buckets. Bucket `i < 39` covers ticks in
/// `(2^(i-1), 2^i]` (bucket 0 is `[0, 1]`); bucket 39 is the overflow
/// bucket up to `u64::MAX`.
pub const HIST_BUCKETS: usize = 40;

/// Minimum eviction delta between residency samples before the
/// eviction-thrash detector can consider firing.
pub const THRASH_MIN_EVICTIONS: u64 = 4;

/// Upper bounds (inclusive, in ticks) of the histogram buckets, strictly
/// increasing: `1, 2, 4, …, 2^38, u64::MAX`.
pub fn bucket_bounds() -> [u64; HIST_BUCKETS] {
    let mut b = [0u64; HIST_BUCKETS];
    for (i, slot) in b.iter_mut().enumerate() {
        *slot = if i < HIST_BUCKETS - 1 { 1u64 << i } else { u64::MAX };
    }
    b
}

/// Index of the first bucket whose upper bound is >= `v`.
fn bucket_index(v: u64) -> usize {
    if v <= 1 {
        0
    } else {
        ((64 - (v - 1).leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Nearest-rank percentile over a sorted slice (deterministic, no
/// interpolation). Empty input reports 0. This is *the* percentile
/// definition for the whole serve stack — the scheduler's per-class
/// latency stats, the generate loop's per-token percentiles, and the
/// histogram estimates below all share it so the semantics cannot drift.
pub fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Nearest-rank percentile over bucket counts: returns the upper bound of
/// the bucket holding the nearest-rank sample (0 when empty).
fn percentile_of(counts: &[u64; HIST_BUCKETS], p: f64) -> u64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0;
    }
    let rank = ((p * total as f64).ceil() as u64).clamp(1, total);
    let bounds = bucket_bounds();
    let mut cum = 0u64;
    for i in 0..HIST_BUCKETS {
        cum += counts[i];
        if cum >= rank {
            return bounds[i];
        }
    }
    bounds[HIST_BUCKETS - 1]
}

/// Lock a mutex, recovering the data if a panicking thread poisoned it —
/// metrics must never take the serve path down with it.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A fixed-bucket latency histogram in ticks. Recording is one relaxed
/// atomic increment; reads snapshot all buckets relaxed (consistent
/// enough for monitoring — no sample is ever lost or double-counted,
/// only the cross-bucket cut may be mid-update).
#[derive(Debug, Default)]
pub struct LatHistogram {
    counts: [AtomicU64; HIST_BUCKETS],
}

impl LatHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self { counts: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    /// Record one sample of `ticks`.
    pub fn record(&self, ticks: u64) {
        self.counts[bucket_index(ticks)].fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot the bucket counts.
    pub fn counts(&self) -> [u64; HIST_BUCKETS] {
        std::array::from_fn(|i| self.counts[i].load(Ordering::Relaxed))
    }

    /// Total samples recorded.
    pub fn total(&self) -> u64 {
        self.counts().iter().sum()
    }

    /// Nearest-rank percentile estimate: the upper bound (in ticks) of the
    /// bucket the nearest-rank sample fell in; 0 when empty.
    pub fn percentile_ticks(&self, p: f64) -> u64 {
        percentile_of(&self.counts(), p)
    }
}

/// What kind of condition an [`Alert`] reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlertKind {
    /// Oldest pending request has waited more than 2x the p99 target.
    QueueStale,
    /// A drain cycle used under a quarter of the row budget with work
    /// still pending (the batch is starving while demand exists).
    OccupancyCollapse,
    /// The mmap window cache evicted at least [`THRASH_MIN_EVICTIONS`]
    /// windows since the last sample without at least as many cache hits.
    EvictionThrash,
    /// The SLO controller started shedding Background load.
    SloShed,
    /// The SLO controller recovered and stopped shedding.
    SloRecover,
}

impl AlertKind {
    /// Stable lower-snake name used in JSON output.
    pub fn name(self) -> &'static str {
        match self {
            AlertKind::QueueStale => "queue_stale",
            AlertKind::OccupancyCollapse => "occupancy_collapse",
            AlertKind::EvictionThrash => "eviction_thrash",
            AlertKind::SloShed => "slo_shed",
            AlertKind::SloRecover => "slo_recover",
        }
    }
}

/// One alert event: what fired, when (clock ticks), and a human-readable
/// detail string. Deterministic under `SimClock` — seeded overload traces
/// replay the identical alert sequence.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Alert {
    /// The condition that fired.
    pub kind: AlertKind,
    /// Clock tick at which it fired.
    pub at_ticks: u64,
    /// Deterministic human-readable context.
    pub detail: String,
}

/// Pluggable alert delivery. Implementations must be cheap and must not
/// block the serve path (a JSON-lines stderr writer, a test collector, …).
pub trait AlertSink: Send + Sync {
    /// Deliver one alert at emission time (called before the alert is
    /// appended to the in-memory log).
    fn emit(&self, alert: &Alert);
}

/// Per-class histogram summary inside a [`MetricsSnapshot`].
#[derive(Clone, Debug, PartialEq)]
pub struct ClassHist {
    /// Class name (`interactive`/`batch`/`background`).
    pub class: &'static str,
    /// Queue-wait (arrival → dispatch) bucket counts.
    pub queue_counts: Vec<u64>,
    /// Queue-wait p50 estimate in ticks.
    pub queue_p50_ticks: u64,
    /// Queue-wait p99 estimate in ticks.
    pub queue_p99_ticks: u64,
    /// Service (dispatch → complete) bucket counts.
    pub service_counts: Vec<u64>,
    /// Service p50 estimate in ticks.
    pub service_p50_ticks: u64,
    /// Service p99 estimate in ticks.
    pub service_p99_ticks: u64,
    /// End-to-end latency (arrival → complete) bucket counts.
    pub latency_counts: Vec<u64>,
    /// Latency p50 estimate in ticks.
    pub latency_p50_ticks: u64,
    /// Latency p99 estimate in ticks.
    pub latency_p99_ticks: u64,
}

/// A point-in-time copy of every counter, gauge and histogram.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    /// Clock tick the snapshot was taken at.
    pub at_ticks: u64,
    /// Requests offered to admission.
    pub offered: u64,
    /// Requests admitted.
    pub admitted: u64,
    /// Requests rejected (queue capacity).
    pub rejected: u64,
    /// Requests shed by the SLO controller.
    pub shed: u64,
    /// Window dispatches executed.
    pub dispatches: u64,
    /// Tokens processed.
    pub tokens: u64,
    /// Drain/decode cycles completed.
    pub cycles: u64,
    /// Last sampled mmap residency stats, if any were sampled.
    pub residency: Option<ResidencyStats>,
    /// Per-class histogram summaries, in [`Priority::ALL`] order.
    pub classes: Vec<ClassHist>,
    /// Alerts emitted so far.
    pub alerts: usize,
}

#[derive(Debug, Default)]
struct ThrashState {
    last: Option<ResidencyStats>,
    active: bool,
}

/// The shared always-on stats layer. One instance is threaded (by
/// reference or `Arc`) through `Batcher`, `Scheduler` and
/// `GenerateEngine`; all of them record into the same counters.
#[derive(Default)]
pub struct ServeMetrics {
    offered: AtomicU64,
    admitted: AtomicU64,
    rejected: AtomicU64,
    shed: AtomicU64,
    dispatches: AtomicU64,
    tokens: AtomicU64,
    cycles: AtomicU64,
    queue: [LatHistogram; 3],
    service: [LatHistogram; 3],
    latency: [LatHistogram; 3],
    gauge: Mutex<ThrashState>,
    alerts: Mutex<Vec<Alert>>,
    snapshots: Mutex<Vec<MetricsSnapshot>>,
    sink: Option<Box<dyn AlertSink>>,
}

impl std::fmt::Debug for ServeMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServeMetrics")
            .field("offered", &self.offered())
            .field("admitted", &self.admitted())
            .field("rejected", &self.rejected())
            .field("shed", &self.shed())
            .field("dispatches", &self.dispatches())
            .field("tokens", &self.tokens())
            .field("cycles", &self.cycles())
            .finish_non_exhaustive()
    }
}

impl ServeMetrics {
    /// A fresh metrics instance with no alert sink.
    pub fn new() -> Self {
        Self::default()
    }

    /// A fresh metrics instance delivering alerts through `sink` as they
    /// fire (they are also kept in the in-memory log either way).
    pub fn with_sink(sink: Box<dyn AlertSink>) -> Self {
        Self { sink: Some(sink), ..Self::default() }
    }

    /// Count `n` requests offered to admission.
    pub fn add_offered(&self, n: u64) {
        self.offered.fetch_add(n, Ordering::Relaxed);
    }

    /// Requests offered so far.
    pub fn offered(&self) -> u64 {
        self.offered.load(Ordering::Relaxed)
    }

    /// Count `n` requests admitted.
    pub fn add_admitted(&self, n: u64) {
        self.admitted.fetch_add(n, Ordering::Relaxed);
    }

    /// Requests admitted so far.
    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    /// Count `n` requests rejected at admission (queue capacity).
    pub fn add_rejected(&self, n: u64) {
        self.rejected.fetch_add(n, Ordering::Relaxed);
    }

    /// Requests rejected so far.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Count `n` requests shed by the SLO controller.
    pub fn add_shed(&self, n: u64) {
        self.shed.fetch_add(n, Ordering::Relaxed);
    }

    /// Requests shed so far.
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Count `n` window dispatches.
    pub fn add_dispatches(&self, n: u64) {
        self.dispatches.fetch_add(n, Ordering::Relaxed);
    }

    /// Window dispatches so far.
    pub fn dispatches(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// Count `n` tokens processed.
    pub fn add_tokens(&self, n: u64) {
        self.tokens.fetch_add(n, Ordering::Relaxed);
    }

    /// Tokens processed so far.
    pub fn tokens(&self) -> u64 {
        self.tokens.load(Ordering::Relaxed)
    }

    /// Count `n` drain/decode cycles.
    pub fn add_cycles(&self, n: u64) {
        self.cycles.fetch_add(n, Ordering::Relaxed);
    }

    /// Cycles so far.
    pub fn cycles(&self) -> u64 {
        self.cycles.load(Ordering::Relaxed)
    }

    /// Record one queue-wait sample (arrival → dispatch) for `class`.
    pub fn record_queue(&self, class: Priority, ticks: u64) {
        self.queue[class.index()].record(ticks);
    }

    /// Record one service sample (dispatch → complete) for `class`.
    pub fn record_service(&self, class: Priority, ticks: u64) {
        self.service[class.index()].record(ticks);
    }

    /// Record one end-to-end latency sample (arrival → complete) for
    /// `class` — the series the SLO controller watches.
    pub fn record_latency(&self, class: Priority, ticks: u64) {
        self.latency[class.index()].record(ticks);
    }

    /// Snapshot the end-to-end latency bucket counts for `class`.
    pub fn latency_counts(&self, class: Priority) -> [u64; HIST_BUCKETS] {
        self.latency[class.index()].counts()
    }

    /// Feed a residency sample into the gauges and run the eviction-thrash
    /// detector: a rising edge (>= [`THRASH_MIN_EVICTIONS`] evictions
    /// since the previous sample, and at least as many evictions as cache
    /// hits over the same span) emits one [`AlertKind::EvictionThrash`].
    pub fn sample_residency(&self, r: ResidencyStats, at_ticks: u64) {
        let fire = {
            let mut g = lock(&self.gauge);
            let fire = match g.last {
                Some(prev) => {
                    let dev = r.evictions.saturating_sub(prev.evictions);
                    let dh = r.hits.saturating_sub(prev.hits);
                    let thrash = dev >= THRASH_MIN_EVICTIONS && dev >= dh;
                    let rising = thrash && !g.active;
                    g.active = thrash;
                    if rising {
                        Some((dev, dh))
                    } else {
                        None
                    }
                }
                None => None,
            };
            g.last = Some(r);
            fire
        };
        if let Some((dev, dh)) = fire {
            self.alert(
                AlertKind::EvictionThrash,
                at_ticks,
                format!("{dev} evictions vs {dh} hits since last residency sample"),
            );
        }
    }

    /// The most recent residency sample, if any.
    pub fn residency(&self) -> Option<ResidencyStats> {
        lock(&self.gauge).last
    }

    /// Emit one alert: deliver through the sink (if any), then append to
    /// the in-memory log.
    pub fn alert(&self, kind: AlertKind, at_ticks: u64, detail: String) {
        let a = Alert { kind, at_ticks, detail };
        if let Some(s) = &self.sink {
            s.emit(&a);
        }
        lock(&self.alerts).push(a);
    }

    /// All alerts emitted so far, in emission order.
    pub fn alerts(&self) -> Vec<Alert> {
        lock(&self.alerts).clone()
    }

    /// Build a point-in-time snapshot of every counter and histogram.
    pub fn snapshot(&self, at_ticks: u64) -> MetricsSnapshot {
        let classes = Priority::ALL
            .iter()
            .map(|&c| {
                let i = c.index();
                let (q, s, l) =
                    (self.queue[i].counts(), self.service[i].counts(), self.latency[i].counts());
                ClassHist {
                    class: c.name(),
                    queue_p50_ticks: percentile_of(&q, 0.50),
                    queue_p99_ticks: percentile_of(&q, 0.99),
                    queue_counts: q.to_vec(),
                    service_p50_ticks: percentile_of(&s, 0.50),
                    service_p99_ticks: percentile_of(&s, 0.99),
                    service_counts: s.to_vec(),
                    latency_p50_ticks: percentile_of(&l, 0.50),
                    latency_p99_ticks: percentile_of(&l, 0.99),
                    latency_counts: l.to_vec(),
                }
            })
            .collect();
        MetricsSnapshot {
            at_ticks,
            offered: self.offered(),
            admitted: self.admitted(),
            rejected: self.rejected(),
            shed: self.shed(),
            dispatches: self.dispatches(),
            tokens: self.tokens(),
            cycles: self.cycles(),
            residency: self.residency(),
            classes,
            alerts: lock(&self.alerts).len(),
        }
    }

    /// Take a snapshot at `at_ticks` and append it to the periodic log.
    pub fn push_snapshot(&self, at_ticks: u64) {
        let s = self.snapshot(at_ticks);
        lock(&self.snapshots).push(s);
    }

    /// The periodic snapshot log, in push order.
    pub fn snapshots(&self) -> Vec<MetricsSnapshot> {
        lock(&self.snapshots).clone()
    }
}

/// SLO controller parameters.
#[derive(Clone, Copy, Debug)]
pub struct SloCfg {
    /// The Interactive end-to-end p99 target in ticks.
    pub p99_target_ticks: u64,
    /// Minimum new latency samples before an evaluation window closes
    /// (smaller deltas keep accumulating into the same window).
    pub min_samples: u64,
    /// Consecutive healthy windows required to stop shedding (hysteresis).
    pub recover_cycles: u32,
}

impl SloCfg {
    /// A config with the given p99 target and the default window size (8
    /// samples) and hysteresis (3 healthy windows).
    pub fn new(p99_target_ticks: u64) -> Self {
        Self { p99_target_ticks, min_samples: 8, recover_cycles: 3 }
    }
}

/// The SLO feedback loop: watches the Interactive end-to-end latency
/// histogram in deltas and decides when to shed / recover Background
/// load. Purely deterministic — state depends only on the sequence of
/// histogram counts it is shown.
#[derive(Debug)]
pub struct SloController {
    cfg: SloCfg,
    shedding: bool,
    healthy: u32,
    last: [u64; HIST_BUCKETS],
}

impl SloController {
    /// A controller that has seen no samples yet.
    pub fn new(cfg: SloCfg) -> Self {
        Self { cfg, shedding: false, healthy: 0, last: [0; HIST_BUCKETS] }
    }

    /// Re-baseline on `m`'s current Interactive latency counts, so a
    /// controller attached to an already-used metrics instance does not
    /// treat historical samples as its first window.
    pub fn prime(&mut self, m: &ServeMetrics) {
        self.last = m.latency_counts(Priority::Interactive);
    }

    /// Whether Background load should currently be shed.
    pub fn shedding(&self) -> bool {
        self.shedding
    }

    /// Close an evaluation window if enough new Interactive latency
    /// samples arrived, update the shed state machine, and return the
    /// alert to emit on a state change (shed or recover edge).
    pub fn evaluate(&mut self, m: &ServeMetrics) -> Option<(AlertKind, String)> {
        let cur = m.latency_counts(Priority::Interactive);
        let mut delta = [0u64; HIST_BUCKETS];
        let mut total = 0u64;
        for i in 0..HIST_BUCKETS {
            delta[i] = cur[i].saturating_sub(self.last[i]);
            total += delta[i];
        }
        if total < self.cfg.min_samples.max(1) {
            // window not full yet: keep accumulating against the same
            // baseline (do NOT advance `last`)
            return None;
        }
        let p99 = percentile_of(&delta, 0.99);
        self.last = cur;
        if p99 > self.cfg.p99_target_ticks {
            let was = self.shedding;
            self.shedding = true;
            self.healthy = 0;
            if !was {
                return Some((
                    AlertKind::SloShed,
                    format!(
                        "interactive p99 {p99}t > target {}t over {total} samples",
                        self.cfg.p99_target_ticks
                    ),
                ));
            }
        } else if self.shedding {
            self.healthy += 1;
            if self.healthy >= self.cfg.recover_cycles.max(1) {
                self.shedding = false;
                self.healthy = 0;
                return Some((
                    AlertKind::SloRecover,
                    format!(
                        "interactive p99 {p99}t <= target {}t, hysteresis met",
                        self.cfg.p99_target_ticks
                    ),
                ));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v = [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100];
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.95), 100);
        assert_eq!(percentile(&v, 0.99), 100);
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&[7], 0.5), 7);
        assert_eq!(percentile(&[], 0.99), 0);
    }

    #[test]
    fn percentile_empty_and_edge_p_are_pinned() {
        // the fuzz targets fold these values into replay digests, so the
        // empty/edge behavior is contract, not convenience: empty input is
        // exactly 0 for every p (never a panic, never garbage)
        for p in [0.0, 0.5, 0.99, 1.0, 2.0, -1.0, f64::NAN] {
            assert_eq!(percentile(&[], p), 0, "empty slice, p={p}");
        }
        // rank clamps into [1, len]: p=0 (and below) hits the first sample,
        // p>=1 the last
        let v = [10u64, 20, 30];
        assert_eq!(percentile(&v, 0.0), 10);
        assert_eq!(percentile(&v, -0.5), 10);
        assert_eq!(percentile(&v, 1.0), 30);
        assert_eq!(percentile(&v, 7.0), 30);
    }

    #[test]
    fn histogram_percentile_empty_and_edge_p_are_pinned() {
        let h = LatHistogram::new();
        // zero recorded samples: exactly 0 at every p, including the edges
        for p in [0.0, 0.5, 0.99, 1.0, 2.0, -1.0, f64::NAN] {
            assert_eq!(h.percentile_ticks(p), 0, "empty histogram, p={p}");
        }
        // one sample: every p reports that sample's bucket bound (the rank
        // clamps into [1, total])
        h.record(1000); // (512, 1024] bucket
        for p in [0.0, 0.5, 1.0, 3.0] {
            assert_eq!(h.percentile_ticks(p), 1024, "single sample, p={p}");
        }
    }

    #[test]
    fn bucket_bounds_monotone_and_index_maps_into_bounds() {
        let b = bucket_bounds();
        assert_eq!(b.len(), HIST_BUCKETS);
        assert_eq!(b[0], 1);
        assert_eq!(b[39], u64::MAX);
        for w in b.windows(2) {
            assert!(w[0] < w[1], "bounds must be strictly increasing");
        }
        for v in [0u64, 1, 2, 3, 1000, 1024, 1025, 1 << 38, (1 << 38) + 1, u64::MAX] {
            let i = bucket_index(v);
            assert!(v <= b[i], "{v} must fit its bucket bound {}", b[i]);
            if i > 0 {
                assert!(v > b[i - 1], "{v} must not fit the previous bucket");
            }
        }
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_percentile_estimates_bucket_upper_bound() {
        let h = LatHistogram::new();
        assert_eq!(h.percentile_ticks(0.99), 0, "empty histogram reports 0");
        for _ in 0..9 {
            h.record(1000);
        }
        h.record(5000);
        assert_eq!(h.total(), 10);
        // 1000 lands in the (512, 1024] bucket, 5000 in (4096, 8192]
        assert_eq!(h.percentile_ticks(0.50), 1024);
        assert_eq!(h.percentile_ticks(0.99), 8192);
    }

    #[test]
    fn slo_controller_sheds_and_recovers_with_hysteresis() {
        let m = ServeMetrics::new();
        let mut ctl = SloController::new(SloCfg {
            p99_target_ticks: 2000,
            min_samples: 4,
            recover_cycles: 2,
        });
        assert!(!ctl.shedding());
        // window 1: slow → shed edge
        for _ in 0..4 {
            m.record_latency(Priority::Interactive, 5000);
        }
        let a = ctl.evaluate(&m).expect("violation must emit a shed alert");
        assert_eq!(a.0, AlertKind::SloShed);
        assert!(ctl.shedding());
        // window 2: still slow → no second shed alert, streak stays reset
        for _ in 0..4 {
            m.record_latency(Priority::Interactive, 5000);
        }
        assert!(ctl.evaluate(&m).is_none());
        assert!(ctl.shedding());
        // window 3: healthy (1024 <= 2000) → streak 1 of 2, still shedding
        for _ in 0..4 {
            m.record_latency(Priority::Interactive, 1000);
        }
        assert!(ctl.evaluate(&m).is_none());
        assert!(ctl.shedding());
        // window 4: slow again → the healthy streak must reset
        for _ in 0..4 {
            m.record_latency(Priority::Interactive, 5000);
        }
        assert!(ctl.evaluate(&m).is_none());
        // windows 5+6: two consecutive healthy windows → recover edge
        for _ in 0..4 {
            m.record_latency(Priority::Interactive, 1000);
        }
        assert!(ctl.evaluate(&m).is_none());
        for _ in 0..4 {
            m.record_latency(Priority::Interactive, 1000);
        }
        let a = ctl.evaluate(&m).expect("hysteresis met must emit a recover alert");
        assert_eq!(a.0, AlertKind::SloRecover);
        assert!(!ctl.shedding());
    }

    #[test]
    fn slo_controller_accumulates_below_min_samples_and_primes() {
        let m = ServeMetrics::new();
        // historical samples the controller must NOT see as its window
        for _ in 0..10 {
            m.record_latency(Priority::Interactive, 9000);
        }
        let mut ctl = SloController::new(SloCfg {
            p99_target_ticks: 2000,
            min_samples: 4,
            recover_cycles: 2,
        });
        ctl.prime(&m);
        assert!(ctl.evaluate(&m).is_none(), "primed baseline: no new samples");
        // 2 new samples < min_samples: accumulate, window stays open
        m.record_latency(Priority::Interactive, 5000);
        m.record_latency(Priority::Interactive, 5000);
        assert!(ctl.evaluate(&m).is_none());
        // 2 more close the window at 4 samples and trip the target
        m.record_latency(Priority::Interactive, 5000);
        m.record_latency(Priority::Interactive, 5000);
        let a = ctl.evaluate(&m).expect("accumulated window must close");
        assert_eq!(a.0, AlertKind::SloShed);
    }

    #[test]
    fn eviction_thrash_fires_on_rising_edges_only() {
        let m = ServeMetrics::new();
        let base = ResidencyStats::default();
        m.sample_residency(base, 0);
        assert!(m.alerts().is_empty(), "first sample has no delta");
        // spike: 6 evictions, 1 hit → fire
        let spike = ResidencyStats { evictions: 6, hits: 1, ..base };
        m.sample_residency(spike, 100);
        // still thrashing: 6 more evictions, 0 hits → no second alert
        let spike2 = ResidencyStats { evictions: 12, hits: 1, ..base };
        m.sample_residency(spike2, 200);
        // calm: many hits, few evictions → detector disarms
        let calm = ResidencyStats { evictions: 13, hits: 50, ..base };
        m.sample_residency(calm, 300);
        // second spike → second rising edge
        let spike3 = ResidencyStats { evictions: 20, hits: 51, ..base };
        m.sample_residency(spike3, 400);
        let alerts = m.alerts();
        assert_eq!(alerts.len(), 2, "two rising edges, two alerts: {alerts:?}");
        assert!(alerts.iter().all(|a| a.kind == AlertKind::EvictionThrash));
        assert_eq!(alerts[0].at_ticks, 100);
        assert_eq!(alerts[1].at_ticks, 400);
        assert_eq!(m.residency(), Some(spike3), "gauge keeps the latest sample");
    }

    #[test]
    fn counters_and_snapshot_roundtrip() {
        let m = ServeMetrics::new();
        m.add_offered(10);
        m.add_admitted(6);
        m.add_rejected(1);
        m.add_shed(3);
        m.add_dispatches(4);
        m.add_tokens(240);
        m.add_cycles(2);
        m.record_queue(Priority::Batch, 100);
        m.record_service(Priority::Batch, 1000);
        m.record_latency(Priority::Batch, 1100);
        let s = m.snapshot(777);
        assert_eq!(s.at_ticks, 777);
        assert_eq!(
            (s.offered, s.admitted, s.rejected, s.shed),
            (10, 6, 1, 3),
            "conservation fields survive the snapshot"
        );
        assert_eq!((s.dispatches, s.tokens, s.cycles), (4, 240, 2));
        assert_eq!(s.classes.len(), 3);
        assert_eq!(s.classes[1].class, "batch");
        assert_eq!(s.classes[1].queue_counts.iter().sum::<u64>(), 1);
        assert_eq!(s.classes[1].queue_p99_ticks, 128);
        assert_eq!(s.classes[1].service_p99_ticks, 1024);
        assert_eq!(s.classes[1].latency_p99_ticks, 2048);
        assert_eq!(s.classes[0].queue_counts.iter().sum::<u64>(), 0);
        assert!(s.residency.is_none());
        m.push_snapshot(778);
        assert_eq!(m.snapshots().len(), 1);
        assert_eq!(m.snapshots()[0].at_ticks, 778);
    }

    #[test]
    fn sink_receives_alerts_at_emission() {
        struct Collect(std::sync::Arc<Mutex<Vec<(AlertKind, u64)>>>);
        impl AlertSink for Collect {
            fn emit(&self, a: &Alert) {
                lock(&self.0).push((a.kind, a.at_ticks));
            }
        }
        let seen = std::sync::Arc::new(Mutex::new(Vec::new()));
        let m = ServeMetrics::with_sink(Box::new(Collect(seen.clone())));
        m.alert(AlertKind::QueueStale, 5, "old".into());
        m.alert(AlertKind::SloShed, 9, "slow".into());
        let log = m.alerts();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0].kind, AlertKind::QueueStale);
        assert_eq!(log[1].at_ticks, 9);
        assert_eq!(*lock(&seen), vec![(AlertKind::QueueStale, 5), (AlertKind::SloShed, 9)]);
    }
}
