//! Request batcher: coalesces queued eval requests into maximal batches.
//!
//! The executable surface is fixed-shape (`[batch, seq]` rows), so serving
//! throughput is won by *filling* those rows: a perplexity segment, one
//! zero-shot candidate, and a forward-hidden call are all row-shaped work,
//! and the batcher packs rows from different requests into one dispatch.
//! Issuing the same rows one-by-one pays a full dispatch per row (the
//! remaining `batch-1` rows ride along as padding) — the measured
//! batched-vs-sequential gap `cbq serve-bench` reports.
//!
//! This module is deliberately runtime-free: it schedules over the
//! [`RowExecutor`] trait, which the backend-bound engine
//! (`serve::ServeEngine`) implements and tests mock.
//!
//! Dispatch concurrency: [`Batcher::with_dispatch`] hands up to N
//! independent row batches to executor threads at once (the executor is
//! `Sync`; the native backend runs each batch on the shared worker pool).
//! Results are written to per-chunk slots, so responses are identical to
//! the serial schedule regardless of completion order.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use super::metrics::ServeMetrics;
use crate::calib::{self, corpus::Style, TaskKind};

/// One row of model work: `seq` input tokens, `seq` next-token targets and a
/// per-position loss mask.
#[derive(Clone, Debug)]
pub struct WorkRow {
    /// Input token ids, length `seq`.
    pub inputs: Vec<i32>,
    /// Next-token targets, length `seq`.
    pub targets: Vec<i32>,
    /// Per-position loss mask (1.0 = scored).
    pub mask: Vec<f32>,
}

impl WorkRow {
    /// Build from a (seq+1)-token row; positions before `score_from` are
    /// masked out (0 scores everything, i.e. plain perplexity). An empty
    /// token slice (adversarial / fuzzed traces) yields an empty row —
    /// dispatch-time validation rejects zero-row work with a clean error.
    pub fn from_tokens(tokens: &[u32], score_from: usize) -> Self {
        let seq = tokens.len().saturating_sub(1);
        let mut mask = vec![0.0f32; seq];
        for (s, m) in mask.iter_mut().enumerate() {
            if s + 1 >= score_from {
                *m = 1.0;
            }
        }
        Self {
            inputs: tokens[..seq].iter().map(|&t| t as i32).collect(),
            targets: tokens.get(1..).unwrap_or(&[]).iter().map(|&t| t as i32).collect(),
            mask,
        }
    }
}

/// Per-row result: masked NLL sum and masked position count.
#[derive(Clone, Copy, Debug, Default)]
pub struct RowOut {
    /// Masked negative-log-likelihood sum over the row.
    pub nll: f32,
    /// Number of masked (scored) positions.
    pub count: f32,
}

/// Anything that can run up to [`batch_rows`](Self::batch_rows) rows in one
/// dispatch. Implementations pad short dispatches internally.
///
/// `execute` takes `&self` and the trait requires `Sync`: the batcher may
/// run several dispatches concurrently (`Batcher::with_dispatch`), so
/// executors keep mutable bookkeeping behind interior locks.
pub trait RowExecutor: Sync {
    /// Fixed batch capacity of one dispatch.
    fn batch_rows(&self) -> usize;
    /// Fixed row length every [`WorkRow`] must match.
    fn seq(&self) -> usize;
    /// Run up to [`batch_rows`](Self::batch_rows) rows, returning one
    /// [`RowOut`] per input row.
    fn execute(&self, rows: &[WorkRow]) -> Result<Vec<RowOut>>;
}

/// What a queued request wants back.
#[derive(Clone, Debug)]
pub enum RequestKind {
    /// Perplexity over the request's rows: responds with summed NLL/count.
    Ppl,
    /// Zero-shot choice: each row is one candidate; responds with the argmin
    /// of per-row mean NLL.
    Choice {
        /// Ground-truth candidate index (carried through for scoring).
        correct: usize,
    },
    /// Forward pass only (downstream consumes hidden states); responds with
    /// the token count pushed through.
    Hidden,
}

/// One queued unit of serving work: a request kind plus its rows.
#[derive(Clone, Debug)]
pub struct Request {
    /// What the caller wants back.
    pub kind: RequestKind,
    /// The model rows this request spans (dispatched together or rejected
    /// together — never partially admitted).
    pub rows: Vec<WorkRow>,
}

/// The answer to one [`Request`], in submission order.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Summed NLL and scored-position count for a perplexity request.
    Ppl {
        /// Masked NLL summed over the request's rows.
        nll: f64,
        /// Scored positions summed over the request's rows.
        count: f64,
    },
    /// Zero-shot choice outcome.
    Choice {
        /// Index of the lowest mean-NLL candidate.
        pick: usize,
        /// Ground-truth candidate index (carried through for scoring).
        correct: usize,
        /// Per-candidate mean NLL scores.
        scores: Vec<f32>,
    },
    /// Forward-only request: how many tokens were pushed through.
    Hidden {
        /// Token count (rows × seq).
        tokens: usize,
    },
    /// Turned away at admission: the bounded queue was full. The request
    /// performed no model work (callers should retry/shed load).
    Rejected,
}

impl Response {
    /// `exp(nll/count)` for perplexity responses, `None` otherwise.
    pub fn perplexity(&self) -> Option<f64> {
        match self {
            Response::Ppl { nll, count } => Some((nll / count.max(1.0)).exp()),
            _ => None,
        }
    }
}

/// Per-priority-class latency accounting. Filled by the live scheduler
/// (`serve::scheduler`), which measures queue wait (arrival -> dispatch)
/// and service (dispatch -> completion) per class in clock ticks and
/// reports nearest-rank percentiles in seconds. Plain burst runs leave
/// `ServeStats::class_lat` empty.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClassLat {
    /// Class name ("interactive" / "batch" / "background").
    pub class: String,
    /// Requests of this class offered.
    pub submitted: usize,
    /// Requests of this class served to completion.
    pub completed: usize,
    /// Requests of this class turned away at admission (queue capacity
    /// or, in live runs with the SLO controller active, shedding).
    pub rejected: usize,
    /// Median queue wait (arrival → dispatch), seconds.
    pub queue_p50_s: f64,
    /// 95th-percentile queue wait, seconds.
    pub queue_p95_s: f64,
    /// 99th-percentile queue wait, seconds.
    pub queue_p99_s: f64,
    /// Median service time (dispatch → completion), seconds.
    pub service_p50_s: f64,
    /// 95th-percentile service time, seconds.
    pub service_p95_s: f64,
    /// 99th-percentile service time, seconds.
    pub service_p99_s: f64,
}

/// Throughput accounting for one batcher run.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Requests offered (admitted + rejected).
    pub requests: usize,
    /// Executor dispatches performed.
    pub dispatches: usize,
    /// real (non-padding) rows executed
    pub rows: usize,
    /// dispatches * batch capacity
    pub row_capacity: usize,
    /// real tokens pushed through (rows * seq)
    pub tokens: usize,
    /// requests turned away by the bounded admission queue
    pub rejected: usize,
    /// requests shed by the SLO controller (live scheduler runs only;
    /// counted apart from `rejected` so overload-control load loss is
    /// distinguishable from capacity loss)
    pub shed: usize,
    /// Wall-clock duration of the run in seconds.
    pub wall_seconds: f64,
    /// configured dispatch concurrency this run executed with (1 = serial)
    pub dispatch_lanes: usize,
    /// highest number of dispatches observed in flight at once
    pub peak_in_flight: usize,
    /// summed executor-busy time across lanes (occupancy-over-time: with
    /// `dispatch_lanes` lanes over `wall_seconds`, lane occupancy is
    /// `lane_busy_seconds / (dispatch_lanes * wall_seconds)`)
    pub lane_busy_seconds: f64,
    /// per-priority-class latency percentiles; empty unless the run came
    /// from the live scheduler (`serve::scheduler::Scheduler::run`)
    pub class_lat: Vec<ClassLat>,
}

impl ServeStats {
    /// Fraction of executed batch rows that carried real work.
    pub fn occupancy(&self) -> f64 {
        self.rows as f64 / self.row_capacity.max(1) as f64
    }

    /// Fraction of lane-time the dispatch lanes spent inside the executor
    /// (1.0 = every lane busy for the whole run). Reports 0 when no wall
    /// time elapsed (instant simulated traces) — never `inf`/NaN.
    pub fn lane_occupancy(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.lane_busy_seconds / (self.dispatch_lanes.max(1) as f64 * self.wall_seconds)
        } else {
            0.0
        }
    }

    /// Real tokens served per second of wall time. Reports 0 when no wall
    /// time elapsed (instant simulated traces) — never `inf`/NaN.
    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.tokens as f64 / self.wall_seconds
        } else {
            0.0
        }
    }

    /// Served (admitted) requests per second — rejected and shed requests
    /// did no model work and do not count as throughput. Reports 0 when no
    /// wall time elapsed (instant simulated traces) — never `inf`/NaN.
    pub fn requests_per_s(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.requests.saturating_sub(self.rejected + self.shed) as f64 / self.wall_seconds
        } else {
            0.0
        }
    }
}

/// Materialize a chunk's rows, execute them, and validate the result
/// shape. Returns (per-row outputs, executor-busy seconds). Shared by the
/// serial and concurrent dispatch paths so validation cannot drift.
fn run_chunk(
    exec: &dyn RowExecutor,
    requests: &[Request],
    chunk: &[(usize, usize)],
) -> Result<(Vec<RowOut>, f64)> {
    let rows: Vec<WorkRow> =
        chunk.iter().map(|&(ri, qi)| requests[ri].rows[qi].clone()).collect();
    let t0 = Instant::now();
    let res = exec.execute(&rows);
    let busy = t0.elapsed().as_secs_f64();
    let res = res?;
    ensure!(
        res.len() == rows.len(),
        "executor returned {} results for {} rows",
        res.len(),
        rows.len()
    );
    Ok((res, busy))
}

/// Land one executed chunk: route per-row outputs to their request slots
/// and book the dispatch into the stats.
fn merge_chunk(
    stats: &mut ServeStats,
    outs: &mut [Vec<RowOut>],
    chunk: &[(usize, usize)],
    res: Vec<RowOut>,
    cap: usize,
    seq: usize,
) {
    for (&(ri, qi), out) in chunk.iter().zip(res) {
        outs[ri][qi] = out;
    }
    stats.dispatches += 1;
    stats.rows += chunk.len();
    stats.row_capacity += cap;
    stats.tokens += chunk.len() * seq;
}

/// Coalescing request batcher with an optional bounded admission queue and
/// configurable dispatch concurrency.
pub struct Batcher {
    /// Upper bound on rows per dispatch: `batch_rows()` when coalescing,
    /// 1 for the sequential baseline.
    rows_per_dispatch: usize,
    /// Admission cap in *rows*: requests that would push the queued row
    /// count past this bound are rejected up front (visible overload
    /// instead of unbounded queue growth). `None` = unlimited.
    queue_cap: Option<usize>,
    /// How many independent dispatches may execute concurrently.
    dispatch: usize,
    /// Always-on stats layer to record each run into (standalone burst
    /// runs; the live scheduler records itself and leaves this unset to
    /// avoid double-counting its inner batcher).
    metrics: Option<Arc<ServeMetrics>>,
}

impl Batcher {
    /// Coalesce rows from all requests into maximal dispatches.
    pub fn coalescing(exec: &dyn RowExecutor) -> Self {
        Self {
            rows_per_dispatch: exec.batch_rows().max(1),
            queue_cap: None,
            dispatch: 1,
            metrics: None,
        }
    }

    /// One row per dispatch (the naive serving baseline).
    pub fn sequential() -> Self {
        Self { rows_per_dispatch: 1, queue_cap: None, dispatch: 1, metrics: None }
    }

    /// Record every `run` into `metrics` (admission counters, dispatches,
    /// tokens, one cycle per run). Responses and stats are unchanged.
    pub fn with_metrics(mut self, metrics: Arc<ServeMetrics>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Execute up to `n` window dispatches concurrently (0/1 = serial).
    /// Chunk contents and per-request responses are independent of `n`;
    /// only wall-clock changes.
    pub fn with_dispatch(mut self, n: usize) -> Self {
        self.dispatch = n.max(1);
        self
    }

    /// Bound the admission queue to `cap` rows (0 = unlimited). A request
    /// is admitted atomically — all of its rows or none — so a multi-row
    /// choice request never ends up half-scored.
    ///
    /// Semantics: `run` drains a backlog that already arrived, so the cap
    /// bounds the backlog admitted **per offered burst** — capacity is
    /// *not* re-credited as dispatches complete within the same `run`
    /// call (pinned by the `queue_cap_is_per_burst_without_scheduler`
    /// regression test). Re-credited admission is the live scheduler's
    /// job: `serve::scheduler::Scheduler` admits against the rows
    /// *currently waiting*, returns capacity when a drain cycle dispatches
    /// them, and calls `run` per cycle with requests it already admitted
    /// (leaving this cap unset).
    pub fn with_queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = if cap == 0 { None } else { Some(cap) };
        self
    }

    /// Run every request to completion, returning per-request responses (in
    /// request order) and throughput stats.
    pub fn run(
        &self,
        exec: &dyn RowExecutor,
        requests: &[Request],
    ) -> Result<(Vec<Response>, ServeStats)> {
        let seq = exec.seq();
        let cap = exec.batch_rows().max(1);
        let per_dispatch = self.rows_per_dispatch.clamp(1, cap);

        // admission + flatten: (request index, row index within request).
        // Requests are validated regardless of admission (shape bugs must
        // surface even under overload), admitted whole-or-not.
        let mut flat: Vec<(usize, usize)> = Vec::new();
        let mut admitted = vec![true; requests.len()];
        let mut stats = ServeStats { requests: requests.len(), ..Default::default() };
        let mut queued_rows = 0usize;
        for (ri, req) in requests.iter().enumerate() {
            ensure!(!req.rows.is_empty(), "request {ri} has no rows");
            for (qi, row) in req.rows.iter().enumerate() {
                ensure!(
                    row.inputs.len() == seq && row.targets.len() == seq && row.mask.len() == seq,
                    "request {ri} row {qi}: row length != executor seq {seq}"
                );
            }
            if let Some(cap) = self.queue_cap {
                if queued_rows + req.rows.len() > cap {
                    admitted[ri] = false;
                    stats.rejected += 1;
                    continue;
                }
            }
            queued_rows += req.rows.len();
            for qi in 0..req.rows.len() {
                flat.push((ri, qi));
            }
        }

        let mut outs: Vec<Vec<RowOut>> =
            requests.iter().map(|r| vec![RowOut::default(); r.rows.len()]).collect();
        let chunks: Vec<&[(usize, usize)]> = flat.chunks(per_dispatch).collect();
        let lanes = self.dispatch.clamp(1, chunks.len().max(1));
        stats.dispatch_lanes = lanes;
        let t0 = Instant::now();
        if lanes <= 1 {
            for chunk in &chunks {
                let (res, busy) = run_chunk(exec, requests, chunk)?;
                stats.lane_busy_seconds += busy;
                merge_chunk(&mut stats, &mut outs, chunk, res, cap, seq);
            }
            stats.peak_in_flight = usize::from(!chunks.is_empty());
        } else {
            // concurrent dispatch: N lanes pull chunk indices from a shared
            // counter; results land in per-chunk slots so the merged output
            // is identical to the serial schedule
            let next = AtomicUsize::new(0);
            let in_flight = AtomicUsize::new(0);
            let peak = AtomicUsize::new(0);
            let failed = AtomicBool::new(false);
            type LaneOut = (Vec<(usize, Vec<RowOut>)>, f64);
            let lane_results: Vec<Result<LaneOut>> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..lanes)
                    .map(|_| {
                        s.spawn(|| -> Result<LaneOut> {
                            let mut local: Vec<(usize, Vec<RowOut>)> = Vec::new();
                            let mut busy = 0.0f64;
                            loop {
                                if failed.load(Ordering::SeqCst) {
                                    break;
                                }
                                let ci = next.fetch_add(1, Ordering::SeqCst);
                                if ci >= chunks.len() {
                                    break;
                                }
                                let cur = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
                                peak.fetch_max(cur, Ordering::SeqCst);
                                let res = run_chunk(exec, requests, chunks[ci]);
                                in_flight.fetch_sub(1, Ordering::SeqCst);
                                match res {
                                    Ok((r, b)) => {
                                        busy += b;
                                        local.push((ci, r));
                                    }
                                    Err(e) => {
                                        failed.store(true, Ordering::SeqCst);
                                        return Err(e);
                                    }
                                }
                            }
                            Ok((local, busy))
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("dispatch lane panicked"))
                    .collect()
            });
            for lr in lane_results {
                let (local, busy) = lr?;
                stats.lane_busy_seconds += busy;
                for (ci, res) in local {
                    merge_chunk(&mut stats, &mut outs, chunks[ci], res, cap, seq);
                }
            }
            stats.peak_in_flight = peak.load(Ordering::SeqCst);
        }
        stats.wall_seconds = t0.elapsed().as_secs_f64();

        if let Some(m) = &self.metrics {
            m.add_offered(requests.len() as u64);
            m.add_admitted((requests.len() - stats.rejected) as u64);
            m.add_rejected(stats.rejected as u64);
            m.add_dispatches(stats.dispatches as u64);
            m.add_tokens(stats.tokens as u64);
            m.add_cycles(1);
        }

        let responses = requests
            .iter()
            .zip(&outs)
            .enumerate()
            .map(|(ri, (req, rows))| {
                if !admitted[ri] {
                    return Response::Rejected;
                }
                match &req.kind {
                RequestKind::Ppl => Response::Ppl {
                    nll: rows.iter().map(|r| r.nll as f64).sum(),
                    count: rows.iter().map(|r| r.count as f64).sum(),
                },
                RequestKind::Choice { correct } => {
                    let scores: Vec<f32> =
                        rows.iter().map(|r| r.nll / r.count.max(1.0)).collect();
                    // total_cmp: NaN scores (broken model numerics) sort
                    // last instead of panicking the serve loop
                    let pick = scores
                        .iter()
                        .enumerate()
                        .min_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(i, _)| i)
                        .unwrap_or(0);
                    Response::Choice { pick, correct: *correct, scores }
                }
                    RequestKind::Hidden => Response::Hidden { tokens: rows.len() * seq },
                }
            })
            .collect();
        Ok((responses, stats))
    }
}

// ---------------------------------------------------------------------------
// request-mix builders (serve-bench workload)
// ---------------------------------------------------------------------------

/// Perplexity requests: each covers one held-out (seq+1)-token segment.
pub fn ppl_requests(style: Style, n_segments: usize, seq: usize) -> Vec<Request> {
    let rows_per_batch = 4;
    let batches =
        calib::eval_stream(style, n_segments.div_ceil(rows_per_batch), rows_per_batch, seq);
    let mut out = Vec::with_capacity(n_segments);
    'outer: for b in &batches {
        for r in 0..b.batch {
            if out.len() == n_segments {
                break 'outer;
            }
            out.push(Request {
                kind: RequestKind::Ppl,
                rows: vec![WorkRow::from_tokens(b.row(r), 0)],
            });
        }
    }
    out
}

/// Zero-shot choice requests: one per item, one row per candidate.
pub fn choice_requests(kind: TaskKind, n_items: usize, seq: usize) -> Vec<Request> {
    calib::choice_task(kind, n_items, seq + 1)
        .into_iter()
        .map(|item| {
            let rows = item
                .cands
                .iter()
                .map(|c| {
                    let mut toks = item.prompt.clone();
                    toks.extend_from_slice(c);
                    WorkRow::from_tokens(&toks, item.prompt.len())
                })
                .collect();
            Request { kind: RequestKind::Choice { correct: item.correct }, rows }
        })
        .collect()
}

/// Forward-hidden requests over calibration-style segments.
pub fn hidden_requests(n: usize, seq: usize) -> Vec<Request> {
    let rows_per_batch = 4;
    let batches = calib::batches(Style::Wiki, 7777, n.div_ceil(rows_per_batch), rows_per_batch, seq);
    let mut out = Vec::with_capacity(n);
    'outer: for b in &batches {
        for r in 0..b.batch {
            if out.len() == n {
                break 'outer;
            }
            out.push(Request {
                kind: RequestKind::Hidden,
                rows: vec![WorkRow::from_tokens(b.row(r), 0)],
            });
        }
    }
    out
}

/// The standard mixed serve-bench workload.
pub fn standard_mix(seq: usize, n_ppl: usize, n_choice: usize, n_hidden: usize) -> Vec<Request> {
    let mut reqs = ppl_requests(Style::C4, n_ppl, seq);
    reqs.extend(choice_requests(TaskKind::TopicMatch, n_choice, seq));
    reqs.extend(hidden_requests(n_hidden, seq));
    reqs
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mock: nll = sum of masked targets, count = mask sum; records
    /// dispatch sizes (behind a lock: `execute` takes `&self`).
    struct Mock {
        batch: usize,
        seq: usize,
        dispatch_sizes: std::sync::Mutex<Vec<usize>>,
    }

    impl Mock {
        fn new(batch: usize, seq: usize) -> Self {
            Self { batch, seq, dispatch_sizes: std::sync::Mutex::new(Vec::new()) }
        }

        fn sizes(&self) -> Vec<usize> {
            self.dispatch_sizes.lock().unwrap().clone()
        }
    }

    impl RowExecutor for Mock {
        fn batch_rows(&self) -> usize {
            self.batch
        }
        fn seq(&self) -> usize {
            self.seq
        }
        fn execute(&self, rows: &[WorkRow]) -> Result<Vec<RowOut>> {
            assert!(rows.len() <= self.batch);
            self.dispatch_sizes.lock().unwrap().push(rows.len());
            Ok(rows
                .iter()
                .map(|r| RowOut {
                    nll: r
                        .targets
                        .iter()
                        .zip(&r.mask)
                        .map(|(&t, &m)| t as f32 * m)
                        .sum(),
                    count: r.mask.iter().sum(),
                })
                .collect())
        }
    }

    fn row(tokens: &[u32]) -> WorkRow {
        WorkRow::from_tokens(tokens, 0)
    }

    #[test]
    fn coalescing_fills_batches_and_sequential_does_not() {
        let seq = 4;
        let reqs: Vec<Request> = (0..10)
            .map(|i| Request {
                kind: RequestKind::Ppl,
                rows: vec![row(&[i, i + 1, i + 2, i + 3, i + 4])],
            })
            .collect();

        let m = Mock::new(4, seq);
        let (resp_b, stats_b) = Batcher::coalescing(&m).run(&m, &reqs).unwrap();
        assert_eq!(m.sizes(), vec![4, 4, 2]);
        assert_eq!(stats_b.dispatches, 3);
        assert_eq!(stats_b.rows, 10);
        assert_eq!(stats_b.tokens, 40);
        assert!((stats_b.occupancy() - 10.0 / 12.0).abs() < 1e-12);

        let m1 = Mock::new(4, seq);
        let (resp_s, stats_s) = Batcher::sequential().run(&m1, &reqs).unwrap();
        assert_eq!(stats_s.dispatches, 10);
        assert!((stats_s.occupancy() - 10.0 / 40.0).abs() < 1e-12);

        // identical responses either way
        for (a, b) in resp_b.iter().zip(&resp_s) {
            match (a, b) {
                (Response::Ppl { nll: n1, count: c1 }, Response::Ppl { nll: n2, count: c2 }) => {
                    assert_eq!(n1, n2);
                    assert_eq!(c1, c2);
                }
                _ => panic!("kind mismatch"),
            }
        }
    }

    #[test]
    fn choice_rows_coalesce_across_requests_and_pick_argmin() {
        let seq = 3;
        // candidate rows with known target sums: pick the smaller
        let req = |a: [u32; 4], b: [u32; 4], correct: usize| Request {
            kind: RequestKind::Choice { correct },
            rows: vec![row(&a), row(&b)],
        };
        let reqs = vec![
            req([0, 9, 9, 9], [0, 1, 1, 1], 1), // row1 smaller -> pick 1
            req([0, 1, 0, 1], [0, 5, 5, 5], 0), // row0 smaller -> pick 0
        ];
        let m = Mock::new(4, seq);
        let (resp, stats) = Batcher::coalescing(&m).run(&m, &reqs).unwrap();
        // 4 candidate rows from 2 requests fill exactly one dispatch
        assert_eq!(stats.dispatches, 1);
        match &resp[0] {
            Response::Choice { pick, correct, scores } => {
                assert_eq!(*pick, 1);
                assert_eq!(*correct, 1);
                assert_eq!(scores.len(), 2);
            }
            _ => panic!("wrong kind"),
        }
        match &resp[1] {
            Response::Choice { pick, .. } => assert_eq!(*pick, 0),
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn masks_respect_prompt_boundary() {
        let r = WorkRow::from_tokens(&[10, 11, 12, 13, 14], 3);
        // seq = 4; positions scoring targets row[1..] = [11,12,13,14];
        // score_from=3 masks predictions of tokens before index 3
        assert_eq!(r.mask, vec![0.0, 0.0, 1.0, 1.0]);
        assert_eq!(r.inputs, vec![10, 11, 12, 13]);
        assert_eq!(r.targets, vec![11, 12, 13, 14]);
    }

    #[test]
    fn from_tokens_handles_degenerate_rows() {
        // empty and single-token rows must not underflow/panic; they
        // produce zero-length rows that dispatch validation rejects
        for toks in [&[][..], &[42u32][..]] {
            let r = WorkRow::from_tokens(toks, 0);
            assert!(r.inputs.is_empty());
            assert!(r.targets.is_empty());
            assert!(r.mask.is_empty());
        }
    }

    #[test]
    fn mix_builders_produce_well_formed_requests() {
        let seq = 96;
        let reqs = standard_mix(seq, 6, 3, 2);
        assert_eq!(reqs.len(), 11);
        for r in &reqs {
            for row in &r.rows {
                assert_eq!(row.inputs.len(), seq);
                assert_eq!(row.targets.len(), seq);
                assert_eq!(row.mask.len(), seq);
            }
        }
        let n_choice = reqs
            .iter()
            .filter(|r| matches!(r.kind, RequestKind::Choice { .. }))
            .count();
        assert_eq!(n_choice, 3);
        // choice requests carry 2 candidate rows each
        for r in reqs.iter().filter(|r| matches!(r.kind, RequestKind::Choice { .. })) {
            assert_eq!(r.rows.len(), 2);
        }
    }

    #[test]
    fn bounded_admission_rejects_overflow_and_keeps_order() {
        let seq = 4;
        let reqs: Vec<Request> = (0..6)
            .map(|i| Request {
                kind: RequestKind::Ppl,
                rows: vec![row(&[i, i + 1, i + 2, i + 3, i + 4])],
            })
            .collect();
        let m = Mock::new(4, seq);
        let (resp, stats) =
            Batcher::coalescing(&m).with_queue_cap(4).run(&m, &reqs).unwrap();
        assert_eq!(stats.rejected, 2);
        assert_eq!(stats.rows, 4);
        assert_eq!(resp.len(), 6);
        for r in &resp[..4] {
            assert!(matches!(r, Response::Ppl { .. }));
        }
        for r in &resp[4..] {
            assert_eq!(*r, Response::Rejected);
        }
        // only admitted rows were dispatched
        assert_eq!(m.sizes(), vec![4]);
    }

    #[test]
    fn admission_is_whole_request() {
        // a 2-row choice request must never be half-admitted
        let seq = 3;
        let reqs = vec![
            Request { kind: RequestKind::Ppl, rows: vec![row(&[0, 1, 2, 3])] },
            Request {
                kind: RequestKind::Choice { correct: 0 },
                rows: vec![row(&[0, 1, 1, 1]), row(&[0, 9, 9, 9])],
            },
            Request { kind: RequestKind::Ppl, rows: vec![row(&[4, 5, 6, 7])] },
        ];
        let m = Mock::new(4, seq);
        // cap of 2: ppl (1 row) admitted, choice (2 rows) would exceed ->
        // rejected whole; trailing ppl still fits
        let (resp, stats) =
            Batcher::coalescing(&m).with_queue_cap(2).run(&m, &reqs).unwrap();
        assert_eq!(stats.rejected, 1);
        assert!(matches!(resp[0], Response::Ppl { .. }));
        assert_eq!(resp[1], Response::Rejected);
        assert!(matches!(resp[2], Response::Ppl { .. }));
    }

    /// Regression pin for the pre-scheduler semantics: within one `run`,
    /// the cap bounds the whole offered burst — completing dispatches does
    /// NOT re-credit capacity. (The live scheduler layers re-crediting on
    /// top by calling `run` per drain cycle; see tests/scheduler.rs for
    /// the contrast test.)
    #[test]
    fn queue_cap_is_per_burst_without_scheduler() {
        let seq = 4;
        let reqs: Vec<Request> = (0..8)
            .map(|i| Request {
                kind: RequestKind::Ppl,
                rows: vec![row(&[i, i + 1, i + 2, i + 3, i + 4])],
            })
            .collect();
        // batch of 2: the 4 admitted rows take two dispatches, which both
        // complete during the run — yet requests 4..8 stay rejected
        let m = Mock::new(2, seq);
        let (resp, stats) =
            Batcher::coalescing(&m).with_queue_cap(4).run(&m, &reqs).unwrap();
        assert_eq!(stats.rejected, 4, "completed dispatches must not re-credit the cap");
        assert_eq!(stats.dispatches, 2);
        assert_eq!(stats.rows, 4);
        for r in &resp[..4] {
            assert!(matches!(r, Response::Ppl { .. }));
        }
        for r in &resp[4..] {
            assert_eq!(*r, Response::Rejected);
        }
    }

    #[test]
    fn zero_cap_means_unlimited() {
        let seq = 4;
        let reqs: Vec<Request> = (0..5)
            .map(|i| Request {
                kind: RequestKind::Ppl,
                rows: vec![row(&[i, i + 1, i + 2, i + 3, i + 4])],
            })
            .collect();
        let m = Mock::new(2, seq);
        let (_, stats) = Batcher::coalescing(&m).with_queue_cap(0).run(&m, &reqs).unwrap();
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.rows, 5);
    }

    #[test]
    fn concurrent_dispatch_matches_serial_and_accounts_fully() {
        let seq = 4;
        let reqs: Vec<Request> = (0..23)
            .map(|i| Request {
                kind: RequestKind::Ppl,
                rows: vec![row(&[i, i + 1, i + 2, i + 3, i + 4])],
            })
            .collect();
        let m = Mock::new(4, seq);
        let (resp_serial, stats_serial) = Batcher::coalescing(&m).run(&m, &reqs).unwrap();

        let m4 = Mock::new(4, seq);
        let (resp_par, stats_par) =
            Batcher::coalescing(&m4).with_dispatch(4).run(&m4, &reqs).unwrap();

        assert_eq!(resp_par, resp_serial, "dispatch concurrency changed answers");
        assert_eq!(stats_par.dispatches, stats_serial.dispatches);
        assert_eq!(stats_par.rows, stats_serial.rows);
        assert_eq!(stats_par.tokens, stats_serial.tokens);
        assert_eq!(stats_par.dispatch_lanes, 4);
        assert!(stats_par.peak_in_flight >= 1 && stats_par.peak_in_flight <= 4);
        // same chunks executed, order aside
        let mut a = m.sizes();
        let mut b = m4.sizes();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn concurrent_dispatch_with_admission_accounts_every_request() {
        // completed + rejected must equal submitted under concurrency
        let seq = 4;
        let reqs: Vec<Request> = (0..17)
            .map(|i| Request {
                kind: RequestKind::Ppl,
                rows: vec![row(&[i, i + 1, i + 2, i + 3, i + 4])],
            })
            .collect();
        let m = Mock::new(4, seq);
        let (resp, stats) = Batcher::coalescing(&m)
            .with_queue_cap(10)
            .with_dispatch(4)
            .run(&m, &reqs)
            .unwrap();
        let completed = resp.iter().filter(|r| !matches!(r, Response::Rejected)).count();
        assert_eq!(completed + stats.rejected, reqs.len());
        assert_eq!(stats.rejected, 7);
        assert_eq!(stats.rows, 10);
    }

    #[test]
    fn dispatch_on_single_chunk_falls_back_to_serial() {
        let seq = 4;
        let reqs = vec![Request {
            kind: RequestKind::Ppl,
            rows: vec![row(&[1, 2, 3, 4, 5])],
        }];
        let m = Mock::new(4, seq);
        let (_, stats) = Batcher::coalescing(&m).with_dispatch(8).run(&m, &reqs).unwrap();
        assert_eq!(stats.dispatch_lanes, 1, "one chunk never needs more than one lane");
        assert_eq!(stats.peak_in_flight, 1);
    }

    #[test]
    fn rejects_misshapen_rows() {
        let m = Mock::new(2, 4);
        let reqs = vec![Request { kind: RequestKind::Ppl, rows: vec![row(&[1, 2, 3])] }];
        assert!(Batcher::coalescing(&m).run(&m, &reqs).is_err());
    }

    /// Regression: an instant run (simulated clocks, empty bursts) used to
    /// report `inf` rates from the `max(1e-12)` pseudo-guard.
    #[test]
    fn zero_elapsed_rates_are_zero_not_inf() {
        let s = ServeStats {
            requests: 5,
            tokens: 100,
            rows: 10,
            lane_busy_seconds: 1.0,
            wall_seconds: 0.0,
            ..Default::default()
        };
        assert_eq!(s.tokens_per_s(), 0.0);
        assert_eq!(s.requests_per_s(), 0.0);
        assert_eq!(s.lane_occupancy(), 0.0);
        // shed requests do not count as served throughput
        let t = ServeStats {
            requests: 10,
            rejected: 2,
            shed: 3,
            wall_seconds: 2.0,
            ..Default::default()
        };
        assert_eq!(t.requests_per_s(), 2.5);
    }

    #[test]
    fn with_metrics_records_burst_counters() {
        let seq = 4;
        let reqs: Vec<Request> = (0..6)
            .map(|i| Request {
                kind: RequestKind::Ppl,
                rows: vec![row(&[i, i + 1, i + 2, i + 3, i + 4])],
            })
            .collect();
        let m = Mock::new(4, seq);
        let metrics = Arc::new(ServeMetrics::new());
        let (_, stats) = Batcher::coalescing(&m)
            .with_queue_cap(4)
            .with_metrics(metrics.clone())
            .run(&m, &reqs)
            .unwrap();
        assert_eq!(metrics.offered(), 6);
        assert_eq!(metrics.rejected(), 2);
        assert_eq!(metrics.admitted(), 4);
        assert_eq!(metrics.dispatches(), stats.dispatches as u64);
        assert_eq!(metrics.tokens(), stats.tokens as u64);
        assert_eq!(metrics.cycles(), 1);
    }
}
