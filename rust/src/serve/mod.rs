//! Batched serving engine over snapshot-loaded quantized models.
//!
//! The quantize path (`coordinator`) produces a [`QuantizedModel`]; the
//! snapshot store (`snapshot`) persists it; this module serves it:
//!
//! * [`registry::ModelRegistry`] — loads `CBQS` files by name and keeps the
//!   loaded models resident; [`registry::LoadMode::Mmap`] opens them as
//!   memory-mapped lazy views instead of decoding everything up front;
//! * [`ServeEngine`] — binds a resident model to a [`Backend`]'s
//!   executables, covering the block chain with the *largest exported
//!   window executables* (the same greedy covering `forward_hidden` uses).
//!   Eagerly loaded models **pin** every static input (weights, quant
//!   state, globals) once at engine build; mmap-loaded models pin
//!   **lazily** — a window's codes are pinned on first touch, a bounded
//!   LRU keeps at most `--resident-windows` (or `CBQ_RESIDENT_MB`)
//!   windows' worth of tensors resident, and eviction drops straight back
//!   to the file mapping. On the native backend windows default to
//!   **packed-domain pinning** ([`EngineOptions::packed`]): the 2/4/8-bit
//!   codes + per-channel scales are pinned as-is and the quantized matmul
//!   reads them in place — 4–16x smaller resident windows than the f32
//!   path, and a background prefetch warms the next planned window's file
//!   pages while the current one executes. Responses are bitwise-identical
//!   across all of eager / lazy / packed / evict-and-retouch (asserted in
//!   `rust/tests/mmap.rs`);
//! * [`batcher::Batcher`] — coalesces queued eval requests (perplexity
//!   segments, zero-shot choice items, forward-hidden calls) into maximal
//!   batches, optionally executes several window dispatches concurrently
//!   (`with_dispatch`, CLI `--dispatch`), and reports tokens/s, requests/s,
//!   batch occupancy and in-flight/lane-occupancy counters;
//! * [`scheduler::Scheduler`] — the live arrival loop on top of the
//!   batcher: seeded synthetic traces, Interactive/Batch/Background
//!   priority classes with weighted aging (no starvation), admission
//!   capacity re-credited as drain cycles complete, and per-class
//!   p50/p95/p99 queue+service latency folded into [`ServeStats`]. All
//!   decisions run on [`clock::Clock`] ticks; under [`clock::SimClock`]
//!   a trace replays to bitwise-identical responses and decisions for any
//!   dispatch lane count (CLI `cbq serve-bench --live`).
//!
//! Memory: `Value`/`Tensor` storage is `Arc`-backed, so the registry's
//! resident model, every engine bound to it, and every pinned executable
//! input all share **one** copy of each weight buffer — per process, not
//! per engine (refcount/pointer-identity assertions live in
//! `tests/backend.rs::export_load_serve_end_to_end_on_native`). Under
//! `--mmap` the f32 tensors (embed, LM head, norms, scales) are zero-copy
//! views into one shared mapping of the snapshot file, and only the
//! unpacked windows in the LRU occupy heap at all
//! ([`ServeEngine::residency`] reports the exact accounting).

pub mod batcher;
pub mod clock;
pub mod generate;
pub mod metrics;
pub mod registry;
pub mod scheduler;

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::config::RoundingMode;
use crate::coordinator::{window_plan, Pipeline, QuantizedModel};
use crate::model_state::embed_lookup;
use crate::runtime::backend::{kernels, pool};
use crate::runtime::{Artifacts, Backend, Bindings, PackedValue, Pinned, Value};
use crate::snapshot::SnapshotModel;
use crate::tensor::{Tensor, TensorI32};

pub use batcher::{
    Batcher, ClassLat, Request, RequestKind, Response, RowExecutor, RowOut, ServeStats, WorkRow,
};
pub use clock::{Clock, RealClock, SimClock, TICKS_PER_SEC};
pub use generate::{
    synth_gen_trace, GenArrival, GenCfg, GenOutcome, GenRequest, GenStats, GenTraceSpec,
    GenerateEngine,
};
pub use metrics::{
    percentile, Alert, AlertKind, AlertSink, ClassHist, LatHistogram, MetricsSnapshot,
    ServeMetrics, SloCfg, SloController,
};
pub use registry::{LoadMode, LoadedSnapshot, ModelRegistry};
pub use scheduler::{
    synth_trace, Arrival, Decision, Lcg, LiveOutcome, Priority, Scheduler, SchedulerCfg, TraceSpec,
};

/// Residency limits for lazily pinned (mmap-loaded) engines. Both bounds
/// are enforced together; `None` means unlimited on that axis. With no
/// bound at all, every window stays resident after first touch (lazy
/// cold-start, eager steady-state).
#[derive(Clone, Copy, Debug)]
pub struct EngineOptions {
    /// Maximum pinned windows kept resident (CLI `--resident-windows`).
    pub resident_windows: Option<usize>,
    /// Maximum bytes of unpacked window tensors kept resident
    /// (`CBQ_RESIDENT_MB`, converted to bytes).
    pub resident_bytes: Option<u64>,
    /// Serve mmap windows straight from the packed 2/4/8-bit codes
    /// ([`crate::snapshot::lazy::LazyModel::block_packed`]) instead of
    /// dequantizing to f32 at pin time — 4–16x smaller resident windows,
    /// bitwise-identical responses. Effective only on the native backend
    /// for mmap-loaded snapshots; the `CBQ_PACKED=0` kill switch overrides
    /// it to off (CLI `--packed` / `--no-packed`).
    pub packed: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self { resident_windows: None, resident_bytes: None, packed: true }
    }
}

impl EngineOptions {
    /// Defaults from the environment: `CBQ_RESIDENT_MB` caps resident
    /// unpacked bytes; windows stay unlimited unless the CLI overrides. An
    /// unparseable value is loudly ignored — silently dropping a mistyped
    /// budget would leave residency unbounded, the exact failure the
    /// variable exists to prevent. Packed serving defaults on
    /// (`CBQ_PACKED=0` disables).
    pub fn from_env() -> Self {
        let mut opts = Self::default();
        if let Ok(raw) = std::env::var("CBQ_RESIDENT_MB") {
            if !raw.is_empty() {
                match raw.parse::<u64>() {
                    Ok(mb) => opts.resident_bytes = Some(mb * 1024 * 1024),
                    Err(_) => eprintln!(
                        "warning: CBQ_RESIDENT_MB=`{raw}` is not a whole number of \
                         MiB — ignoring it; window residency is UNBOUNDED"
                    ),
                }
            }
        }
        opts
    }
}

/// Snapshot of an engine's window-residency accounting (see
/// [`ServeEngine::residency`]). Byte figures come from
/// [`Pinned::host_resident_bytes`], i.e. actual `Storage` heap
/// introspection with shared buffers deduped — mapped tensors count 0.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Windows currently pinned (eager: the whole plan).
    pub resident_windows: usize,
    /// Heap bytes of currently pinned window tensors.
    pub resident_bytes: u64,
    /// High-water mark of `resident_windows`.
    pub peak_windows: usize,
    /// High-water mark of `resident_bytes` — the figure the
    /// `--resident-windows` / `CBQ_RESIDENT_MB` budget bounds.
    pub peak_bytes: u64,
    /// Window materializations (cold faults + re-faults after eviction).
    pub faults: u64,
    /// Window cache hits.
    pub hits: u64,
    /// Windows evicted to stay under budget.
    pub evictions: u64,
    /// Background prefetches issued for the next planned window
    /// (`madvise(WILLNEED)` + page touch on the pool, overlapped with the
    /// current window's execution).
    pub prefetches: u64,
    /// Faults that landed on a window a prefetch had already warmed.
    pub prefetch_hits: u64,
}

/// One resident entry of the lazy window cache.
struct LazyWindow {
    pinned: Arc<Pinned>,
    bytes: u64,
    last_use: u64,
    /// File span of the window's packed records inside the snapshot
    /// mapping `(map, offset, len)` — eviction hints `MADV_DONTNEED` over
    /// it so the kernel can reclaim the cold file pages too, not just the
    /// unpacked heap tensors. `None` when the source isn't a real mapping.
    span: Option<(Arc<mmap::Mmap>, usize, usize)>,
}

/// LRU state + counters for lazy pinning. Faults are serialized under this
/// lock (materializing a window is itself parallel inside the kernels);
/// dispatches run outside it, holding `Arc<Pinned>` handles.
#[derive(Default)]
struct WindowCache {
    entries: BTreeMap<usize, LazyWindow>,
    tick: u64,
    resident_bytes: u64,
    peak_bytes: u64,
    peak_windows: usize,
    faults: u64,
    hits: u64,
    evictions: u64,
    prefetches: u64,
    prefetch_hits: u64,
    /// Windows with an issued, not-yet-consumed background prefetch; a
    /// fault on a marked window counts as a `prefetch_hit` and clears it.
    prefetched: std::collections::BTreeSet<usize>,
}

enum Steps {
    /// All windows pinned at engine build (eagerly loaded snapshots).
    Eager(Vec<Arc<Pinned>>),
    /// Windows pinned on first touch, bounded by the budget (mmap).
    Lazy {
        cache: Mutex<WindowCache>,
        max_windows: usize,
        max_bytes: Option<u64>,
        /// Pin packed codes + scales instead of dequantized f32 weights.
        packed: bool,
    },
}

/// Evict idle (not `Arc`-shared) LRU windows until the cache — plus an
/// incoming window of `extra_windows`/`extra_bytes` — fits the budget.
/// Stops early when only in-use windows remain (transient overshoot).
fn evict_idle(
    c: &mut WindowCache,
    extra_windows: usize,
    extra_bytes: u64,
    max_windows: usize,
    max_bytes: Option<u64>,
) {
    loop {
        let over_count = c.entries.len() + extra_windows > max_windows;
        let over_bytes = max_bytes
            .map(|mb| !c.entries.is_empty() && c.resident_bytes + extra_bytes > mb)
            .unwrap_or(false);
        if !over_count && !over_bytes {
            break;
        }
        let victim = c
            .entries
            .iter()
            .filter(|(_, w)| Arc::strong_count(&w.pinned) == 1)
            .min_by_key(|(_, w)| w.last_use)
            .map(|(k, _)| *k);
        let Some(k) = victim else { break }; // all in use: overshoot
        let w = c.entries.remove(&k).expect("victim key just observed");
        c.resident_bytes -= w.bytes;
        c.evictions += 1;
        // the DontNeed hint below discards any pages a prefetch warmed, so
        // a stale marker would count the next re-fault as a spurious
        // prefetch_hit (and markers for never-re-faulted windows would
        // accumulate forever)
        c.prefetched.remove(&k);
        // best-effort page hint: the evicted window's file pages are cold
        // now (a re-fault re-reads them from the file — MAP_PRIVATE
        // read-only pages are always clean, so this never loses data)
        if let Some((map, off, len)) = &w.span {
            let _ = map.advise_range(mmap::Advice::DontNeed, *off, *len);
        }
    }
}

/// A snapshot model bound to the runtime: per-window pinned weight buffers
/// plus the pinned LM head, ready for row-batch execution.
///
/// For mmap-loaded snapshots the per-window pins materialize on demand —
/// see the module docs and [`ServeEngine::residency`].
pub struct ServeEngine<'rt> {
    rt: &'rt dyn Backend,
    snap: Arc<LoadedSnapshot>,
    /// (start block, window width, executable) per step of the greedy
    /// covering.
    plan: Vec<(usize, usize, String)>,
    steps: Steps,
    /// The embedding table (zero-copy from the map under `--mmap`).
    embed: Tensor,
    lm_pinned: Pinned,
}

/// Build the full static binding set for one window of blocks.
fn window_bindings(
    cfg_batch: usize,
    cfg_seq: usize,
    cfg_d: usize,
    qmax_a: f32,
    a_en: f32,
    blocks: &[(&crate::model_state::BlockParams, &BTreeMap<String, crate::coordinator::LinearQ>)],
) -> Bindings {
    let h_dims = [cfg_batch, cfg_seq, cfg_d];
    let mut b = Bindings::new();
    // everything except h_in is static for serving: pin it all, including
    // the (ignored) reconstruction target.
    b.set("target", Tensor::zeros(&h_dims));
    for (j, (params, qstate)) in blocks.iter().enumerate() {
        Pipeline::bind_block_weights(&mut b, j, params);
        // weights are baked (fake-quantized) => w_en = 0; activation quant
        // stays live with the learned alpha clips.
        Pipeline::bind_qblock(&mut b, j, qstate, qmax_a, 0.0, a_en, false);
    }
    Pipeline::bind_globals(&mut b, 0.0, 2.0, 0.0, 1.0, 1.0);
    b
}

impl<'rt> ServeEngine<'rt> {
    /// Bind `snap` to the backend with residency limits from the
    /// environment ([`EngineOptions::from_env`]).
    pub fn new(rt: &'rt dyn Backend, art: &Artifacts, snap: Arc<LoadedSnapshot>) -> Result<Self> {
        Self::with_options(rt, art, snap, EngineOptions::from_env())
    }

    /// Bind `snap` to the backend. Eagerly loaded snapshots pin every
    /// window now (`opts` is irrelevant — everything is resident anyway);
    /// mmap-loaded snapshots defer window pinning to first touch, bounded
    /// by `opts`.
    pub fn with_options(
        rt: &'rt dyn Backend,
        art: &Artifacts,
        snap: Arc<LoadedSnapshot>,
        opts: EngineOptions,
    ) -> Result<Self> {
        let cfg = &snap.meta.cfg;
        let name = &cfg.name;
        let windows = art.windows(name);
        let raw_plan = window_plan(&windows, cfg.n_layers);
        let plan: Vec<(usize, usize, String)> = raw_plan
            .iter()
            .map(|&(start, w)| (start, w, format!("win_fwd_w{w}_{name}")))
            .collect();
        for (_, _, exec) in &plan {
            rt.spec(exec).with_context(|| format!("serve plan needs executable {exec}"))?;
        }

        let embed = snap.model.embed()?;

        let lm_exec = format!("lm_eval_{name}");
        rt.spec(&lm_exec)
            .with_context(|| format!("serve plan needs executable {lm_exec}"))?;
        let mut b = Bindings::new();
        b.set("final_norm", snap.model.final_norm()?);
        b.set("head", snap.model.head()?);
        let lm_pinned = rt.pin(&lm_exec, b.inner())?;

        let steps = match &snap.model {
            SnapshotModel::Eager(model) => {
                let mut pins = Vec::with_capacity(plan.len());
                for (start, w, exec) in &plan {
                    let pinned = Self::pin_window(rt, cfg, model, *start, *w, exec)?;
                    pins.push(Arc::new(pinned));
                }
                Steps::Eager(pins)
            }
            SnapshotModel::Lazy(lazy) => {
                // warmup hint: the first pass over the plan faults windows
                // in file order, so tell the kernel to read ahead
                // aggressively (best-effort; a failed hint changes nothing)
                if let Some(map) = lazy.container().source.mapped() {
                    let _ = map.advise(mmap::Advice::Sequential);
                }
                Steps::Lazy {
                    cache: Mutex::new(WindowCache::default()),
                    max_windows: opts.resident_windows.unwrap_or(usize::MAX).max(1),
                    max_bytes: opts.resident_bytes,
                    // packed-domain pinning is a native-backend kernel path;
                    // the PJRT backend needs f32 literals. CBQ_PACKED=0 is
                    // the process-wide kill switch.
                    packed: opts.packed && kernels::packed_enabled() && rt.name() == "native",
                }
            }
        };

        Ok(Self { rt, snap, plan, steps, embed, lm_pinned })
    }

    /// Pin one window straight off an eager model (borrowing its shared
    /// tensor handles — no decode, no copy).
    fn pin_window(
        rt: &dyn Backend,
        cfg: &crate::runtime::ModelCfg,
        model: &QuantizedModel,
        start: usize,
        w: usize,
        exec: &str,
    ) -> Result<Pinned> {
        let blocks: Vec<_> = (0..w)
            .map(|j| (&model.params.blocks[start + j], &model.qstate[start + j]))
            .collect();
        let b = window_bindings(
            cfg.batch,
            cfg.seq,
            cfg.d_model,
            model.bits.qmax_a(),
            if model.bits.act_enabled() { 1.0 } else { 0.0 },
            &blocks,
        );
        rt.pin(exec, b.inner())
    }

    /// Materialize + pin window `i` of the plan from a lazy model. On the
    /// f32 path every member block's codes are unpacked + dequantized; on
    /// the packed path the codes are re-panelized and pinned *as codes*
    /// (plus scales), so the pin keeps `bits/32` of the f32 weight bytes.
    /// The materialized intermediates drop here; the pin is the only
    /// retention.
    fn materialize_window(&self, i: usize, packed: bool) -> Result<(Pinned, u64)> {
        let lazy = self
            .snap
            .model
            .lazy()
            .expect("materialize_window is only reached on lazy snapshots");
        let cfg = &self.snap.meta.cfg;
        let bits = &self.snap.meta.bits;
        let (start, w, exec) = &self.plan[i];
        let (start, w) = (*start, *w);
        let a_en = if bits.act_enabled() { 1.0 } else { 0.0 };
        let b = if packed {
            // Packed-domain bindings: the weight operand is the panelized
            // codes; s_w lives inside the panels and v0 / LoRA factors /
            // `target` are never read by the frozen deployment graph
            // (w_en = 0, use_lora = 0), so none of them is bound — the
            // native backend errors cleanly if anything tries to use them.
            let mut b = Bindings::new();
            for j in 0..w {
                let blk = lazy.block_packed(start + j)?;
                b.set(format!("blocks.{j}.attn_norm"), blk.attn_norm);
                b.set(format!("blocks.{j}.mlp_norm"), blk.mlp_norm);
                for (l, lin) in &blk.linears {
                    b.0.insert(
                        format!("blocks.{j}.{l}"),
                        Value::Packed(PackedValue::new(lin.panels.clone())),
                    );
                    let p = format!("qblocks.{j}.{l}");
                    b.scalar(format!("{p}.alpha"), lin.alpha);
                    b.scalar(format!("{p}.qmax_w"), crate::config::qmax(lin.bits));
                    b.scalar(format!("{p}.qmax_a"), bits.qmax_a());
                    b.scalar(format!("{p}.w_en"), 0.0);
                    b.scalar(format!("{p}.a_en"), a_en);
                }
            }
            Pipeline::bind_globals(&mut b, 0.0, 2.0, 0.0, 1.0, 1.0);
            b
        } else {
            let mats: Vec<_> = (0..w)
                .map(|j| lazy.block(start + j))
                .collect::<Result<_>>()?;
            let blocks: Vec<_> = mats.iter().map(|m| (&m.params, &m.qstate)).collect();
            window_bindings(cfg.batch, cfg.seq, cfg.d_model, bits.qmax_a(), a_en, &blocks)
        };
        let pinned = self.rt.pin(exec, b.inner())?;
        let bytes = pinned.host_resident_bytes();
        Ok((pinned, bytes))
    }

    /// File span `(map, offset, len)` covering every `blocks.{j}.*` record
    /// of plan window `i` inside the snapshot mapping, for the eviction-
    /// time `MADV_DONTNEED` hint. `None` unless the snapshot source is a
    /// real memory mapping.
    fn window_file_span(&self, i: usize) -> Option<(Arc<mmap::Mmap>, usize, usize)> {
        let lazy = self.snap.model.lazy()?;
        let map = lazy.container().source.mapped()?.clone();
        let (start, w, _) = &self.plan[i];
        let (mut lo, mut hi) = (u64::MAX, 0u64);
        for j in *start..*start + *w {
            let prefix = format!("blocks.{j}.");
            for r in lazy.container().records.iter().filter(|r| r.name.starts_with(&prefix)) {
                lo = lo.min(r.offset);
                hi = hi.max(r.offset + r.len);
            }
        }
        (lo < hi).then(|| (map, lo as usize, (hi - lo) as usize))
    }

    /// Estimated heap bytes of window `i` once pinned (used to make room
    /// *before* materializing, so the byte budget bounds the peak, not
    /// just the steady state).
    fn window_bytes_estimate(&self, i: usize, packed: bool) -> u64 {
        let (start, w, _) = &self.plan[i];
        let (start, w) = (*start, *w);
        let cfg = &self.snap.meta.cfg;
        if packed {
            // codes + scales per linear, norms, plus a scalar-binding pad;
            // no target / v0 / LoRA placeholders are ever bound
            let per_blocks: u64 = match self.snap.model.lazy() {
                Some(lazy) => {
                    (0..w).map(|j| lazy.block_packed_resident_estimate(start + j)).sum()
                }
                None => 0,
            };
            return per_blocks + 1024 * w as u64;
        }
        let per_blocks: u64 = match self.snap.model.lazy() {
            Some(lazy) => (0..w).map(|j| lazy.block_resident_estimate(start + j)).sum(),
            None => 0,
        };
        // non-LoRA snapshots carry no a1/a2 records, but bind_qblock still
        // binds zero placeholders of the full LoRA shape per linear —
        // account them or the byte budget would be undershot
        let lora_placeholders: u64 = if matches!(self.snap.meta.rounding, RoundingMode::Lora) {
            0 // a1/a2 are real records, already in block_resident_estimate
        } else {
            let per_block: u64 = crate::quant::LINEARS
                .iter()
                .map(|l| {
                    let (fan_in, fan_out) = cfg.linear_shape(l);
                    4 * ((fan_in + fan_out) * cfg.rank_pad) as u64
                })
                .sum();
            per_block * w as u64
        };
        // + the pinned zero `target` activation each window binds, + a
        // conservative pad for the per-linear scalar bindings (qmax/enable
        // flags, globals) the record table doesn't cover — the estimate
        // must err high or a byte budget could transiently overshoot
        per_blocks
            + lora_placeholders
            + 4 * (cfg.batch * cfg.seq * cfg.d_model) as u64
            + 1024 * w as u64
    }

    /// Fetch (or fault in) the pinned statics for plan step `i`.
    ///
    /// Lazy path: hits bump LRU recency; on a miss, idle LRU windows are
    /// evicted until the budget has room, then the window materializes
    /// **outside** the cache lock — concurrent lanes hitting resident
    /// windows never wait behind an in-flight fault. Two lanes can fault
    /// the same window concurrently; the loser discards its copy (wasted
    /// work, both counted in `faults`, never a duplicate cache entry).
    /// A window still held by an in-flight dispatch (`Arc` shared) is
    /// never evicted, so under heavy concurrency the cache can transiently
    /// exceed the budget by the in-flight windows — it returns to budget
    /// as dispatches finish (a make-room pass also runs after each
    /// insert).
    fn step_pinned(&self, i: usize) -> Result<Arc<Pinned>> {
        match &self.steps {
            Steps::Eager(pins) => Ok(pins[i].clone()),
            Steps::Lazy { cache, max_windows, max_bytes, packed } => {
                let hit = {
                    let mut guard = cache.lock().unwrap_or_else(|e| e.into_inner());
                    // reborrow once so disjoint-field borrows (entries vs
                    // the counters) work through the guard
                    let c = &mut *guard;
                    c.tick += 1;
                    let tick = c.tick;
                    if let Some(win) = c.entries.get_mut(&i) {
                        win.last_use = tick;
                        c.hits += 1;
                        Some(win.pinned.clone())
                    } else {
                        c.faults += 1;
                        if c.prefetched.remove(&i) {
                            // a background prefetch warmed this window's
                            // file pages before the fault landed
                            c.prefetch_hits += 1;
                        }
                        // make room first so the budget bounds the peak
                        let est = self.window_bytes_estimate(i, *packed);
                        evict_idle(c, 1, est, *max_windows, *max_bytes);
                        None
                    }
                };
                let pinned = match hit {
                    Some(p) => p,
                    None => {
                        // the expensive part — unpack + (re)pack or
                        // dequantize + pin — runs with the cache unlocked
                        let (pinned, bytes) = self.materialize_window(i, *packed)?;
                        let pinned = Arc::new(pinned);
                        let mut guard = cache.lock().unwrap_or_else(|e| e.into_inner());
                        let c = &mut *guard;
                        c.tick += 1;
                        let tick = c.tick;
                        // a concurrent schedule_prefetch may have marked
                        // this window while we materialized unlocked; the
                        // window is resident either way now, so the marker
                        // is stale — without this, a later evict + re-fault
                        // would count a spurious prefetch_hit
                        c.prefetched.remove(&i);
                        if let Some(win) = c.entries.get_mut(&i) {
                            // another lane won the race while we were
                            // unlocked: share its pin, drop ours
                            win.last_use = tick;
                            win.pinned.clone()
                        } else {
                            c.resident_bytes += bytes;
                            let span = self.window_file_span(i);
                            c.entries.insert(
                                i,
                                LazyWindow { pinned: pinned.clone(), bytes, last_use: tick, span },
                            );
                            c.peak_bytes = c.peak_bytes.max(c.resident_bytes);
                            c.peak_windows = c.peak_windows.max(c.entries.len());
                            // room reserved before unlocking may have been
                            // taken by a concurrent fault — restore the
                            // budget (the new entry is protected: we still
                            // hold its Arc)
                            evict_idle(c, 0, 0, *max_windows, *max_bytes);
                            pinned
                        }
                    }
                };
                // overlap the *next* planned window's file I/O with this
                // window's execution
                self.prefetch_next(i, cache);
                Ok(pinned)
            }
        }
    }

    /// Issue a background prefetch for the window the plan visits after
    /// `i` (wrap-around: forwards loop the plan every batch). Fire-and-
    /// forget on the worker pool: `madvise(WILLNEED)` over the window's
    /// file span, then one volatile touch per page so the readahead
    /// actually commits before the fault lands. Best-effort by contract —
    /// a dropped prefetch only means the pages fault in on touch, exactly
    /// as without prefetch.
    fn prefetch_next(&self, i: usize, cache: &Mutex<WindowCache>) {
        if self.plan.len() < 2 {
            return; // single-window plans: it is already resident
        }
        let next = (i + 1) % self.plan.len();
        self.schedule_prefetch(next, cache);
    }

    /// Issue a background prefetch for window `i` if this is a lazy
    /// engine and `i` is a planned window — the public entry the generate
    /// loop uses to warm its first window of each decode step while the
    /// per-step admission/promotion bookkeeping runs (the per-access
    /// [`prefetch_next`](Self::prefetch_next) chain then covers the rest
    /// of the plan). No-op on eager engines; best-effort like all
    /// prefetches.
    pub fn prefetch_window(&self, i: usize) {
        if i >= self.plan.len() {
            return;
        }
        if let Steps::Lazy { cache, .. } = &self.steps {
            self.schedule_prefetch(i, cache);
        }
    }

    /// Shared prefetch scheduler: skip if the target window is resident
    /// or already in flight, otherwise count it and warm its file span on
    /// the worker pool.
    fn schedule_prefetch(&self, next: usize, cache: &Mutex<WindowCache>) {
        let Some((map, off, len)) = self.window_file_span(next) else {
            return; // not a real mapping: nothing to warm
        };
        {
            let mut guard = cache.lock().unwrap_or_else(|e| e.into_inner());
            let c = &mut *guard;
            if c.entries.contains_key(&next) || !c.prefetched.insert(next) {
                return; // resident, or a prefetch is already in flight
            }
            c.prefetches += 1;
        }
        pool::spawn_detached(move || {
            let _ = map.advise_range(mmap::Advice::WillNeed, off, len);
            let bytes = map.as_bytes();
            let end = (off + len).min(bytes.len());
            let mut acc = 0u8;
            let mut p = off;
            while p < end {
                // volatile: the read must survive optimization — its only
                // purpose is forcing the page in
                acc ^= unsafe { std::ptr::read_volatile(bytes.as_ptr().add(p)) };
                p += 4096;
            }
            std::hint::black_box(acc);
        });
    }

    /// The bound snapshot.
    pub fn snapshot(&self) -> &LoadedSnapshot {
        &self.snap
    }

    /// Number of window dispatches per forward (the covering length).
    pub fn plan_len(&self) -> usize {
        self.plan.len()
    }

    /// Does this engine pin windows lazily (mmap-loaded snapshot)?
    pub fn is_lazy(&self) -> bool {
        matches!(self.steps, Steps::Lazy { .. })
    }

    /// Does this engine pin windows in the packed domain (codes + scales,
    /// no dequantized f32 weights)? Implies [`Self::is_lazy`].
    pub fn is_packed(&self) -> bool {
        matches!(self.steps, Steps::Lazy { packed: true, .. })
    }

    /// Current window-residency accounting. For eager engines this is the
    /// static whole-plan figure; for lazy engines it reflects the LRU
    /// cache (`peak_bytes` is what the configured budget bounds).
    pub fn residency(&self) -> ResidencyStats {
        match &self.steps {
            Steps::Eager(pins) => {
                let bytes: u64 = pins.iter().map(|p| p.host_resident_bytes()).sum();
                ResidencyStats {
                    resident_windows: pins.len(),
                    resident_bytes: bytes,
                    peak_windows: pins.len(),
                    peak_bytes: bytes,
                    faults: pins.len() as u64,
                    hits: 0,
                    evictions: 0,
                    prefetches: 0,
                    prefetch_hits: 0,
                }
            }
            Steps::Lazy { cache, .. } => {
                let c = cache.lock().unwrap_or_else(|e| e.into_inner());
                ResidencyStats {
                    resident_windows: c.entries.len(),
                    resident_bytes: c.resident_bytes,
                    peak_windows: c.peak_windows,
                    peak_bytes: c.peak_bytes,
                    faults: c.faults,
                    hits: c.hits,
                    evictions: c.evictions,
                    prefetches: c.prefetches,
                    prefetch_hits: c.prefetch_hits,
                }
            }
        }
    }

    /// Forward a full token batch through the pinned block chain. The
    /// executables (and the pinned `target` buffer) are fixed-shape, so the
    /// batch must be exactly `[cfg.batch, cfg.seq]` — partial batches are
    /// padded by the [`RowExecutor`] path, not here.
    pub fn forward_hidden(&self, tokens: &TensorI32) -> Result<Tensor> {
        let cfg = &self.snap.meta.cfg;
        anyhow::ensure!(
            tokens.dims == [cfg.batch, cfg.seq],
            "engine serves fixed [{}, {}] batches, got {:?}",
            cfg.batch,
            cfg.seq,
            tokens.dims
        );
        let mut h = embed_lookup(&self.embed, &tokens.data, cfg.batch, cfg.seq);
        for i in 0..self.plan.len() {
            let pinned = self.step_pinned(i)?;
            let mut b = Bindings::new();
            b.set("h_in", h);
            let out = self.rt.run_pinned(&pinned, b.inner())?;
            h = out["h_out"].clone();
        }
        Ok(h)
    }
}

impl RowExecutor for ServeEngine<'_> {
    fn batch_rows(&self) -> usize {
        self.snap.meta.cfg.batch
    }

    fn seq(&self) -> usize {
        self.snap.meta.cfg.seq
    }

    fn execute(&self, rows: &[WorkRow]) -> Result<Vec<RowOut>> {
        let cfg = &self.snap.meta.cfg;
        let (bsz, seq) = (cfg.batch, cfg.seq);
        anyhow::ensure!(rows.len() <= bsz, "{} rows exceed batch {bsz}", rows.len());
        // pad the fixed-shape batch; padding rows are masked out entirely
        let mut inputs = vec![0i32; bsz * seq];
        let mut targets = vec![0i32; bsz * seq];
        let mut mask = vec![0.0f32; bsz * seq];
        for (r, row) in rows.iter().enumerate() {
            inputs[r * seq..(r + 1) * seq].copy_from_slice(&row.inputs);
            targets[r * seq..(r + 1) * seq].copy_from_slice(&row.targets);
            mask[r * seq..(r + 1) * seq].copy_from_slice(&row.mask);
        }
        let h = self.forward_hidden(&TensorI32::new(vec![bsz, seq], inputs))?;
        let mut b = Bindings::new();
        b.set("h", h);
        b.set_i32("targets", TensorI32::new(vec![bsz, seq], targets));
        b.set("mask", Tensor::new(vec![bsz, seq], mask));
        let out = self.rt.run_pinned(&self.lm_pinned, b.inner())?;
        let (nll, count) = (&out["nll"], &out["count"]);
        Ok(rows
            .iter()
            .enumerate()
            .map(|(r, _)| RowOut { nll: nll.data[r], count: count.data[r] })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::PinnedInner;

    fn dummy_window(bytes: u64, last_use: u64) -> LazyWindow {
        LazyWindow {
            pinned: Arc::new(Pinned {
                exec_name: "t".into(),
                inner: PinnedInner::Native(BTreeMap::new()),
            }),
            bytes,
            last_use,
            span: None,
        }
    }

    /// The stale-marker state only arises via a race (schedule_prefetch
    /// marking a window while step_pinned materializes it unlocked), so
    /// this constructs it directly: a window that is both resident and
    /// marked must lose its marker when evicted — otherwise its next
    /// re-fault counts a spurious prefetch_hit and the marker set grows
    /// without bound for windows that never re-fault.
    #[test]
    fn evict_idle_clears_stale_prefetch_marker() {
        let mut c = WindowCache::default();
        c.entries.insert(0, dummy_window(100, 1));
        c.entries.insert(1, dummy_window(100, 2));
        c.resident_bytes = 200;
        c.prefetched.insert(0);
        c.prefetched.insert(1);
        evict_idle(&mut c, 0, 0, 1, None);
        assert_eq!(c.entries.len(), 1);
        assert_eq!(c.evictions, 1);
        assert!(c.entries.contains_key(&1), "LRU must keep the more recent window");
        assert!(
            !c.prefetched.contains(&0),
            "eviction must clear the victim's marker (its warmed pages are DontNeed'd)"
        );
        assert!(c.prefetched.contains(&1), "the surviving window's marker is untouched");
    }

    #[test]
    fn evict_idle_respects_byte_budget_and_counts() {
        let mut c = WindowCache::default();
        for i in 0..3usize {
            c.entries.insert(i, dummy_window(100, i as u64));
        }
        c.resident_bytes = 300;
        evict_idle(&mut c, 0, 0, usize::MAX, Some(150));
        assert_eq!(c.entries.len(), 1);
        assert_eq!(c.resident_bytes, 100);
        assert_eq!(c.evictions, 2);
        assert!(c.entries.contains_key(&2), "eviction order must be LRU");
    }
}
