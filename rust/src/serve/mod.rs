//! Batched serving engine over snapshot-loaded quantized models.
//!
//! The quantize path (`coordinator`) produces a [`QuantizedModel`]; the
//! snapshot store (`snapshot`) persists it; this module serves it:
//!
//! * [`registry::ModelRegistry`] — loads `CBQS` files by name and keeps the
//!   reconstructed models resident;
//! * [`ServeEngine`] — binds a resident model to a [`Backend`]'s
//!   executables, covering the block chain with the *largest exported
//!   window executables* (the same greedy covering `forward_hidden` uses)
//!   and **pinning** every static input (weights, quant state, globals)
//!   once at engine build — device buffers on PJRT, retained host tensors
//!   on the native backend — so steady-state dispatches bind only the
//!   embedded token batch;
//! * [`batcher::Batcher`] — coalesces queued eval requests (perplexity
//!   segments, zero-shot choice items, forward-hidden calls) into maximal
//!   batches, optionally executes several window dispatches concurrently
//!   (`with_dispatch`, CLI `--dispatch`), and reports tokens/s, requests/s,
//!   batch occupancy and in-flight/lane-occupancy counters;
//! * [`scheduler::Scheduler`] — the live arrival loop on top of the
//!   batcher: seeded synthetic traces, Interactive/Batch/Background
//!   priority classes with weighted aging (no starvation), admission
//!   capacity re-credited as drain cycles complete, and per-class
//!   p50/p95/p99 queue+service latency folded into [`ServeStats`]. All
//!   decisions run on [`clock::Clock`] ticks; under [`clock::SimClock`]
//!   a trace replays to bitwise-identical responses and decisions for any
//!   dispatch lane count (CLI `cbq serve-bench --live`).
//!
//! Memory: `Value`/`Tensor` storage is `Arc`-backed, so the registry's
//! resident model, every engine bound to it, and every pinned executable
//! input all share **one** copy of each weight buffer — per process, not
//! per engine (refcount/pointer-identity assertions live in
//! `tests/backend.rs::export_load_serve_end_to_end_on_native`).

pub mod batcher;
pub mod clock;
pub mod registry;
pub mod scheduler;

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{window_plan, Pipeline};
use crate::runtime::{Artifacts, Backend, Bindings, Pinned};
use crate::tensor::{Tensor, TensorI32};

pub use batcher::{
    Batcher, ClassLat, Request, RequestKind, Response, RowExecutor, RowOut, ServeStats, WorkRow,
};
pub use clock::{Clock, RealClock, SimClock, TICKS_PER_SEC};
pub use registry::{LoadedSnapshot, ModelRegistry};
pub use scheduler::{
    synth_trace, Arrival, Decision, Lcg, LiveOutcome, Priority, Scheduler, SchedulerCfg, TraceSpec,
};

/// A snapshot model bound to the runtime: per-window pinned weight buffers
/// plus the pinned LM head, ready for row-batch execution.
pub struct ServeEngine<'rt> {
    rt: &'rt dyn Backend,
    snap: Arc<LoadedSnapshot>,
    /// (start block, window width, executable, pinned statics) per step of
    /// the greedy covering.
    steps: Vec<(usize, usize, String, Pinned)>,
    lm_pinned: Pinned,
}

impl<'rt> ServeEngine<'rt> {
    pub fn new(rt: &'rt dyn Backend, art: &Artifacts, snap: Arc<LoadedSnapshot>) -> Result<Self> {
        let cfg = &snap.meta.cfg;
        let name = &cfg.name;
        let model = &snap.model;
        let windows = art.windows(name);
        let plan = window_plan(&windows, cfg.n_layers);

        let qmax_a = model.bits.qmax_a();
        let a_en = if model.bits.act_enabled() { 1.0 } else { 0.0 };
        let h_dims = [cfg.batch, cfg.seq, cfg.d_model];

        let mut steps = Vec::with_capacity(plan.len());
        for &(start, w) in &plan {
            let exec = format!("win_fwd_w{w}_{name}");
            rt.spec(&exec)
                .with_context(|| format!("serve plan needs executable {exec}"))?;
            let mut b = Bindings::new();
            // everything except h_in is static for serving: pin it all,
            // including the (ignored) reconstruction target.
            b.set("target", Tensor::zeros(&h_dims));
            for j in 0..w {
                Pipeline::bind_block_weights(&mut b, j, &model.params.blocks[start + j]);
                // weights are baked (fake-quantized) => w_en = 0; activation
                // quant stays live with the learned alpha clips.
                Pipeline::bind_qblock(&mut b, j, &model.qstate[start + j], qmax_a, 0.0, a_en, false);
            }
            Pipeline::bind_globals(&mut b, 0.0, 2.0, 0.0, 1.0, 1.0);
            let pinned = rt.pin(&exec, b.inner())?;
            steps.push((start, w, exec, pinned));
        }

        let lm_exec = format!("lm_eval_{name}");
        rt.spec(&lm_exec)
            .with_context(|| format!("serve plan needs executable {lm_exec}"))?;
        let mut b = Bindings::new();
        b.set("final_norm", model.params.final_norm.clone());
        b.set("head", model.params.head.clone());
        let lm_pinned = rt.pin(&lm_exec, b.inner())?;

        Ok(Self { rt, snap, steps, lm_pinned })
    }

    pub fn snapshot(&self) -> &LoadedSnapshot {
        &self.snap
    }

    /// Number of window dispatches per forward (the covering length).
    pub fn plan_len(&self) -> usize {
        self.steps.len()
    }

    /// Forward a full token batch through the pinned block chain. The
    /// executables (and the pinned `target` buffer) are fixed-shape, so the
    /// batch must be exactly `[cfg.batch, cfg.seq]` — partial batches are
    /// padded by the [`RowExecutor`] path, not here.
    pub fn forward_hidden(&self, tokens: &TensorI32) -> Result<Tensor> {
        let cfg = &self.snap.meta.cfg;
        anyhow::ensure!(
            tokens.dims == [cfg.batch, cfg.seq],
            "engine serves fixed [{}, {}] batches, got {:?}",
            cfg.batch,
            cfg.seq,
            tokens.dims
        );
        let mut h = self.snap.model.params.embed_tokens(&tokens.data, cfg.batch, cfg.seq);
        for (_start, _w, _exec, pinned) in &self.steps {
            let mut b = Bindings::new();
            b.set("h_in", h);
            let out = self.rt.run_pinned(pinned, b.inner())?;
            h = out["h_out"].clone();
        }
        Ok(h)
    }
}

impl RowExecutor for ServeEngine<'_> {
    fn batch_rows(&self) -> usize {
        self.snap.meta.cfg.batch
    }

    fn seq(&self) -> usize {
        self.snap.meta.cfg.seq
    }

    fn execute(&self, rows: &[WorkRow]) -> Result<Vec<RowOut>> {
        let cfg = &self.snap.meta.cfg;
        let (bsz, seq) = (cfg.batch, cfg.seq);
        anyhow::ensure!(rows.len() <= bsz, "{} rows exceed batch {bsz}", rows.len());
        // pad the fixed-shape batch; padding rows are masked out entirely
        let mut inputs = vec![0i32; bsz * seq];
        let mut targets = vec![0i32; bsz * seq];
        let mut mask = vec![0.0f32; bsz * seq];
        for (r, row) in rows.iter().enumerate() {
            inputs[r * seq..(r + 1) * seq].copy_from_slice(&row.inputs);
            targets[r * seq..(r + 1) * seq].copy_from_slice(&row.targets);
            mask[r * seq..(r + 1) * seq].copy_from_slice(&row.mask);
        }
        let h = self.forward_hidden(&TensorI32::new(vec![bsz, seq], inputs))?;
        let mut b = Bindings::new();
        b.set("h", h);
        b.set_i32("targets", TensorI32::new(vec![bsz, seq], targets));
        b.set("mask", Tensor::new(vec![bsz, seq], mask));
        let out = self.rt.run_pinned(&self.lm_pinned, b.inner())?;
        let (nll, count) = (&out["nll"], &out["count"]);
        Ok(rows
            .iter()
            .enumerate()
            .map(|(r, _)| RowOut { nll: nll.data[r], count: count.data[r] })
            .collect())
    }
}
