//! Fuzz target: the CBQS v1/v2 container parser.
//!
//! Each iteration generates a valid container through the real writers,
//! applies 1–3 structure-aware mutations ([`super::mutate`]) and feeds the
//! result to `open_container` in **both** open modes, materializing every
//! record. The oracle:
//!
//! * a panic anywhere is a finding;
//! * a load that succeeds must be bit-exact against the clean container's
//!   [`corpus::entries_hash`] — *unless* a mutation recomputed the
//!   covering CRC, in which case the format genuinely cannot distinguish
//!   the file from an intentionally different one and only panics count;
//! * when both modes accept, they must agree with each other bitwise
//!   (eager/lazy differential).
//!
//! Findings are minimized by end-truncation (while the failure class
//! reproduces) and persisted as `CBQF` fixtures.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use super::corpus::{self, Fnv64};
use super::mutate;
use super::rng::FuzzRng;
use super::{
    catch, with_quiet_panics, write_fixture, Finding, Fixture, FuzzOpts, FuzzReport,
    FIXTURE_EXPECT_ACCEPT, FIXTURE_EXPECT_NO_PANIC, FIXTURE_EXPECT_REJECT,
    FIXTURE_TARGET_SNAPSHOT,
};
use crate::snapshot::format::{self, OpenMode};

/// How one mutated container fared against the oracle.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Verdict {
    /// Loaded bit-exactly in every mode that accepted it.
    LoadExact,
    /// Rejected with a clean error in both modes.
    CleanError,
    /// Accepted with different content, but a CRC-fixed mutation makes
    /// that indistinguishable from a legitimately different file.
    AllowedDivergence,
    /// Parser panicked (message withheld from the digest — it may embed
    /// scratch paths).
    Panic(String),
    /// Accepted a CRC-covered corruption silently (hash mismatch with no
    /// CRC fix-up), or the two open modes disagreed on content.
    SilentCorruption(String),
}

impl Verdict {
    /// Stable code folded into the run digest (never the message).
    fn code(&self) -> u64 {
        match self {
            Verdict::LoadExact => 1,
            Verdict::CleanError => 2,
            Verdict::AllowedDivergence => 3,
            Verdict::Panic(_) => 4,
            Verdict::SilentCorruption(_) => 5,
        }
    }

    fn is_finding(&self) -> bool {
        matches!(self, Verdict::Panic(_) | Verdict::SilentCorruption(_))
    }
}

/// Open `path` in `mode` and materialize every record, returning the
/// content hash. `Ok(Err)` is a clean parser rejection; the outer `Err`
/// is a captured panic message.
fn load_hash(path: &Path, mode: OpenMode) -> std::result::Result<Result<u64>, String> {
    catch(|| {
        let c = format::open_container(path, mode)?;
        let mut entries = std::collections::BTreeMap::new();
        for rec in &c.records {
            entries.insert(rec.name.clone(), c.materialize(rec)?);
        }
        Ok(corpus::entries_hash(&entries))
    })
}

/// Judge one mutated byte string against the oracle. `crc_fixed` reports
/// whether any applied mutation recomputed the covering CRC.
fn judge(bytes: &[u8], clean_hash: u64, crc_fixed: bool, case_path: &Path) -> Verdict {
    if std::fs::write(case_path, bytes).is_err() {
        return Verdict::CleanError; // scratch unwritable: skip, don't crash
    }
    let mut hashes: Vec<Option<u64>> = Vec::with_capacity(2);
    for mode in [OpenMode::Eager, OpenMode::Lazy] {
        match load_hash(case_path, mode) {
            Err(msg) => return Verdict::Panic(msg),
            Ok(Err(_)) => hashes.push(None),
            Ok(Ok(h)) => hashes.push(Some(h)),
        }
    }
    let accepted: Vec<u64> = hashes.iter().flatten().copied().collect();
    if accepted.is_empty() {
        return Verdict::CleanError;
    }
    if accepted.len() == 2 && accepted[0] != accepted[1] {
        return Verdict::SilentCorruption(format!(
            "eager and lazy loads disagree: {:#x} vs {:#x}",
            accepted[0], accepted[1]
        ));
    }
    if accepted.iter().all(|&h| h == clean_hash) {
        return Verdict::LoadExact;
    }
    if crc_fixed {
        Verdict::AllowedDivergence
    } else {
        Verdict::SilentCorruption(format!(
            "load accepted CRC-covered corruption: hash {:#x} != clean {:#x}",
            accepted[0], clean_hash
        ))
    }
}

/// Shrink a failing case by end-truncation: repeatedly drop the largest
/// tail suffix that keeps the *same* failure class reproducing.
fn minimize(bytes: &[u8], clean_hash: u64, crc_fixed: bool, scratch: &Path) -> Vec<u8> {
    let failing = judge(bytes, clean_hash, crc_fixed, scratch);
    debug_assert!(failing.is_finding());
    let same_class = |v: &Verdict| v.code() == failing.code();
    let mut best = bytes.to_vec();
    let mut chunk = best.len() / 2;
    while chunk > 0 {
        while best.len() > chunk {
            let candidate = &best[..best.len() - chunk];
            if same_class(&judge(candidate, clean_hash, crc_fixed, scratch)) {
                best = candidate.to_vec();
            } else {
                break;
            }
        }
        chunk /= 2;
    }
    best
}

/// Replay a fixture payload (regression suite): `expect` reject means both
/// open modes must return a clean error without panicking; `expect` accept
/// means both must load bit-exactly to `clean_hash`; `expect` no-panic
/// means any clean outcome is fine — but an accepted load must still be
/// bit-exact when `clean_hash` is non-zero.
pub fn replay_bytes(payload: &[u8], expect: u8, clean_hash: u64, scratch: &Path) -> Result<()> {
    std::fs::write(scratch, payload)?;
    for mode in [OpenMode::Eager, OpenMode::Lazy] {
        match load_hash(scratch, mode) {
            Err(msg) => bail!("parser panicked under {mode:?}: {msg}"),
            Ok(Err(e)) => {
                if expect == FIXTURE_EXPECT_ACCEPT {
                    bail!("expected clean load under {mode:?}, got error: {e:#}");
                }
            }
            Ok(Ok(h)) => {
                if expect == FIXTURE_EXPECT_REJECT {
                    bail!("expected rejection under {mode:?}, but payload loaded (hash {h:#x})");
                }
                let must_match = expect == FIXTURE_EXPECT_ACCEPT
                    || (expect == FIXTURE_EXPECT_NO_PANIC && clean_hash != 0);
                if must_match && h != clean_hash {
                    bail!("load under {mode:?} not bit-exact: {h:#x} != {clean_hash:#x}");
                }
            }
        }
    }
    Ok(())
}

/// Run the snapshot fuzz target.
pub fn run(opts: &FuzzOpts) -> Result<FuzzReport> {
    let mut rng = FuzzRng::new(opts.seed);
    let mut digest = Fnv64::new();
    let mut findings: Vec<Finding> = Vec::new();
    let (mut cases_ok, mut cases_rejected) = (0u64, 0u64);
    let gen_path = opts.scratch.join("snapshot_gen.cbqs");
    let case_path = opts.scratch.join("snapshot_case.cbqs");

    with_quiet_panics(|| -> Result<()> {
        for iter in 0..opts.iters {
            let case = corpus::gen_container(&mut rng, &gen_path)?;
            digest.update_u64(case.clean_hash);

            let mut bytes = case.bytes.clone();
            let n_mut = rng.range(1, 3);
            let mut crc_fixed = false;
            let mut trail: Vec<String> = Vec::with_capacity(n_mut);
            for _ in 0..n_mut {
                let m = mutate::mutate_container(&mut bytes, &mut rng);
                crc_fixed |= m.crc_fixed;
                trail.push(m.desc);
            }
            digest.update_u64(format::crc32(&bytes) as u64);

            let verdict = judge(&bytes, case.clean_hash, crc_fixed, &case_path);
            digest.update_u64(verdict.code());
            match &verdict {
                Verdict::LoadExact | Verdict::AllowedDivergence => cases_ok += 1,
                Verdict::CleanError => cases_rejected += 1,
                Verdict::Panic(msg) | Verdict::SilentCorruption(msg) => {
                    let minimized = minimize(&bytes, case.clean_hash, crc_fixed, &case_path);
                    // a silent-corruption repro must *reject* once fixed; a
                    // panic repro's post-fix fate is open (no-panic, and
                    // bit-exact if it loads — unless a CRC fix-up makes the
                    // content legitimately different)
                    let (expect, hash) = if matches!(verdict, Verdict::SilentCorruption(_)) {
                        (FIXTURE_EXPECT_REJECT, case.clean_hash)
                    } else {
                        (FIXTURE_EXPECT_NO_PANIC, if crc_fixed { 0 } else { case.clean_hash })
                    };
                    let fixture = opts.fixtures.as_ref().map(|dir| -> Result<PathBuf> {
                        let p = dir.join(format!(
                            "snapshot_seed{}_iter{iter}.cbqf",
                            opts.seed
                        ));
                        write_fixture(
                            &p,
                            &Fixture {
                                target: FIXTURE_TARGET_SNAPSHOT,
                                expect,
                                clean_hash: hash,
                                payload: minimized.clone(),
                            },
                        )?;
                        Ok(p)
                    });
                    let fixture = match fixture {
                        Some(Ok(p)) => Some(p),
                        _ => None,
                    };
                    findings.push(Finding {
                        iter,
                        summary: format!(
                            "{} — v{} container, mutations: [{}] ({} bytes minimized to {})",
                            msg,
                            case.version,
                            trail.join("; "),
                            bytes.len(),
                            minimized.len()
                        ),
                        fixture,
                    });
                }
            }
        }
        Ok(())
    })?;
    std::fs::remove_file(&case_path).ok();

    Ok(FuzzReport {
        target: "snapshot".to_string(),
        seed: opts.seed,
        iters: opts.iters,
        digest: digest.finish(),
        cases_ok,
        cases_rejected,
        findings,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cbq_snapfuzz_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn short_run_is_clean_and_reproducible() {
        let dir = scratch("repro");
        let opts = FuzzOpts { seed: 7, iters: 40, scratch: dir.clone(), fixtures: None };
        let a = run(&opts).unwrap();
        let b = run(&opts).unwrap();
        assert_eq!(a.digest, b.digest, "equal seeds must replay to equal digests");
        assert_eq!(a.cases_ok, b.cases_ok);
        assert_eq!(a.cases_rejected, b.cases_rejected);
        assert!(
            a.findings.is_empty(),
            "snapshot parser findings on a healthy tree: {:#?}",
            a.findings
        );
        assert_eq!(a.cases_ok + a.cases_rejected, 40);
        // different seed, different walk
        let c = run(&FuzzOpts { seed: 8, iters: 40, scratch: dir.clone(), fixtures: None })
            .unwrap();
        assert_ne!(a.digest, c.digest);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The injected-bug drill from the acceptance criteria, inverted: the
    /// oracle itself must catch a "parser" that silently accepts corrupted
    /// content. We simulate the buggy parser by handing `judge` a *wrong*
    /// clean-hash for a pristine file — equivalent to the parser returning
    /// wrong content — and for real corruption we assert the true parser
    /// already rejects what the oracle would otherwise flag.
    #[test]
    fn oracle_flags_silent_corruption() {
        let dir = scratch("oracle");
        let case_path = dir.join("case.cbqs");
        let mut rng = FuzzRng::new(3);
        let case = corpus::gen_container(&mut rng, &dir.join("gen.cbqs")).unwrap();

        // pristine bytes + correct hash: exact
        let v = judge(&case.bytes, case.clean_hash, false, &case_path);
        assert_eq!(v, Verdict::LoadExact);

        // pristine bytes + wrong expected hash (a stand-in for a decoder
        // that returns corrupted tensors): the oracle must flag it
        let v = judge(&case.bytes, case.clean_hash ^ 1, false, &case_path);
        assert!(
            matches!(v, Verdict::SilentCorruption(_)),
            "oracle must flag a non-bit-exact accepted load, got {v:?}"
        );

        // flipping one checksum-covered byte without fixing the CRC must
        // already be rejected by the real parser (clean error, no panic)
        let mut corrupt = case.bytes.clone();
        let mid = corrupt.len() / 2;
        corrupt[mid] ^= 0x40;
        let v = with_quiet_panics(|| judge(&corrupt, case.clean_hash, false, &case_path));
        assert!(
            matches!(v, Verdict::CleanError | Verdict::LoadExact),
            "CRC-covered flip must be rejected cleanly (or be a padding no-op), got {v:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn minimization_shrinks_while_preserving_failure_class() {
        let dir = scratch("minim");
        let case_path = dir.join("case.cbqs");
        let mut rng = FuzzRng::new(5);
        let case = corpus::gen_container(&mut rng, &dir.join("gen.cbqs")).unwrap();
        // a wrong clean-hash makes the pristine file "fail" — minimization
        // must shrink it while the SilentCorruption class keeps reproducing
        let wrong = case.clean_hash ^ 0xFF;
        let v = judge(&case.bytes, wrong, false, &case_path);
        assert!(v.is_finding());
        let min = minimize(&case.bytes, wrong, false, &case_path);
        assert!(min.len() <= case.bytes.len());
        let v2 = judge(&min, wrong, false, &case_path);
        assert_eq!(v2.code(), v.code(), "minimized case must reproduce the same class");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn replay_bytes_enforces_expectations() {
        let dir = scratch("replay");
        let mut rng = FuzzRng::new(9);
        let case = corpus::gen_container(&mut rng, &dir.join("gen.cbqs")).unwrap();
        let p = dir.join("replay.cbqs");
        // accept-expectation on the pristine container passes
        replay_bytes(&case.bytes, FIXTURE_EXPECT_ACCEPT, case.clean_hash, &p).unwrap();
        // reject-expectation on the pristine container fails (it loads)
        assert!(replay_bytes(&case.bytes, FIXTURE_EXPECT_REJECT, case.clean_hash, &p).is_err());
        // truncated-to-8-bytes must satisfy a reject expectation
        replay_bytes(&case.bytes[..8.min(case.bytes.len())], FIXTURE_EXPECT_REJECT, 0, &p)
            .unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
