//! Fuzz target: trace ingestion — the scheduler's live-arrival loop and
//! the generate engine's continuous-batching loop.
//!
//! Two attack surfaces per iteration:
//!
//! * **byte-level** — a valid trace is serialized through the `CBQT`
//!   codec, mutated blindly, and decoded; the decoder must reject
//!   malformed frames cleanly, and whatever decodes must still be safe to
//!   run;
//! * **structure-level** — the decoded trace is mutated semantically
//!   (unsorted arrivals, duplicated entries, zero-row and over-cap
//!   requests, degenerate rows, extreme arrival times) and fed to the
//!   scheduler.
//!
//! The oracle: never a panic; an unsorted or zero-row trace must be
//! rejected (both are `ensure!`d in the scheduler); any accepted run must
//! satisfy conservation (every request admitted or rejected exactly once,
//! admitted ⇒ dispatched and answered, rejected ⇒ `Response::Rejected`)
//! and replay bitwise — including across dispatch lane counts, which the
//! scheduler guarantees by design. Every ~16th iteration additionally runs
//! a mutated *generation* trace through [`GenerateEngine`] over the shared
//! [`FuzzEnv`] model, checking the same conservation + lane-independence
//! invariants on decode scheduling.

use anyhow::{bail, Result};

use super::corpus::{self, Fnv64};
use super::env::FuzzEnv;
use super::rng::FuzzRng;
use super::{
    catch, with_quiet_panics, write_fixture, Finding, Fixture, FuzzOpts, FuzzReport,
    FIXTURE_EXPECT_ACCEPT, FIXTURE_EXPECT_NO_PANIC, FIXTURE_EXPECT_REJECT, FIXTURE_TARGET_TRACE,
};
use crate::serve::scheduler::{synth_trace, Arrival, Scheduler, SchedulerCfg, TraceSpec};
use crate::serve::{
    synth_gen_trace, GenCfg, GenTraceSpec, GenerateEngine, LiveOutcome, LoadMode, Response,
    RowExecutor, RowOut, SimClock, WorkRow,
};

/// Deterministic executor for fuzzed schedules: every row's result is a
/// pure function of its own content, with **no shape assertions** — the
/// fuzzer feeds degenerate rows on purpose, and determinism (not shape
/// policing) is what this mock is for.
struct FuzzExec {
    batch: usize,
    seq: usize,
}

impl RowExecutor for FuzzExec {
    fn batch_rows(&self) -> usize {
        self.batch
    }
    fn seq(&self) -> usize {
        self.seq
    }
    fn execute(&self, rows: &[WorkRow]) -> Result<Vec<RowOut>> {
        Ok(rows
            .iter()
            .map(|r| RowOut {
                nll: r
                    .targets
                    .iter()
                    .zip(&r.mask)
                    .map(|(&t, &m)| t.rem_euclid(23) as f32 * 0.25 * m)
                    .sum(),
                count: r.mask.iter().sum(),
            })
            .collect())
    }
}

/// Structural properties the scheduler is contractually required to
/// reject.
#[derive(Clone, Copy, Debug)]
struct Flaws {
    unsorted: bool,
    zero_rows: bool,
}

fn flaws(trace: &[Arrival]) -> Flaws {
    Flaws {
        unsorted: trace.windows(2).any(|w| w[0].at > w[1].at),
        zero_rows: trace.iter().any(|a| a.request.rows.is_empty()),
    }
}

/// Stable digest of a run's decision log (message-free by construction).
fn outcome_hash(out: &LiveOutcome) -> u64 {
    let mut h = Fnv64::new();
    for d in &out.decisions {
        h.update_u64(d.seq as u64);
        h.update_u64(d.class.index() as u64);
        h.update_u64(d.arrival);
        h.update_u64(d.rows as u64);
        h.update_u64(d.admitted as u64);
        h.update_u64(d.shed as u64);
        h.update_u64(d.cycle as u64);
        h.update_u64(d.dispatch_time);
        h.update_u64(d.complete_time);
    }
    h.update_u64(out.cycles as u64);
    h.finish()
}

/// Arrival-time cap for "huge tick" mutations: far past any realistic
/// trace, but with headroom so modeled service time cannot overflow `u64`
/// arithmetic downstream (overflow at the extreme edge would be a real
/// finding, but one the format can never produce — ticks are offsets from
/// run start).
const HUGE_AT: u64 = u64::MAX / 4;

/// Apply one semantic trace mutation; returns its description.
fn mutate_trace(trace: &mut Vec<Arrival>, rng: &mut FuzzRng) -> String {
    if trace.is_empty() {
        return "noop (empty trace)".to_string();
    }
    let i = rng.index(trace.len());
    match rng.below(8) {
        0 => {
            // break time-sortedness by inflating an early arrival
            trace[i].at = trace[i].at.saturating_add(1 + rng.below(1 << 20));
            format!("inflate at[{i}] (unsorted unless last)")
        }
        1 => {
            let dup = trace[i].clone();
            trace.insert(i, dup);
            format!("duplicate arrival {i}")
        }
        2 => {
            trace[i].request.rows.clear();
            format!("zero rows on request {i}")
        }
        3 => {
            // degenerate row: a zero-length request (tokens.len() < 2)
            trace[i].request.rows =
                vec![WorkRow { inputs: vec![], targets: vec![], mask: vec![] }];
            format!("empty row on request {i}")
        }
        4 => {
            // over-cap: more rows than any queue cap the oracle configures
            let row = trace[i].request.rows.first().cloned().unwrap_or(WorkRow {
                inputs: vec![],
                targets: vec![],
                mask: vec![],
            });
            let n = rng.range(33, 64);
            trace[i].request.rows = vec![row; n];
            format!("inflate request {i} to {n} rows")
        }
        5 => {
            let cls = crate::serve::Priority::ALL[rng.index(3)];
            trace[i].class = cls;
            format!("class[{i}] := {}", cls.name())
        }
        6 => {
            let last = trace.len() - 1;
            trace[last].at = HUGE_AT + rng.below(1 << 16);
            "huge at on last arrival".to_string()
        }
        _ => {
            if let Some(r) = trace[i].request.rows.first_mut() {
                r.inputs.pop();
                r.mask.push(1.0);
            }
            format!("shape-skew row 0 of request {i}")
        }
    }
}

/// Blind byte mutation for the `CBQT` frame (the codec has its own
/// grammar, so container-specific mutations don't apply).
fn mutate_bytes(bytes: &mut Vec<u8>, rng: &mut FuzzRng) -> String {
    match rng.below(5) {
        0 => {
            let cut = rng.range(0, bytes.len().saturating_sub(1));
            bytes.truncate(cut);
            format!("truncate to {cut}")
        }
        1 => {
            let extra = rng.range(1, 16);
            for _ in 0..extra {
                let b = rng.byte();
                bytes.push(b);
            }
            format!("append {extra} bytes")
        }
        2 if !bytes.is_empty() => {
            let at = rng.index(bytes.len());
            bytes[at] ^= rng.flip_mask();
            format!("flip at {at}")
        }
        3 if bytes.len() >= 12 => {
            // splash a length/count field region with a huge value
            let at = 8 + 4 * rng.index((bytes.len() - 8) / 4);
            let v = [u32::MAX, 1 << 24, 0x8000_0000][rng.index(3)];
            bytes[at..at + 4].copy_from_slice(&v.to_le_bytes());
            format!("len splash at {at}")
        }
        _ if !bytes.is_empty() => {
            let at = rng.index(bytes.len());
            let n = rng.range(1, 8).min(bytes.len() - at);
            bytes[at..at + n].fill(0xFF);
            format!("fill {n} at {at}")
        }
        _ => "noop (empty frame)".to_string(),
    }
}

/// How one trace case fared.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Verdict {
    RanClean(u64),
    CleanError,
    Panic(String),
    InvariantViolation(String),
}

impl Verdict {
    fn code(&self) -> u64 {
        match self {
            Verdict::RanClean(_) => 1,
            Verdict::CleanError => 2,
            Verdict::Panic(_) => 3,
            Verdict::InvariantViolation(_) => 4,
        }
    }

    fn is_finding(&self) -> bool {
        matches!(self, Verdict::Panic(_) | Verdict::InvariantViolation(_))
    }
}

fn scheduler_cfg(dispatch: usize, queue_cap: Option<usize>) -> SchedulerCfg {
    SchedulerCfg { dispatch, queue_cap, ..SchedulerCfg::default() }
}

/// Run `trace` through the scheduler under the full oracle. `seq` sizes
/// the mock executor; `queue_cap` optionally bounds admission.
fn judge_trace(trace: &[Arrival], seq: usize, queue_cap: Option<usize>) -> Verdict {
    let exec = FuzzExec { batch: 4, seq };
    let fl = flaws(trace);
    let mut runs: Vec<Option<LiveOutcome>> = Vec::with_capacity(3);
    for dispatch in [1usize, 1, 3] {
        let clock = SimClock::new();
        let sched = Scheduler::new(&clock, scheduler_cfg(dispatch, queue_cap));
        match catch(|| sched.run(&exec, trace)) {
            Err(msg) => return Verdict::Panic(msg),
            Ok(Err(_)) => runs.push(None),
            Ok(Ok(out)) => runs.push(Some(out)),
        }
    }
    let accepted = runs.iter().flatten().count();
    if fl.unsorted || fl.zero_rows {
        return if accepted == 0 {
            Verdict::CleanError
        } else {
            Verdict::InvariantViolation(format!(
                "structurally invalid trace accepted (unsorted={}, zero_rows={})",
                fl.unsorted, fl.zero_rows
            ))
        };
    }
    if accepted == 0 {
        return Verdict::CleanError;
    }
    if accepted != runs.len() {
        return Verdict::InvariantViolation(
            "acceptance differs across identical/lane-varied runs".to_string(),
        );
    }
    let outs: Vec<&LiveOutcome> = runs.iter().flatten().collect();
    // bitwise replay: run 0 and 1 share every parameter; run 2 differs
    // only in lane count, which must not change decisions or responses
    for (label, other) in [("replay", outs[1]), ("dispatch=3", outs[2])] {
        if outs[0].decisions != other.decisions {
            return Verdict::InvariantViolation(format!("decision log differs under {label}"));
        }
        if outs[0].responses != other.responses {
            return Verdict::InvariantViolation(format!("responses differ under {label}"));
        }
    }
    let out = outs[0];
    if out.responses.len() != trace.len() || out.decisions.len() != trace.len() {
        return Verdict::InvariantViolation(format!(
            "conservation: {} responses / {} decisions for {} requests",
            out.responses.len(),
            out.decisions.len(),
            trace.len()
        ));
    }
    for (i, d) in out.decisions.iter().enumerate() {
        let rejected = matches!(out.responses[i], Response::Rejected);
        if d.admitted == rejected {
            return Verdict::InvariantViolation(format!(
                "request {i}: admitted={} but rejected-response={}",
                d.admitted, rejected
            ));
        }
        if d.admitted && d.cycle == usize::MAX {
            return Verdict::InvariantViolation(format!("request {i}: admitted, never dispatched"));
        }
        if d.admitted && d.complete_time < d.dispatch_time {
            return Verdict::InvariantViolation(format!("request {i}: completes before dispatch"));
        }
    }
    Verdict::RanClean(outcome_hash(out))
}

/// Minimize a structurally-failing trace: greedily drop arrivals while the
/// verdict class still reproduces. Only runs on findings (normally never),
/// so the quadratic re-judging cost is irrelevant.
fn minimize_trace(
    trace: &[Arrival],
    seq: usize,
    queue_cap: Option<usize>,
    verdict: &Verdict,
) -> Vec<Arrival> {
    let mut keep = trace.to_vec();
    let mut i = 0;
    while i < keep.len() && keep.len() > 1 {
        let mut cand = keep.clone();
        cand.remove(i);
        if judge_trace(&cand, seq, queue_cap).code() == verdict.code() {
            keep = cand; // still fails the same way without arrival i
        } else {
            i += 1;
        }
    }
    keep
}

/// Replay a trace fixture payload (regression suite).
pub fn replay_bytes(payload: &[u8], expect: u8) -> Result<()> {
    let decoded = match catch(|| corpus::decode_trace(payload)) {
        Err(msg) => bail!("trace decoder panicked: {msg}"),
        Ok(Err(e)) => {
            if expect == FIXTURE_EXPECT_ACCEPT {
                bail!("expected decodable trace, got error: {e:#}");
            }
            return Ok(()); // clean decode rejection satisfies reject/no-panic
        }
        Ok(Ok(t)) => t,
    };
    let seq = fixture_seq(&decoded);
    match judge_trace(&decoded, seq, None) {
        Verdict::Panic(msg) => bail!("scheduler panicked: {msg}"),
        Verdict::InvariantViolation(msg) => bail!("invariant violation: {msg}"),
        Verdict::CleanError => {
            if expect == FIXTURE_EXPECT_ACCEPT {
                bail!("expected clean run, scheduler rejected the trace");
            }
            Ok(())
        }
        Verdict::RanClean(_) => {
            if expect == FIXTURE_EXPECT_REJECT {
                bail!("expected rejection, but the trace ran clean");
            }
            Ok(())
        }
    }
}

/// The executor row length a fixture replays under: the first non-empty
/// row's length (a pure function of the payload, so replays agree).
fn fixture_seq(trace: &[Arrival]) -> usize {
    trace
        .iter()
        .flat_map(|a| &a.request.rows)
        .map(|r| r.inputs.len())
        .find(|&l| l > 0)
        .unwrap_or(6)
}

/// Run one generation-trace case against the generate engine. The engine
/// sorts arrivals itself, so nothing is "invalid" — the oracle is: no
/// panic, conservation (`offered == admitted + rejected` per step,
/// `completed + rejected == requests`), and bitwise lane-independence.
fn judge_gen_trace(env: &FuzzEnv, eng: &GenerateEngine<'_, '_>, rng: &mut FuzzRng) -> Verdict {
    let spec = GenTraceSpec {
        requests: rng.range(1, 8),
        mean_gap: rng.below(200),
        seed: rng.next_u64(),
        vocab: env.cfg.vocab,
        max_prompt: env.cfg.seq + 2, // over-length prompts get rejected at admission
        max_new_tokens: rng.range(1, 5),
    };
    let mut arrivals = synth_gen_trace(&spec);
    // adversarial edits: empty prompts, zero budgets, extreme ticks,
    // shuffled order (the engine re-sorts by (at, index))
    for _ in 0..rng.range(0, 2) {
        if arrivals.is_empty() {
            break;
        }
        let i = rng.index(arrivals.len());
        match rng.below(4) {
            0 => arrivals[i].request.prompt.clear(),
            1 => arrivals[i].request.max_new_tokens = 0,
            2 => arrivals[i].at = HUGE_AT + rng.below(1 << 12),
            _ => {
                let j = rng.index(arrivals.len());
                arrivals.swap(i, j);
            }
        }
    }
    let cfg = GenCfg {
        max_new_tokens: 4,
        slots: rng.range(1, 3),
        queue_cap: if rng.chance(1, 3) { Some(rng.range(1, 4)) } else { None },
        ..GenCfg::default()
    };
    let mut outs = Vec::with_capacity(2);
    for dispatch in [1usize, 2] {
        let cfg = GenCfg { dispatch, ..cfg.clone() };
        let clock = SimClock::new();
        match catch(|| eng.run(&arrivals, &cfg, &clock)) {
            Err(msg) => return Verdict::Panic(msg),
            Ok(Err(_)) => outs.push(None),
            Ok(Ok(o)) => outs.push(Some(o)),
        }
    }
    match (&outs[0], &outs[1]) {
        (None, None) => Verdict::CleanError,
        (Some(_), None) | (None, Some(_)) => Verdict::InvariantViolation(
            "generate acceptance differs across lane counts".to_string(),
        ),
        (Some((o1, s1)), Some((o2, s2))) => {
            if o1 != o2 {
                return Verdict::InvariantViolation(
                    "generate outcomes differ across lane counts".to_string(),
                );
            }
            if o1.len() != arrivals.len() {
                return Verdict::InvariantViolation(format!(
                    "generate conservation: {} outcomes for {} arrivals",
                    o1.len(),
                    arrivals.len()
                ));
            }
            if s1.completed + s1.rejected != s1.requests || s1.requests != arrivals.len() as u64 {
                return Verdict::InvariantViolation(format!(
                    "generate accounting: {} completed + {} rejected != {} requests",
                    s1.completed, s1.rejected, s1.requests
                ));
            }
            for (si, st) in s1.steps.iter().enumerate() {
                if st.offered != st.admitted + st.rejected {
                    return Verdict::InvariantViolation(format!(
                        "step {si}: offered {} != admitted {} + rejected {}",
                        st.offered, st.admitted, st.rejected
                    ));
                }
            }
            let mut h = Fnv64::new();
            for o in o1 {
                h.update_u64(o.seq as u64);
                h.update_u64(o.rejected as u64);
                h.update_u64(o.tokens.len() as u64);
                for &t in &o.tokens {
                    h.update_u64(t as u64);
                }
                h.update_u64(o.finish);
            }
            h.update_u64(s2.decode_steps);
            Verdict::RanClean(h.finish())
        }
    }
}

/// Run the trace fuzz target.
pub fn run(opts: &FuzzOpts) -> Result<FuzzReport> {
    let mut rng = FuzzRng::new(opts.seed);
    let mut digest = Fnv64::new();
    let mut findings: Vec<Finding> = Vec::new();
    let (mut cases_ok, mut cases_rejected) = (0u64, 0u64);
    // the generate leg needs the engine substrate; built once, lazily, and
    // only when the budget actually reaches a generate iteration
    let mut env: Option<FuzzEnv> = None;

    with_quiet_panics(|| -> Result<()> {
        for iter in 0..opts.iters {
            let spec = TraceSpec {
                seed: rng.next_u64(),
                requests: rng.range(1, 24),
                mean_gap_ticks: rng.below(500),
                seq: rng.range(4, 8),
                vocab: 40,
                priorities: true,
            };
            let mut trace = synth_trace(&spec);
            let mut trail: Vec<String> = Vec::new();

            let byte_level = rng.chance(1, 3);
            if byte_level {
                let mut bytes = corpus::encode_trace(&trace);
                for _ in 0..rng.range(1, 4) {
                    trail.push(mutate_bytes(&mut bytes, &mut rng));
                }
                match catch(|| corpus::decode_trace(&bytes)) {
                    Err(msg) => {
                        digest.update_u64(90);
                        findings.push(finding(
                            iter,
                            format!("trace decoder panicked: {msg} — [{}]", trail.join("; ")),
                            opts,
                            &bytes,
                            FIXTURE_EXPECT_NO_PANIC,
                        ));
                        continue;
                    }
                    Ok(Err(_)) => {
                        digest.update_u64(91);
                        cases_rejected += 1;
                        continue;
                    }
                    Ok(Ok(t)) => trace = t,
                }
            } else {
                for _ in 0..rng.range(1, 3) {
                    trail.push(mutate_trace(&mut trace, &mut rng));
                }
            }

            let seq = spec.seq;
            let queue_cap = if rng.chance(1, 2) { Some(rng.range(4, 32)) } else { None };
            let verdict = judge_trace(&trace, seq, queue_cap);
            digest.update_u64(verdict.code());
            if let Verdict::RanClean(h) = &verdict {
                digest.update_u64(*h);
            }
            match &verdict {
                Verdict::RanClean(_) => cases_ok += 1,
                Verdict::CleanError => cases_rejected += 1,
                Verdict::Panic(msg) | Verdict::InvariantViolation(msg) => {
                    let minimal = minimize_trace(&trace, seq, queue_cap, &verdict);
                    let payload = corpus::encode_trace(&minimal);
                    let expect = if matches!(verdict, Verdict::Panic(_)) {
                        FIXTURE_EXPECT_NO_PANIC
                    } else {
                        FIXTURE_EXPECT_REJECT
                    };
                    findings.push(finding(
                        iter,
                        format!("{msg} — mutations: [{}]", trail.join("; ")),
                        opts,
                        &payload,
                        expect,
                    ));
                }
            }

            // generate-engine leg, rate-limited (each run costs real decode
            // steps on the synthetic model)
            if iter % 16 == 0 {
                if env.is_none() {
                    env = Some(FuzzEnv::build(&opts.scratch)?);
                }
                let env_ref = env.as_mut().unwrap();
                let snap = env_ref.snap("fuzz-gen", LoadMode::Eager)?;
                let env_ro: &FuzzEnv = env_ref;
                let eng = env_ro.engine(snap, None)?;
                let gen = GenerateEngine::new(&eng)?;
                let verdict = judge_gen_trace(env_ro, &gen, &mut rng);
                digest.update_u64(100 + verdict.code());
                if let Verdict::RanClean(h) = &verdict {
                    digest.update_u64(*h);
                }
                match &verdict {
                    Verdict::RanClean(_) => cases_ok += 1,
                    Verdict::CleanError => cases_rejected += 1,
                    Verdict::Panic(msg) | Verdict::InvariantViolation(msg) => {
                        findings.push(Finding {
                            iter,
                            summary: format!("generate leg: {msg}"),
                            fixture: None, // repro = target seed (the leg is seed-pure)
                        });
                    }
                }
            }
        }
        Ok(())
    })?;

    Ok(FuzzReport {
        target: "trace".to_string(),
        seed: opts.seed,
        iters: opts.iters,
        digest: digest.finish(),
        cases_ok,
        cases_rejected,
        findings,
    })
}

/// Persist a finding's payload as a fixture (when enabled) and build the
/// [`Finding`] record.
fn finding(iter: u64, summary: String, opts: &FuzzOpts, payload: &[u8], expect: u8) -> Finding {
    let fixture = opts.fixtures.as_ref().and_then(|dir| {
        let p = dir.join(format!("trace_seed{}_iter{iter}.cbqf", opts.seed));
        write_fixture(
            &p,
            &Fixture {
                target: FIXTURE_TARGET_TRACE,
                expect,
                clean_hash: 0,
                payload: payload.to_vec(),
            },
        )
        .ok()
        .map(|()| p)
    });
    Finding { iter, summary, fixture }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::Priority;

    fn mini_trace(seed: u64) -> Vec<Arrival> {
        synth_trace(&TraceSpec {
            seed,
            requests: 12,
            mean_gap_ticks: 200,
            seq: 6,
            vocab: 40,
            priorities: true,
        })
    }

    #[test]
    fn valid_traces_run_clean_and_deterministically() {
        let t = mini_trace(4);
        let a = judge_trace(&t, 6, None);
        let b = judge_trace(&t, 6, None);
        assert_eq!(a, b);
        assert!(matches!(a, Verdict::RanClean(_)), "{a:?}");
        // a bounded queue changes decisions but not cleanliness
        let c = judge_trace(&t, 6, Some(4));
        assert!(matches!(c, Verdict::RanClean(_)), "{c:?}");
    }

    #[test]
    fn contract_violations_are_rejected_not_panics() {
        // unsorted
        let mut t = mini_trace(5);
        t[0].at = u64::MAX / 8;
        let v = with_quiet_panics(|| judge_trace(&t, 6, None));
        assert_eq!(v, Verdict::CleanError, "unsorted must be rejected: {v:?}");
        // zero rows
        let mut t = mini_trace(6);
        t[2].request.rows.clear();
        let v = with_quiet_panics(|| judge_trace(&t, 6, None));
        assert_eq!(v, Verdict::CleanError, "zero-rows must be rejected: {v:?}");
    }

    #[test]
    fn degenerate_rows_run_without_panicking() {
        // zero-length token rows (the WorkRow::from_tokens hardening) and
        // shape-skewed rows must never panic the scheduler loop
        let mut t = mini_trace(7);
        t[1].request.rows = vec![WorkRow { inputs: vec![], targets: vec![], mask: vec![] }];
        if let Some(r) = t[3].request.rows.first_mut() {
            r.inputs.pop();
        }
        let v = with_quiet_panics(|| judge_trace(&t, 6, None));
        assert!(
            matches!(v, Verdict::RanClean(_) | Verdict::CleanError),
            "degenerate rows must be handled cleanly: {v:?}"
        );
    }

    #[test]
    fn mutations_replay_bitwise_from_the_seed() {
        // the full `run` loop (including the generate leg's model build) is
        // exercised by the integration suite and CI's fuzz-smoke job; the
        // unit test pins the property everything rests on — the mutation
        // schedule and resulting trace bytes are pure functions of the seed
        let mut r1 = FuzzRng::new(11);
        let mut r2 = FuzzRng::new(11);
        let mut t1 = mini_trace(8);
        let mut t2 = mini_trace(8);
        let d1: Vec<String> = (0..16).map(|_| mutate_trace(&mut t1, &mut r1)).collect();
        let d2: Vec<String> = (0..16).map(|_| mutate_trace(&mut t2, &mut r2)).collect();
        assert_eq!(d1, d2, "trace mutations must replay from the seed");
        assert_eq!(corpus::encode_trace(&t1), corpus::encode_trace(&t2));
        let mut r3 = FuzzRng::new(12);
        let mut t3 = mini_trace(8);
        let d3: Vec<String> = (0..16).map(|_| mutate_trace(&mut t3, &mut r3)).collect();
        assert_ne!(d1, d3, "different seeds must explore different schedules");
    }

    #[test]
    fn replay_bytes_enforces_expectations() {
        let t = mini_trace(9);
        let good = corpus::encode_trace(&t);
        replay_bytes(&good, FIXTURE_EXPECT_ACCEPT).unwrap();
        assert!(replay_bytes(&good, FIXTURE_EXPECT_REJECT).is_err());
        // an unsorted trace encodes fine but must be rejected by the
        // scheduler — the canonical reject fixture
        let mut bad = t.clone();
        bad[0].at = u64::MAX / 8;
        bad[0].class = Priority::Interactive;
        let payload = corpus::encode_trace(&bad);
        replay_bytes(&payload, FIXTURE_EXPECT_REJECT).unwrap();
        assert!(replay_bytes(&payload, FIXTURE_EXPECT_ACCEPT).is_err());
        // truncated frames are decoder-rejected
        replay_bytes(&good[..good.len() / 2], FIXTURE_EXPECT_REJECT).unwrap();
    }
}
