//! Structure-aware mutations over CBQS container bytes.
//!
//! The fuzzer does not throw random bytes at the parser — it starts from a
//! *valid* container emitted by the real `snapshot::format` writers and
//! applies mutations that are aware of the v1/v2 framing: truncations,
//! trailing garbage, bit flips, version/magic corruption, and — the
//! interesting family — **checksum-consistent field corruption**: a record's
//! offset, length, dims, bits or name length is overwritten (including
//! `u64`-overflow values that make `offset + len` wrap) and the covering
//! CRC is then *recomputed*, so the corruption survives the checksum gate
//! and the parser's own bounds checks are what must catch it.
//!
//! Every mutation reports whether it fixed up the covering CRC
//! ([`Mutation::crc_fixed`]): a CRC-consistent mutation produces a file the
//! format genuinely cannot distinguish from an intentionally different one,
//! so the oracle only demands "no panic, no over-read" there — whereas a
//! CRC-breaking mutation that still loads with altered content is a
//! **silent-corruption** finding.

use super::rng::FuzzRng;

/// Byte span of the v1 frame prefix: magic + version + payload_len.
const V1_HEADER: usize = 12;
/// Byte span of the v2 frame prefix: magic + version + meta_len (u64).
const V2_PREFIX: usize = 16;

/// One applied mutation: a human-readable description (for findings and
/// fixture names) plus whether the covering checksum was recomputed.
#[derive(Clone, Debug)]
pub struct Mutation {
    /// What was done, e.g. `"v2 record 3 offset := 0xffffffffffffffc0"`.
    pub desc: String,
    /// Did the mutation fix up the covering CRC so the corruption passes
    /// the checksum gate? (Changes the oracle: see module docs.)
    pub crc_fixed: bool,
}

/// Container version sniffed from the 8-byte prefix (`None` when the file
/// is too short or not CBQS-framed).
pub fn sniff_version(bytes: &[u8]) -> Option<u32> {
    if bytes.len() < 8 || &bytes[..4] != b"CBQS" {
        return None;
    }
    Some(u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]))
}

// ---------------------------------------------------------------------------
// CRC fix-up helpers
// ---------------------------------------------------------------------------

/// Recompute the v2 metadata CRC (covers bytes `0..16+meta_len`) after a
/// meta-region mutation. No-op when the frame is too short to hold it.
pub fn fix_meta_crc_v2(bytes: &mut [u8]) {
    if bytes.len() < V2_PREFIX + 4 {
        return;
    }
    let meta_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let Some(crc_at) = V2_PREFIX.checked_add(meta_len) else { return };
    if crc_at + 4 > bytes.len() {
        return;
    }
    let crc = crate::snapshot::format::crc32(&bytes[..crc_at]);
    bytes[crc_at..crc_at + 4].copy_from_slice(&crc.to_le_bytes());
}

/// Recompute the v1 trailing CRC (covers the whole payload) after a
/// payload mutation. No-op when the frame is not exactly
/// `12 + payload_len + 4` bytes.
pub fn fix_payload_crc_v1(bytes: &mut [u8]) {
    if bytes.len() < V1_HEADER + 4 {
        return;
    }
    let plen = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    if V1_HEADER + plen + 4 != bytes.len() {
        return;
    }
    let crc = crate::snapshot::format::crc32(&bytes[V1_HEADER..V1_HEADER + plen]);
    let at = V1_HEADER + plen;
    bytes[at..at + 4].copy_from_slice(&crc.to_le_bytes());
}

// ---------------------------------------------------------------------------
// v2 meta layout (absolute field offsets, parsed defensively)
// ---------------------------------------------------------------------------

/// Absolute byte offsets of one v2 record's mutable fields.
#[derive(Clone, Debug)]
pub struct RecordFields {
    /// Offset of the `name_len` u32.
    pub name_len_at: usize,
    /// Offset of the `dtype` byte.
    pub dtype_at: usize,
    /// Offset of the `bits` byte.
    pub bits_at: usize,
    /// Offset of the `ndim` byte.
    pub ndim_at: usize,
    /// Offsets of each `dims[i]` u32.
    pub dims_at: Vec<usize>,
    /// Offset of the `group` i32.
    pub group_at: usize,
    /// Offset of the payload `offset` u64.
    pub offset_at: usize,
    /// Offset of the payload `len` u64.
    pub len_at: usize,
    /// Offset of the payload `crc` u32.
    pub crc_at: usize,
}

/// Field map of a v2 meta block. Parsed with the same framing rules as the
/// reader but *defensively* — any inconsistency yields `None` and the
/// caller falls back to blind byte mutations.
pub fn parse_v2_layout(bytes: &[u8]) -> Option<Vec<RecordFields>> {
    if sniff_version(bytes) != Some(2) || bytes.len() < V2_PREFIX {
        return None;
    }
    let meta_len = u64::from_le_bytes(bytes[8..16].try_into().ok()?) as usize;
    let meta_end = V2_PREFIX.checked_add(meta_len)?;
    if meta_end + 4 > bytes.len() {
        return None;
    }
    let mut pos = V2_PREFIX;
    let rd_u32 = |p: usize| -> Option<u32> {
        bytes.get(p..p + 4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
    };
    let header_len = rd_u32(pos)? as usize;
    pos = pos.checked_add(4)?.checked_add(header_len)?;
    let n_records = rd_u32(pos)? as usize;
    pos += 4;
    if n_records > (1 << 20) {
        return None;
    }
    let mut out = Vec::with_capacity(n_records);
    for _ in 0..n_records {
        let name_len_at = pos;
        let name_len = rd_u32(pos)? as usize;
        pos = pos.checked_add(4)?.checked_add(name_len)?;
        let dtype_at = pos;
        let bits_at = pos + 1;
        let ndim_at = pos + 2;
        let ndim = *bytes.get(ndim_at)? as usize;
        pos += 3;
        let dims_at: Vec<usize> = (0..ndim).map(|i| pos + 4 * i).collect();
        pos = pos.checked_add(4 * ndim)?;
        let group_at = pos;
        let offset_at = pos + 4;
        let len_at = pos + 12;
        let crc_at = pos + 20;
        pos = pos.checked_add(24)?;
        if pos > meta_end {
            return None;
        }
        out.push(RecordFields {
            name_len_at,
            dtype_at,
            bits_at,
            ndim_at,
            dims_at,
            group_at,
            offset_at,
            len_at,
            crc_at,
        });
    }
    Some(out)
}

// ---------------------------------------------------------------------------
// mutation engine
// ---------------------------------------------------------------------------

fn write_u32(bytes: &mut [u8], at: usize, v: u32) {
    bytes[at..at + 4].copy_from_slice(&v.to_le_bytes());
}

fn write_u64(bytes: &mut [u8], at: usize, v: u64) {
    bytes[at..at + 8].copy_from_slice(&v.to_le_bytes());
}

/// Apply one structure-aware mutation to `bytes` in place and describe it.
/// The choice of mutation, its position and its value all come from `rng`,
/// so a seed replays the identical mutation schedule.
pub fn mutate_container(bytes: &mut Vec<u8>, rng: &mut FuzzRng) -> Mutation {
    let version = sniff_version(bytes);
    // targeted v2 field corruption gets the biggest share of the budget —
    // it is the family only a structure-aware fuzzer can produce
    let strategy = if version == Some(2) { rng.below(10) } else { rng.below(7) };
    match strategy {
        0 => {
            // truncation: anywhere, with a bias toward the framing edges
            let cut = if rng.chance(1, 3) {
                rng.range(0, 20.min(bytes.len()))
            } else {
                rng.range(0, bytes.len().saturating_sub(1))
            };
            bytes.truncate(cut);
            Mutation { desc: format!("truncate to {cut} bytes"), crc_fixed: false }
        }
        1 => {
            let extra = rng.range(1, 64);
            for _ in 0..extra {
                let b = rng.byte();
                bytes.push(b);
            }
            Mutation { desc: format!("append {extra} trailing bytes"), crc_fixed: false }
        }
        2 => {
            if bytes.is_empty() {
                return Mutation { desc: "flip on empty file (noop)".into(), crc_fixed: false };
            }
            let at = rng.index(bytes.len());
            let mask = rng.flip_mask();
            bytes[at] ^= mask;
            Mutation { desc: format!("flip bit {mask:#04x} at {at}"), crc_fixed: false }
        }
        3 => {
            if bytes.is_empty() {
                return Mutation { desc: "zero on empty file (noop)".into(), crc_fixed: false };
            }
            let at = rng.index(bytes.len());
            let n = rng.range(1, 16).min(bytes.len() - at);
            bytes[at..at + n].fill(0);
            Mutation { desc: format!("zero {n} bytes at {at}"), crc_fixed: false }
        }
        4 => {
            if bytes.len() >= 8 {
                let v = [0u32, 3, 0xEE, u32::MAX][rng.index(4)];
                write_u32(bytes, 4, v);
                Mutation { desc: format!("version := {v}"), crc_fixed: false }
            } else {
                Mutation { desc: "version on short file (noop)".into(), crc_fixed: false }
            }
        }
        5 => {
            if bytes.len() >= 4 {
                let at = rng.index(4);
                bytes[at] = bytes[at].wrapping_add(1 + rng.byte() % 254);
                Mutation { desc: format!("magic byte {at} corrupted"), crc_fixed: false }
            } else {
                Mutation { desc: "magic on short file (noop)".into(), crc_fixed: false }
            }
        }
        6 => {
            // framing-length corruption: v2 meta_len / v1 payload_len
            if version == Some(2) && bytes.len() >= V2_PREFIX {
                let v = [0u64, 1, u64::MAX, u64::MAX - 63, bytes.len() as u64 * 2]
                    [rng.index(5)];
                write_u64(bytes, 8, v);
                Mutation { desc: format!("meta_len := {v:#x}"), crc_fixed: false }
            } else if bytes.len() >= V1_HEADER {
                let v = [0u32, 1, u32::MAX, bytes.len() as u32 * 2][rng.index(4)];
                write_u32(bytes, 8, v);
                Mutation { desc: format!("payload_len := {v:#x}"), crc_fixed: false }
            } else {
                Mutation { desc: "framing on short file (noop)".into(), crc_fixed: false }
            }
        }
        // v2-only targeted families below (strategy 7..=9)
        _ => {
            let Some(records) = parse_v2_layout(bytes) else {
                // layout no longer parses (previous mutation broke it):
                // degrade to a raw flip
                if bytes.is_empty() {
                    return Mutation {
                        desc: "layout flip on empty file (noop)".into(),
                        crc_fixed: false,
                    };
                }
                let at = rng.index(bytes.len());
                bytes[at] ^= rng.flip_mask();
                return Mutation { desc: format!("raw flip at {at}"), crc_fixed: false };
            };
            if records.is_empty() {
                // zero-record container: splash the header JSON instead
                let at = rng.range(V2_PREFIX, (bytes.len() - 5).max(V2_PREFIX));
                bytes[at] ^= rng.flip_mask();
                fix_meta_crc_v2(bytes);
                return Mutation {
                    desc: format!("meta splash at {at} (crc fixed)"),
                    crc_fixed: true,
                };
            }
            let r = &records[rng.index(records.len())];
            let (at, field) = match rng.below(8) {
                0 => {
                    // unaligned / out-of-file / overlapping payload offset
                    let v = [
                        1u64,
                        bytes.len() as u64,                   // exactly at EOF
                        bytes.len() as u64 * 4,               // past EOF
                        u64::MAX - 7,                         // offset+len wraps
                        (bytes.len() as u64 / 2) | 1,         // unaligned mid-file
                    ][rng.index(5)];
                    write_u64(bytes, r.offset_at, v);
                    (r.offset_at, format!("offset := {v:#x}"))
                }
                1 => {
                    let v = [u64::MAX, u64::MAX / 2, bytes.len() as u64 * 8, 0][rng.index(4)];
                    write_u64(bytes, r.len_at, v);
                    (r.len_at, format!("len := {v:#x}"))
                }
                2 => {
                    let v = [0u8, 9, 64, 255][rng.index(4)];
                    bytes[r.bits_at] = v;
                    (r.bits_at, format!("bits := {v}"))
                }
                3 => {
                    let v = [0u8, 9, 200, 255][rng.index(4)];
                    bytes[r.ndim_at] = v;
                    (r.ndim_at, format!("ndim := {v}"))
                }
                4 if !r.dims_at.is_empty() => {
                    let d = r.dims_at[rng.index(r.dims_at.len())];
                    let v = [0u32, u32::MAX, 0x8000_0000][rng.index(3)];
                    write_u32(bytes, d, v);
                    (d, format!("dim := {v:#x}"))
                }
                5 => {
                    let v = [u32::MAX, 1 << 21, 0x7FFF_FFFF][rng.index(3)];
                    write_u32(bytes, r.group_at, v);
                    (r.group_at, format!("group := {v:#x}"))
                }
                6 => {
                    let v = [u32::MAX, 1 << 16, 4097][rng.index(3)];
                    write_u32(bytes, r.name_len_at, v);
                    (r.name_len_at, format!("name_len := {v}"))
                }
                _ => {
                    let v = [3u8, 255][rng.index(2)];
                    bytes[r.dtype_at] = v;
                    (r.dtype_at, format!("dtype := {v}"))
                }
            };
            let crc_fixed = rng.chance(3, 4); // mostly fix the CRC (the hard case)
            if crc_fixed {
                fix_meta_crc_v2(bytes);
            }
            Mutation {
                desc: format!(
                    "v2 field at {at}: {field}{}",
                    if crc_fixed { " (crc fixed)" } else { "" }
                ),
                crc_fixed,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Value;
    use crate::snapshot::format::{self, crc32};
    use crate::tensor::io::Entry;
    use crate::tensor::Tensor;

    fn v2_bytes(name: &str) -> Vec<u8> {
        let p = std::env::temp_dir().join(format!("cbq_mut_{}_{name}", std::process::id()));
        let entries = vec![
            ("a".to_string(), Entry::F32(Tensor::new(vec![2, 3], vec![1.0; 6])), -1),
            ("b.q".to_string(), Entry::F32(Tensor::new(vec![4], vec![0.5; 4])), 0),
        ];
        format::write_container(&p, &Value::obj(vec![("format", Value::str("CBQS"))]), &entries)
            .unwrap();
        let raw = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        raw
    }

    #[test]
    fn layout_parse_finds_every_record() {
        let raw = v2_bytes("layout");
        let recs = parse_v2_layout(&raw).expect("layout should parse");
        assert_eq!(recs.len(), 2);
        // the offset field of record 0 holds a 64-aligned in-file offset
        let off = u64::from_le_bytes(raw[recs[0].offset_at..recs[0].offset_at + 8].try_into().unwrap());
        assert_eq!(off % 64, 0);
        assert!(off < raw.len() as u64);
        // the bits byte of an f32 record holds its storage width
        assert_eq!(raw[recs[0].bits_at], 32);
    }

    #[test]
    fn meta_crc_fixup_restores_validity() {
        let mut raw = v2_bytes("crcfix");
        let meta_len = u64::from_le_bytes(raw[8..16].try_into().unwrap()) as usize;
        let crc_at = 16 + meta_len;
        // break a meta byte, then fix: stored CRC must equal a fresh CRC
        raw[18] ^= 0x10;
        fix_meta_crc_v2(&mut raw);
        let stored = u32::from_le_bytes(raw[crc_at..crc_at + 4].try_into().unwrap());
        assert_eq!(stored, crc32(&raw[..crc_at]));
    }

    #[test]
    fn mutations_are_seed_deterministic() {
        let base = v2_bytes("det");
        let run = |seed: u64| {
            let mut b = base.clone();
            let mut rng = FuzzRng::new(seed);
            let descs: Vec<String> =
                (0..32).map(|_| mutate_container(&mut b, &mut rng).desc).collect();
            (b, descs)
        };
        assert_eq!(run(42), run(42), "same seed must replay the same mutations");
        assert_ne!(run(42).0, run(43).0, "different seeds should diverge");
    }
}
