//! Shared fuzz environment: one tiny synthetic model, quantized and
//! exported once per run, serving as the substrate for every engine-level
//! fuzz leg (serve differentials, generate-trace ingestion).
//!
//! Built exactly like the integration tests build theirs (`cbq synth` →
//! RTN quantize → `snapshot::save`), so the fuzzer attacks the same stack
//! the tests certify — just with adversarial inputs. Construction is
//! deterministic: the synthetic spec is fixed, so every run fuzzes the
//! identical model.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{BitSpec, QuantJob};
use crate::coordinator::Pipeline;
use crate::runtime::{synth, Artifacts, ModelCfg, NativeBackend};
use crate::serve::{EngineOptions, LoadMode, LoadedSnapshot, ModelRegistry, ServeEngine};

/// The lazily-built engine substrate. Hold one per fuzz run; engines are
/// constructed per case from snapshots the registry shares.
pub struct FuzzEnv {
    /// Synthetic artifacts (manifest + pretrained weights + corpus).
    pub art: Artifacts,
    /// Native CPU backend bound to the artifacts.
    pub rt: NativeBackend,
    /// The exported quantized snapshot every engine loads.
    pub snap_path: PathBuf,
    /// Model config of the exported snapshot (seq/vocab bounds for trace
    /// generation).
    pub cfg: ModelCfg,
    registry: ModelRegistry,
}

impl FuzzEnv {
    /// Synthesize, quantize (fast RTN path) and export the fuzz model
    /// under `scratch`. ~seconds; done once per run, only for targets
    /// that need engines.
    pub fn build(scratch: &Path) -> Result<FuzzEnv> {
        let dir = scratch.join("fuzz_artifacts");
        let mut spec = synth::SynthSpec::tiny();
        // 4 layers => a 2-window serve plan, so the lazy engine's eviction
        // path is actually on the fuzzed surface
        spec.n_layers = 4;
        spec.pretrain_steps = 40;
        synth::generate(&dir, &spec).context("synthesizing fuzz artifacts")?;
        let art = Artifacts::load(&dir).context("loading fuzz artifacts")?;
        let rt = NativeBackend::new(&art).context("native backend for fuzzing")?;
        let snap_path = scratch.join("fuzz_model.cbqs");
        let model = art.default_model().to_string();
        let (cfg, qm) = {
            let mut pipe = Pipeline::new(&art, &rt, &model)?;
            let mut job = QuantJob::rtn(BitSpec::new(4, 16));
            job.calib_sequences = 4;
            let (qm, _) = pipe.run(&job)?;
            (pipe.cfg.clone(), qm)
        };
        crate::snapshot::save(&snap_path, &cfg, &qm).context("exporting fuzz snapshot")?;
        Ok(FuzzEnv { art, rt, snap_path, cfg, registry: ModelRegistry::new() })
    }

    /// Load (or re-share) the fuzz snapshot under `name` in `mode`. The
    /// mutable borrow ends at return, so several snapshots can feed
    /// engines that live side by side.
    pub fn snap(&mut self, name: &str, mode: LoadMode) -> Result<Arc<LoadedSnapshot>> {
        self.registry.load_with(name, &self.snap_path, mode)
    }

    /// Build an engine over a snapshot from [`FuzzEnv::snap`]. `opts:
    /// None` uses eager-style defaults with packing off — explicit, never
    /// environment-dependent, so fuzz runs replay regardless of
    /// `CBQ_PACKED`/`CBQ_RESIDENT_MB` in the caller's shell.
    pub fn engine(
        &self,
        snap: Arc<LoadedSnapshot>,
        opts: Option<EngineOptions>,
    ) -> Result<ServeEngine<'_>> {
        let opts = opts.unwrap_or(EngineOptions {
            resident_windows: None,
            resident_bytes: None,
            packed: false,
        });
        ServeEngine::with_options(&self.rt, &self.art, snap, opts)
    }
}
