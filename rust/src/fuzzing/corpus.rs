//! Grammar-aware corpus generation: valid CBQS containers and encodable
//! scheduler traces, both pure functions of a [`FuzzRng`] stream.
//!
//! Containers are emitted through the *real* `snapshot::format` writers
//! (never a reimplementation), so every corpus file is valid by
//! construction and the mutation engine starts from the exact byte layout
//! production snapshots have. Traces are serialized through the small
//! `CBQT` codec defined here so the byte-mutation machinery can attack
//! trace ingestion the same way it attacks the container parser.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use super::rng::FuzzRng;
use crate::json::Value;
use crate::serve::scheduler::{Arrival, Priority};
use crate::serve::{Request, RequestKind, WorkRow};
use crate::snapshot::format;
use crate::tensor::io::{Entry, PackedTensor, MAX_NAME_LEN};
use crate::tensor::Tensor;

// ---------------------------------------------------------------------------
// content hashing (FNV-1a 64)
// ---------------------------------------------------------------------------

/// Incremental FNV-1a 64-bit hash — the fuzzer's stable content digest.
/// Chosen over `DefaultHasher` because its output is pinned across Rust
/// versions and platforms, which fixture files require.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    /// Standard FNV-1a offset basis.
    pub fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }

    /// Fold raw bytes into the hash.
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    /// Fold a u64 (little-endian) into the hash.
    pub fn update_u64(&mut self, v: u64) {
        self.update(&v.to_le_bytes());
    }

    /// Final hash value.
    pub fn finish(self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// Canonical content hash of a loaded entry map: folds every name, dtype,
/// shape and payload (f32 bit patterns / packed code bytes) in `BTreeMap`
/// order. Two loads of the same logical model hash equal iff they are
/// bit-exact.
pub fn entries_hash(entries: &BTreeMap<String, Entry>) -> u64 {
    let mut h = Fnv64::new();
    h.update_u64(entries.len() as u64);
    for (name, e) in entries {
        h.update_u64(name.len() as u64);
        h.update(name.as_bytes());
        match e {
            Entry::F32(t) => {
                h.update(&[0u8]);
                h.update_u64(t.dims.len() as u64);
                for &d in &t.dims {
                    h.update_u64(d as u64);
                }
                for &v in t.data.iter() {
                    h.update(&v.to_bits().to_le_bytes());
                }
            }
            Entry::Packed(p) => {
                h.update(&[2u8, p.bits]);
                h.update_u64(p.dims.len() as u64);
                for &d in &p.dims {
                    h.update_u64(d as u64);
                }
                h.update(&p.data);
            }
        }
    }
    h.finish()
}

// ---------------------------------------------------------------------------
// container corpus
// ---------------------------------------------------------------------------

/// One generated corpus container: the on-disk bytes (already framed by
/// the real writer), the frame version used, and the content hash of the
/// entries it must load back to.
#[derive(Clone, Debug)]
pub struct ContainerCase {
    /// Raw container file bytes.
    pub bytes: Vec<u8>,
    /// 1 or 2 — which writer produced it.
    pub version: u32,
    /// [`entries_hash`] of the written entries (the bit-exact oracle).
    pub clean_hash: u64,
}

fn gen_name(rng: &mut FuzzRng, i: usize) -> String {
    match rng.below(6) {
        0 => format!("blocks.{}.wq.q", rng.below(32)),
        1 => format!("blocks.{}.w1.scale", rng.below(32)),
        2 => format!("t{i}"),
        3 => "x".repeat(rng.range(1, 64)),
        // edge: maximal and near-maximal header names
        4 => "n".repeat(MAX_NAME_LEN),
        _ => format!("lora.{}.{}", rng.below(8), rng.below(4)),
    }
}

fn gen_entry(rng: &mut FuzzRng) -> Entry {
    // shapes: scalar (empty dims), vectors, small matrices
    let dims: Vec<usize> = match rng.below(5) {
        0 => vec![],
        1 => vec![rng.range(1, 17)],
        _ => vec![rng.range(1, 9), rng.range(1, 9)],
    };
    let count: usize = dims.iter().product();
    if rng.chance(1, 2) {
        let data: Vec<f32> = (0..count).map(|_| rng.f32_in(-4.0, 4.0)).collect();
        Entry::F32(Tensor::new(dims, data))
    } else {
        let bits = rng.range(1, 8) as u8;
        let half = 1i32 << (bits - 1);
        let codes: Vec<i32> =
            (0..count).map(|_| rng.below(2 * half as u64) as i32 - half).collect();
        Entry::Packed(PackedTensor::pack(&codes, dims, bits).expect("codes in range"))
    }
}

/// Generate one valid container (v1 or v2, chosen by the stream) into
/// `scratch` and return its bytes + oracle hash. The file is removed
/// before returning — mutation works on the in-memory bytes.
pub fn gen_container(rng: &mut FuzzRng, scratch: &std::path::Path) -> Result<ContainerCase> {
    let n = rng.range(0, 5);
    let entries: Vec<(String, Entry, i32)> = (0..n)
        .map(|i| {
            let name = gen_name(rng, i);
            let e = gen_entry(rng);
            let group = if rng.chance(1, 3) { rng.below(1 << 10) as i32 } else { -1 };
            (name, e, group)
        })
        .collect();
    let header = Value::obj(vec![
        ("format", Value::str("CBQS")),
        ("fuzz_case", Value::num(rng.below(1 << 20) as f64)),
    ]);
    let version = if rng.chance(1, 3) { 1 } else { 2 };
    if version == 1 {
        let v1: Vec<(String, Entry)> =
            entries.iter().map(|(n, e, _)| (n.clone(), e.clone())).collect();
        format::write_container_v1(scratch, &header, &v1)?;
    } else {
        format::write_container(scratch, &header, &entries)?;
    }
    let bytes = std::fs::read(scratch)?;
    std::fs::remove_file(scratch).ok();
    let map: BTreeMap<String, Entry> =
        entries.into_iter().map(|(n, e, _)| (n, e)).collect();
    Ok(ContainerCase { bytes, version, clean_hash: entries_hash(&map) })
}

// ---------------------------------------------------------------------------
// CBQT trace codec
// ---------------------------------------------------------------------------

/// Magic of the fuzzer's trace serialization.
pub const TRACE_MAGIC: &[u8; 4] = b"CBQT";
/// Codec version.
pub const TRACE_VERSION: u32 = 1;
/// Hardening cap on decoded element counts (arrivals, rows, tokens) so a
/// mutated length field cannot drive an OOM allocation.
pub const TRACE_MAX_ITEMS: usize = 1 << 20;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Serialize a scheduler trace to `CBQT` bytes. Rows are stored
/// field-for-field (inputs/targets/mask bit patterns), so decode rebuilds
/// the exact [`WorkRow`]s — including degenerate ones a mutation produced.
pub fn encode_trace(trace: &[Arrival]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(TRACE_MAGIC);
    put_u32(&mut out, TRACE_VERSION);
    put_u32(&mut out, trace.len() as u32);
    for a in trace {
        put_u64(&mut out, a.at);
        out.push(a.class.index() as u8);
        let (kind, correct) = match &a.request.kind {
            RequestKind::Ppl => (0u8, 0u32),
            RequestKind::Choice { correct } => (1, *correct as u32),
            RequestKind::Hidden => (2, 0),
        };
        out.push(kind);
        put_u32(&mut out, correct);
        put_u32(&mut out, a.request.rows.len() as u32);
        for r in &a.request.rows {
            put_u32(&mut out, r.inputs.len() as u32);
            for &t in &r.inputs {
                put_u32(&mut out, t as u32);
            }
            put_u32(&mut out, r.targets.len() as u32);
            for &t in &r.targets {
                put_u32(&mut out, t as u32);
            }
            put_u32(&mut out, r.mask.len() as u32);
            for &m in &r.mask {
                put_u32(&mut out, m.to_bits());
            }
        }
    }
    out
}

struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.b.len());
        let Some(end) = end else { bail!("trace truncated at byte {}", self.pos) };
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn bounded(&mut self, what: &str) -> Result<usize> {
        let n = self.u32()? as usize;
        ensure!(n <= TRACE_MAX_ITEMS, "trace {what} count {n} exceeds cap");
        // a count can never promise more elements than bytes remain
        ensure!(n <= self.b.len() - self.pos, "trace {what} count {n} overruns frame");
        Ok(n)
    }
}

/// Decode `CBQT` bytes back to a trace. Every length is bounds-checked
/// against the remaining frame and the [`TRACE_MAX_ITEMS`] cap; class and
/// kind tags out of range are clean errors. This is itself a hardened
/// parser — the trace fuzz target attacks it byte-wise before the
/// scheduler ever sees the result.
pub fn decode_trace(bytes: &[u8]) -> Result<Vec<Arrival>> {
    let mut rd = Rd { b: bytes, pos: 0 };
    ensure!(rd.take(4)? == TRACE_MAGIC, "bad trace magic");
    let ver = rd.u32()?;
    ensure!(ver == TRACE_VERSION, "unsupported trace version {ver}");
    let n = rd.bounded("arrival")?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let at = rd.u64()?;
        let class = match rd.u8()? {
            0 => Priority::Interactive,
            1 => Priority::Batch,
            2 => Priority::Background,
            c => bail!("trace class tag {c} out of range"),
        };
        let kind_tag = rd.u8()?;
        let correct = rd.u32()? as usize;
        let kind = match kind_tag {
            0 => RequestKind::Ppl,
            1 => RequestKind::Choice { correct },
            2 => RequestKind::Hidden,
            k => bail!("trace request kind tag {k} out of range"),
        };
        let n_rows = rd.bounded("row")?;
        let mut rows = Vec::with_capacity(n_rows);
        for _ in 0..n_rows {
            let n_in = rd.bounded("input token")?;
            let inputs: Vec<i32> =
                (0..n_in).map(|_| rd.u32().map(|v| v as i32)).collect::<Result<_>>()?;
            let n_tg = rd.bounded("target token")?;
            let targets: Vec<i32> =
                (0..n_tg).map(|_| rd.u32().map(|v| v as i32)).collect::<Result<_>>()?;
            let n_mk = rd.bounded("mask")?;
            let mask: Vec<f32> =
                (0..n_mk).map(|_| rd.u32().map(f32::from_bits)).collect::<Result<_>>()?;
            rows.push(WorkRow { inputs, targets, mask });
        }
        out.push(Arrival { at, class, request: Request { kind, rows } });
    }
    ensure!(rd.pos == bytes.len(), "trailing garbage after trace ({} bytes)", bytes.len() - rd.pos);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scheduler::{synth_trace, TraceSpec};

    fn spec(seed: u64) -> TraceSpec {
        TraceSpec { seed, requests: 24, mean_gap_ticks: 300, seq: 6, vocab: 40, priorities: true }
    }

    #[test]
    fn trace_codec_round_trips_bit_exactly() {
        let trace = synth_trace(&spec(9));
        let bytes = encode_trace(&trace);
        let back = decode_trace(&bytes).unwrap();
        assert_eq!(back.len(), trace.len());
        for (a, b) in trace.iter().zip(&back) {
            assert_eq!(a.at, b.at);
            assert_eq!(a.class, b.class);
            assert_eq!(a.request.rows.len(), b.request.rows.len());
            for (ra, rb) in a.request.rows.iter().zip(&b.request.rows) {
                assert_eq!(ra.inputs, rb.inputs);
                assert_eq!(ra.targets, rb.targets);
                assert_eq!(
                    ra.mask.iter().map(|m| m.to_bits()).collect::<Vec<_>>(),
                    rb.mask.iter().map(|m| m.to_bits()).collect::<Vec<_>>()
                );
            }
        }
    }

    #[test]
    fn trace_decode_rejects_malformed_frames() {
        let bytes = encode_trace(&synth_trace(&spec(3)));
        assert!(decode_trace(&bytes[..bytes.len() - 1]).is_err(), "truncation");
        let mut garbage = bytes.clone();
        garbage.push(0);
        assert!(decode_trace(&garbage).is_err(), "trailing garbage");
        let mut magic = bytes.clone();
        magic[0] ^= 0xFF;
        assert!(decode_trace(&magic).is_err(), "magic");
        // class tag out of range: first arrival's class byte sits right
        // after magic(4) + version(4) + n(4) + at(8)
        let mut cls = bytes.clone();
        cls[20] = 9;
        assert!(decode_trace(&cls).is_err(), "class tag");
        // huge arrival count must be a clean error, not an OOM
        let mut huge = bytes.clone();
        huge[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_trace(&huge).is_err(), "count cap");
    }

    #[test]
    fn corpus_containers_load_back_to_their_hash() {
        let mut rng = FuzzRng::new(77);
        let scratch = std::env::temp_dir().join(format!("cbq_corpus_{}", std::process::id()));
        for i in 0..12 {
            let case = gen_container(&mut rng, &scratch).unwrap();
            let p = scratch.with_extension(format!("case{i}"));
            std::fs::write(&p, &case.bytes).unwrap();
            let (_, entries) = format::read_container(&p).unwrap();
            assert_eq!(
                entries_hash(&entries),
                case.clean_hash,
                "case {i} (v{}) must load bit-exactly",
                case.version
            );
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn entries_hash_separates_content_and_shape() {
        let t = |dims: Vec<usize>, data: Vec<f32>| Entry::F32(Tensor::new(dims, data));
        let mk = |e: Entry| {
            let mut m = BTreeMap::new();
            m.insert("a".to_string(), e);
            entries_hash(&m)
        };
        let base = mk(t(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        assert_ne!(base, mk(t(vec![4], vec![1.0, 2.0, 3.0, 4.0])), "shape");
        assert_ne!(base, mk(t(vec![2, 2], vec![1.0, 2.0, 3.0, 4.5])), "content");
        assert_eq!(base, mk(t(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0])), "stable");
    }
}
