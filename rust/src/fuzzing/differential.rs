//! Fuzz target: differential oracles — randomized configurations pushed
//! through independently-implemented paths that must agree **bitwise**.
//!
//! Two legs:
//!
//! * **kernels** (every iteration) — random `m×k×n` shapes straddling the
//!   blocked-path threshold, every packed bit-width, adversarial scale
//!   columns (exact zero, negative, below the `EPS` floor, huge) and
//!   planted zeros in the activation matrix; `qmatmul`/`qmatvec` under
//!   every forced SIMD tier (scalar, SSE2, AVX2 — tiers clamp to what the
//!   host supports) must equal the f32 dequant-then-matmul oracle bit for
//!   bit, and `qmatvec` must equal the matching single-row `qmatmul`;
//! * **serving** (every ~8th iteration) — the same randomized request mix
//!   served by the eager-load engine, the lazy (`mmap`, one resident
//!   window, eviction active) engine, and the packed-domain engine; all
//!   three response vectors must compare equal.
//!
//! Any disagreement or panic is a finding. The digest folds the oracle
//! outputs' bit patterns, so CI's double-invocation check also certifies
//! that the *numerics* replay across runs, not just the verdicts.

use anyhow::Result;

use super::corpus::Fnv64;
use super::env::FuzzEnv;
use super::rng::FuzzRng;
use super::{catch, with_quiet_panics, Finding, FuzzOpts, FuzzReport};
use crate::quant;
use crate::runtime::backend::kernels as k;
use crate::runtime::backend::kernels::SimdTier;
use crate::serve::{batcher, Batcher, EngineOptions, LoadMode, Response};

/// The three forced tiers every kernel case runs under. `*_with_tier`
/// clamps to the host's best tier, so requesting AVX2 on a plain-SSE2 host
/// degrades safely instead of faulting.
const TIERS: [SimdTier; 3] = [SimdTier::Scalar, SimdTier::Sse2, SimdTier::Avx2];

/// One randomized kernel case: returns `Ok(hash-of-outputs)` or a
/// human-readable disagreement description.
fn kernel_case(rng: &mut FuzzRng) -> std::result::Result<u64, String> {
    let (m, kk, n) = (rng.range(1, 12), rng.range(1, 64), rng.range(1, 48));
    let bits = [2u8, 3, 4, 5, 6, 7, 8][rng.index(7)];
    let half = 1i32 << (bits - 1);
    let codes: Vec<i32> =
        (0..kk * n).map(|_| rng.below(2 * half as u64) as i32 - half).collect();
    // scale columns: mostly ordinary positive, with planted edge cases
    // (exact zero and negatives hit the EPS floor; tiny and huge stress
    // the multiply) — the same corpus the proptests certify
    let s_w: Vec<f32> = (0..n)
        .map(|_| match rng.below(6) {
            0 => 0.0,
            1 => -0.25,
            2 => quant::EPS / 4.0,
            3 => 2.9e4,
            _ => rng.f32_in(1e-3, 2.0),
        })
        .collect();
    let a: Vec<f32> = (0..m * kk)
        .map(|_| if rng.chance(1, 5) { 0.0 } else { rng.f32_in(-2.0, 2.0) })
        .collect();

    let q = k::QPanels::pack(&codes, kk, n, bits, &s_w);
    let deq: Vec<f32> =
        (0..kk * n).map(|i| codes[i] as f32 * s_w[i % n].max(quant::EPS)).collect();
    if q.dequant() != deq {
        return Err(format!("dequant mismatch ({kk}x{n} bits {bits})"));
    }
    let oracle = k::matmul(&a, m, kk, &deq, n);
    for tier in TIERS {
        if k::qmatmul_with_tier(&a, m, kk, &q, tier) != oracle {
            return Err(format!(
                "qmatmul {m}x{kk}x{n} bits {bits} tier {} diverges from dequant oracle",
                tier.name()
            ));
        }
    }
    // matvec leg: first row of A against the same panels
    let row = &a[..kk];
    let row_oracle = k::matmul(row, 1, kk, &deq, n);
    for tier in TIERS {
        let v = k::qmatvec_with_tier(row, kk, &q, tier);
        if v != row_oracle {
            return Err(format!(
                "qmatvec {kk}x{n} bits {bits} tier {} diverges from dequant oracle",
                tier.name()
            ));
        }
        if v != k::qmatmul_with_tier(row, 1, kk, &q, tier) {
            return Err(format!(
                "qmatvec vs 1-row qmatmul {kk}x{n} bits {bits} tier {} diverge",
                tier.name()
            ));
        }
    }
    let mut h = Fnv64::new();
    for &x in &oracle {
        h.update(&x.to_bits().to_le_bytes());
    }
    Ok(h.finish())
}

/// Stable digest of a response vector (folds exact bit patterns).
fn responses_hash(resps: &[Response]) -> u64 {
    let mut h = Fnv64::new();
    for r in resps {
        match r {
            Response::Ppl { nll, count } => {
                h.update_u64(1);
                h.update_u64(nll.to_bits());
                h.update_u64(count.to_bits());
            }
            Response::Choice { pick, correct, scores } => {
                h.update_u64(2);
                h.update_u64(*pick as u64);
                h.update_u64(*correct as u64);
                for s in scores {
                    h.update(&s.to_bits().to_le_bytes());
                }
            }
            Response::Hidden { tokens } => {
                h.update_u64(3);
                h.update_u64(*tokens as u64);
            }
            Response::Rejected => h.update_u64(4),
        }
    }
    h.finish()
}

/// One randomized serve case: the same request mix through the eager, lazy
/// (single resident window, eviction on every hop) and packed engines.
fn serve_case(env: &mut FuzzEnv, rng: &mut FuzzRng) -> std::result::Result<u64, String> {
    let seq = env.cfg.seq;
    let mix =
        batcher::standard_mix(seq, rng.range(1, 4), rng.range(0, 2), rng.range(0, 2));
    let eager_snap = env.snap("diff-eager", LoadMode::Eager).map_err(|e| format!("{e:#}"))?;
    let lazy_snap = env.snap("diff-lazy", LoadMode::Mmap).map_err(|e| format!("{e:#}"))?;
    let packed_snap = env.snap("diff-packed", LoadMode::Mmap).map_err(|e| format!("{e:#}"))?;
    let env_ro: &FuzzEnv = env;
    let legs: [(&str, Option<EngineOptions>, _); 3] = [
        ("eager", None, eager_snap),
        (
            "lazy",
            Some(EngineOptions { resident_windows: Some(1), resident_bytes: None, packed: false }),
            lazy_snap,
        ),
        (
            "packed",
            Some(EngineOptions { resident_windows: None, resident_bytes: None, packed: true }),
            packed_snap,
        ),
    ];
    let mut first: Option<(Vec<Response>, u64)> = None;
    for (name, opts, snap) in legs {
        let eng = env_ro.engine(snap, opts).map_err(|e| format!("{name}: {e:#}"))?;
        let out = catch(|| Batcher::coalescing(&eng).run(&eng, &mix))
            .map_err(|msg| format!("{name} engine panicked: {msg}"))?;
        let (resps, _) = out.map_err(|e| format!("{name} engine errored: {e:#}"))?;
        match &first {
            None => {
                let h = responses_hash(&resps);
                first = Some((resps, h));
            }
            Some((base, _)) => {
                if &resps != base {
                    return Err(format!(
                        "{name} engine responses diverge from eager ({} requests)",
                        mix.len()
                    ));
                }
            }
        }
    }
    Ok(first.map(|(_, h)| h).unwrap_or_default())
}

/// Run the differential fuzz target.
pub fn run(opts: &FuzzOpts) -> Result<FuzzReport> {
    let mut rng = FuzzRng::new(opts.seed);
    let mut digest = Fnv64::new();
    let mut findings: Vec<Finding> = Vec::new();
    let mut cases_ok = 0u64;
    let cases_rejected = 0u64; // differentials never "cleanly reject"
    let mut env: Option<FuzzEnv> = None;

    with_quiet_panics(|| -> Result<()> {
        for iter in 0..opts.iters {
            match catch(|| kernel_case(&mut rng.clone())) {
                Err(msg) => {
                    digest.update_u64(3);
                    findings.push(Finding {
                        iter,
                        summary: format!("kernel case panicked: {msg}"),
                        fixture: None, // repro = --target differential --seed
                    });
                }
                Ok(Err(msg)) => {
                    digest.update_u64(4);
                    findings.push(Finding { iter, summary: msg, fixture: None });
                }
                Ok(Ok(h)) => {
                    digest.update_u64(1);
                    digest.update_u64(h);
                    cases_ok += 1;
                }
            }
            // the RNG state must advance identically whether or not the
            // case panicked mid-draw, so the case above ran on a clone;
            // re-sync by burning a fixed stride
            for _ in 0..8 {
                rng.next_u64();
            }

            if iter % 8 == 0 {
                if env.is_none() {
                    env = Some(FuzzEnv::build(&opts.scratch)?);
                }
                let env_ref = env.as_mut().unwrap();
                match serve_case(env_ref, &mut rng) {
                    Ok(h) => {
                        digest.update_u64(11);
                        digest.update_u64(h);
                        cases_ok += 1;
                    }
                    Err(msg) => {
                        digest.update_u64(12);
                        findings.push(Finding {
                            iter,
                            summary: format!("serve differential: {msg}"),
                            fixture: None,
                        });
                    }
                }
            }
        }
        Ok(())
    })?;

    Ok(FuzzReport {
        target: "differential".to_string(),
        seed: opts.seed,
        iters: opts.iters,
        digest: digest.finish(),
        cases_ok,
        cases_rejected,
        findings,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_cases_agree_and_replay() {
        let mut r1 = FuzzRng::new(3);
        let mut r2 = FuzzRng::new(3);
        for _ in 0..24 {
            let a = kernel_case(&mut r1).expect("kernel paths must agree bitwise");
            let b = kernel_case(&mut r2).expect("kernel paths must agree bitwise");
            assert_eq!(a, b, "kernel case digest must replay from the seed");
        }
    }

    #[test]
    fn responses_hash_separates_variants() {
        let a = responses_hash(&[Response::Ppl { nll: 1.0, count: 2.0 }]);
        let b = responses_hash(&[Response::Ppl { nll: 1.0, count: 3.0 }]);
        let c = responses_hash(&[Response::Hidden { tokens: 8 }]);
        let d = responses_hash(&[Response::Rejected]);
        assert!(a != b && a != c && a != d && c != d);
        assert_eq!(a, responses_hash(&[Response::Ppl { nll: 1.0, count: 2.0 }]));
    }
}
