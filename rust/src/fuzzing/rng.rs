//! Deterministic randomness for the fuzzer.
//!
//! Wraps the scheduler's [`Lcg`] (the PR 4 trace generator's PRNG) with the
//! small vocabulary of draws a structure-aware fuzzer needs: bounded
//! integers, weighted coin flips, byte fills and index picks. No wall
//! clock, no OS entropy — the whole fuzz run is a pure function of the
//! seed, which is what makes `cbq fuzz --seed S` replay bit-for-bit.

use crate::serve::scheduler::Lcg;

/// Seeded fuzzing RNG: every draw is derived from the [`Lcg`] stream, so
/// equal seeds produce equal mutation schedules on every platform.
#[derive(Clone, Debug)]
pub struct FuzzRng(Lcg);

impl FuzzRng {
    /// Seeded constructor; the seed is premixed by the underlying [`Lcg`].
    pub fn new(seed: u64) -> Self {
        Self(Lcg::new(seed))
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        self.0.next_u64()
    }

    /// Uniform draw in `[0, n)` (`n == 0` returns 0).
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        self.0.below(n)
    }

    /// Uniform `usize` in `[lo, hi]` (inclusive; `hi < lo` returns `lo`).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        if hi <= lo {
            return lo;
        }
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// True with probability `num`/`den`.
    pub fn chance(&mut self, num: u64, den: u64) -> bool {
        self.below(den.max(1)) < num
    }

    /// One random byte.
    pub fn byte(&mut self) -> u8 {
        self.below(256) as u8
    }

    /// A random non-zero byte mask (for bit flips that must change the
    /// target byte).
    pub fn flip_mask(&mut self) -> u8 {
        1u8 << self.below(8)
    }

    /// Uniform index into a non-empty slice length (`len == 0` returns 0;
    /// callers must guard emptiness themselves).
    pub fn index(&mut self, len: usize) -> usize {
        self.below(len as u64) as usize
    }

    /// Uniform f32 in `[lo, hi)` — enough resolution for scale/weight
    /// corpora, derived from the high bits like the proptest generators.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32;
        lo + (hi - lo) * u
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_seeds_replay_equal_streams() {
        let mut a = FuzzRng::new(7);
        let mut b = FuzzRng::new(7);
        for _ in 0..256 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = FuzzRng::new(8);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| c.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn draws_respect_bounds() {
        let mut r = FuzzRng::new(11);
        for _ in 0..512 {
            let v = r.range(3, 9);
            assert!((3..=9).contains(&v));
            assert!(r.below(5) < 5);
            let f = r.f32_in(-1.5, 2.5);
            assert!((-1.5..2.5).contains(&f));
            assert!(r.flip_mask().count_ones() == 1);
        }
        assert_eq!(r.range(4, 4), 4);
        assert_eq!(r.range(9, 3), 9);
        assert_eq!(r.below(0), 0);
    }
}
