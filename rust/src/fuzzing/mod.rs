//! Deterministic adversarial harness: structure-aware fuzzing of the CBQS
//! container parser, scheduler/generate trace ingestion, and the serving /
//! kernel differential oracles.
//!
//! Design rules (ROADMAP item 4, "seeded + deterministic so failures
//! replay"):
//!
//! * **No external deps, no entropy.** Everything derives from one
//!   [`rng::FuzzRng`] (the scheduler's LCG) — `cbq fuzz --target <t>
//!   --seed S --iters N` replays the identical corpus, mutations and
//!   verdicts on every platform, twice in a row.
//! * **Grammar-aware corpora.** Containers come out of the *real*
//!   `snapshot::format` writers and traces out of the real synthesizers,
//!   then get mutated — so every case starts from the production byte
//!   layout instead of random noise the parser rejects in the first
//!   16 bytes.
//! * **Three oracles.** A parser must never panic and never accept a
//!   checksum-covered corruption silently; trace ingestion must keep the
//!   scheduler/generate conservation + replay invariants or fail cleanly;
//!   and the eager/lazy/packed engines and scalar/SSE2/AVX2 kernels must
//!   agree bitwise on randomized inputs.
//! * **Failures persist.** A finding is minimized (end-truncation while
//!   the failure class reproduces) and written as a `CBQF` fixture under
//!   `rust/tests/fixtures/`, which `tests/fuzz_regressions.rs` replays
//!   forever.

pub mod corpus;
pub mod differential;
pub mod env;
pub mod mutate;
pub mod rng;
pub mod snapshot_target;
pub mod trace_target;

use std::panic::{self, AssertUnwindSafe};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use corpus::Fnv64;

/// Valid `--target` names, in the order CI runs them.
pub const TARGETS: &[&str] = &["snapshot", "trace", "differential"];

/// One fuzz run's parameters.
#[derive(Clone, Debug)]
pub struct FuzzOpts {
    /// Master seed: the whole run is a pure function of it.
    pub seed: u64,
    /// Iteration budget.
    pub iters: u64,
    /// Scratch directory for case files (and the differential target's
    /// synthetic model). Created on demand, cleaned per case.
    pub scratch: PathBuf,
    /// Where to persist minimized finding fixtures (`None` = don't).
    pub fixtures: Option<PathBuf>,
}

impl FuzzOpts {
    /// Options with the default scratch location (`$TMPDIR/cbq_fuzz_<pid>`).
    pub fn new(seed: u64, iters: u64) -> Self {
        Self {
            seed,
            iters,
            scratch: std::env::temp_dir().join(format!("cbq_fuzz_{}", std::process::id())),
            fixtures: None,
        }
    }
}

/// One confirmed failure: what happened, on which iteration, and the
/// minimized fixture that reproduces it (when persistence is enabled).
#[derive(Clone, Debug)]
pub struct Finding {
    /// Iteration (0-based) the failure surfaced on.
    pub iter: u64,
    /// Failure class + mutation trail, human-readable.
    pub summary: String,
    /// Path of the persisted minimized fixture, if any.
    pub fixture: Option<PathBuf>,
}

/// Outcome of a whole fuzz run. `digest` folds every case's verdict and
/// mutated-bytes checksum — two runs with equal seed/iters must report the
/// identical digest (the CLI prints it; CI compares two invocations).
#[derive(Clone, Debug)]
pub struct FuzzReport {
    /// Target name (`snapshot` / `trace` / `differential`).
    pub target: String,
    /// Seed the run used.
    pub seed: u64,
    /// Iterations executed.
    pub iters: u64,
    /// Order-sensitive FNV-1a digest of every case's outcome. Never folds
    /// error *messages* (they may embed scratch paths) — only outcome
    /// codes, content hashes and byte checksums.
    pub digest: u64,
    /// Cases that parsed/ran clean (bit-exact load, invariant-clean run).
    pub cases_ok: u64,
    /// Cases rejected with a clean error (the expected fate of most
    /// mutations).
    pub cases_rejected: u64,
    /// Confirmed failures (empty on a healthy tree).
    pub findings: Vec<Finding>,
}

/// Run one fuzz target by name.
pub fn run_target(target: &str, opts: &FuzzOpts) -> Result<FuzzReport> {
    std::fs::create_dir_all(&opts.scratch)
        .with_context(|| format!("creating fuzz scratch {:?}", opts.scratch))?;
    match target {
        "snapshot" => snapshot_target::run(opts),
        "trace" => trace_target::run(opts),
        "differential" => differential::run(opts),
        other => bail!("unknown fuzz target `{other}` (expected one of {TARGETS:?})"),
    }
}

// ---------------------------------------------------------------------------
// panic capture
// ---------------------------------------------------------------------------

/// Run `f`, converting a panic into `Err(message)`. Wrapped around every
/// parser/engine call under fuzz: a panic is always a finding, never an
/// abort of the run.
pub fn catch<T>(f: impl FnOnce() -> T) -> std::result::Result<T, String> {
    panic::catch_unwind(AssertUnwindSafe(f)).map_err(|e| {
        if let Some(s) = e.downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = e.downcast_ref::<String>() {
            s.clone()
        } else {
            "non-string panic payload".to_string()
        }
    })
}

/// Silence the default panic hook while `f` runs (fuzzing provokes panics
/// on purpose; the default hook's backtrace spam would bury real
/// findings). Panics inside `f` must be contained by [`catch`] — every
/// fuzz loop does — so the previous hook is always restored on return.
pub fn with_quiet_panics<T>(f: impl FnOnce() -> T) -> T {
    let prev = panic::take_hook();
    panic::set_hook(Box::new(|_| {}));
    let out = f();
    panic::set_hook(prev);
    out
}

// ---------------------------------------------------------------------------
// CBQF fixture files
// ---------------------------------------------------------------------------

/// Fixture magic.
pub const FIXTURE_MAGIC: &[u8; 4] = b"CBQF";
/// Fixture codec version.
pub const FIXTURE_VERSION: u32 = 1;
/// Fixture payload is a CBQS container attacked by the snapshot target.
pub const FIXTURE_TARGET_SNAPSHOT: u8 = 0;
/// Fixture payload is a `CBQT` trace attacked by the trace target.
pub const FIXTURE_TARGET_TRACE: u8 = 1;
/// The parser/ingestor must reject the payload with a clean error.
pub const FIXTURE_EXPECT_REJECT: u8 = 0;
/// The payload must be accepted: bit-exact load (snapshot, against
/// `clean_hash`) or an invariant-clean run (trace).
pub const FIXTURE_EXPECT_ACCEPT: u8 = 1;
/// The payload may be accepted or rejected, but must never panic — and an
/// accepted snapshot load must still be bit-exact when `clean_hash` is
/// non-zero, and an accepted trace run must still hold its invariants.
/// (Used for minimized panic findings, whose post-fix fate is open.)
pub const FIXTURE_EXPECT_NO_PANIC: u8 = 2;

/// A minimized repro case persisted under `rust/tests/fixtures/` —
/// self-describing, so `tests/fuzz_regressions.rs` replays it without any
/// out-of-band knowledge.
#[derive(Clone, Debug)]
pub struct Fixture {
    /// [`FIXTURE_TARGET_SNAPSHOT`] or [`FIXTURE_TARGET_TRACE`].
    pub target: u8,
    /// [`FIXTURE_EXPECT_REJECT`] or [`FIXTURE_EXPECT_ACCEPT`].
    pub expect: u8,
    /// For accept-expectation snapshot fixtures: the [`corpus::entries_hash`]
    /// the load must reproduce. 0 when unused.
    pub clean_hash: u64,
    /// The attacked bytes (container file or `CBQT` trace).
    pub payload: Vec<u8>,
}

/// Serialize a fixture to its `CBQF` file.
pub fn write_fixture(path: &Path, fx: &Fixture) -> Result<()> {
    let mut out = Vec::with_capacity(fx.payload.len() + 32);
    out.extend_from_slice(FIXTURE_MAGIC);
    out.extend_from_slice(&FIXTURE_VERSION.to_le_bytes());
    out.push(fx.target);
    out.push(fx.expect);
    out.extend_from_slice(&fx.clean_hash.to_le_bytes());
    out.extend_from_slice(&(fx.payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&fx.payload);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    std::fs::write(path, out).with_context(|| format!("writing fixture {path:?}"))
}

/// Parse a `CBQF` file.
pub fn read_fixture(path: &Path) -> Result<Fixture> {
    let bytes = std::fs::read(path).with_context(|| format!("reading fixture {path:?}"))?;
    ensure!(bytes.len() >= 26, "fixture {path:?} too short ({} bytes)", bytes.len());
    ensure!(&bytes[..4] == FIXTURE_MAGIC, "fixture {path:?} has bad magic");
    let ver = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    ensure!(ver == FIXTURE_VERSION, "fixture {path:?} has unsupported version {ver}");
    let target = bytes[8];
    let expect = bytes[9];
    ensure!(
        target <= FIXTURE_TARGET_TRACE && expect <= FIXTURE_EXPECT_NO_PANIC,
        "fixture {path:?} has out-of-range target/expect tags"
    );
    let clean_hash = u64::from_le_bytes(bytes[10..18].try_into().unwrap());
    let plen = u64::from_le_bytes(bytes[18..26].try_into().unwrap()) as usize;
    ensure!(26 + plen == bytes.len(), "fixture {path:?} payload length mismatch");
    Ok(Fixture { target, expect, clean_hash, payload: bytes[26..].to_vec() })
}

/// Replay one fixture against today's parsers, returning `Err` when its
/// expectation no longer holds — the regression-suite entry point.
pub fn replay_fixture(path: &Path) -> Result<()> {
    let fx = read_fixture(path)?;
    let scratch = std::env::temp_dir()
        .join(format!("cbq_fuzz_replay_{}_{:x}", std::process::id(), fnv_of(&fx.payload)));
    let res = with_quiet_panics(|| match fx.target {
        FIXTURE_TARGET_SNAPSHOT => {
            snapshot_target::replay_bytes(&fx.payload, fx.expect, fx.clean_hash, &scratch)
        }
        _ => trace_target::replay_bytes(&fx.payload, fx.expect),
    });
    std::fs::remove_file(&scratch).ok();
    res.with_context(|| format!("fixture {path:?}"))
}

fn fnv_of(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixture_codec_round_trips() {
        let fx = Fixture {
            target: FIXTURE_TARGET_TRACE,
            expect: FIXTURE_EXPECT_REJECT,
            clean_hash: 0xDEAD_BEEF_u64,
            payload: vec![1, 2, 3, 4, 5],
        };
        let p = std::env::temp_dir().join(format!("cbq_fx_{}.cbqf", std::process::id()));
        write_fixture(&p, &fx).unwrap();
        let back = read_fixture(&p).unwrap();
        assert_eq!(back.target, fx.target);
        assert_eq!(back.expect, fx.expect);
        assert_eq!(back.clean_hash, fx.clean_hash);
        assert_eq!(back.payload, fx.payload);
        // corrupting the framing is a clean error
        let mut raw = std::fs::read(&p).unwrap();
        raw.truncate(10);
        std::fs::write(&p, &raw).unwrap();
        assert!(read_fixture(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn catch_converts_panics_to_errors() {
        assert_eq!(with_quiet_panics(|| catch(|| 41 + 1)), Ok(42));
        let e = with_quiet_panics(|| catch(|| panic!("boom {}", 7))).unwrap_err();
        assert!(e.contains("boom 7"), "{e}");
    }

    #[test]
    fn unknown_target_is_a_clean_error() {
        let e = run_target("nope", &FuzzOpts::new(1, 1)).unwrap_err();
        assert!(format!("{e:#}").contains("unknown fuzz target"));
    }
}
