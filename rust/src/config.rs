//! Quantization job configuration: bit specs (including CBQ*'s per-layer
//! mixed precision), method selection, pre-processing choice and the CBD /
//! LoRA-Rounding hyper-parameters — the knobs every table in the paper
//! sweeps.


/// Bit-width specification. `bits_a = 16` disables activation quantization
/// (weight-only mode); per-layer overrides implement CBQ* (Table 1: FC2 of
/// the first and last block promoted to 4-bit under W2A16).
#[derive(Clone, Debug, PartialEq)]
pub struct BitSpec {
    /// Default weight bit width (overridable per layer).
    pub bits_w: u8,
    /// Activation bit width; 16 means no activation quantization.
    pub bits_a: u8,
    /// (block index, linear name, weight bits) overrides.
    pub overrides: Vec<(usize, String, u8)>,
}

impl BitSpec {
    /// Uniform `W{bits_w}A{bits_a}` spec with no overrides.
    pub fn new(bits_w: u8, bits_a: u8) -> Self {
        Self { bits_w, bits_a, overrides: Vec::new() }
    }
    /// W4A16 — weight-only 4-bit (paper Table 2).
    pub fn w4a16() -> Self {
        Self::new(4, 16)
    }
    /// W3A16 — weight-only 3-bit.
    pub fn w3a16() -> Self {
        Self::new(3, 16)
    }
    /// W2A16 — weight-only 2-bit (the extreme-low-bit setting).
    pub fn w2a16() -> Self {
        Self::new(2, 16)
    }
    /// W4A8 — weight + activation quantization (paper Table 1).
    pub fn w4a8() -> Self {
        Self::new(4, 8)
    }
    /// W4A4 — fully low-bit weights and activations.
    pub fn w4a4() -> Self {
        Self::new(4, 4)
    }
    /// W6A6 — the mid-precision weight+activation setting.
    pub fn w6a6() -> Self {
        Self::new(6, 6)
    }

    /// CBQ* (paper Table 1 footnote): W2A16 but the FC2 (`wdown`) of the
    /// first and last transformer block kept at 4 bits.
    pub fn w2a16_star(n_layers: usize) -> Self {
        let mut s = Self::new(2, 16);
        s.overrides.push((0, "wdown".to_string(), 4));
        s.overrides.push((n_layers - 1, "wdown".to_string(), 4));
        s
    }

    /// Effective weight bits for `(block, linear)`: the override if one is
    /// registered, else the uniform default.
    pub fn weight_bits(&self, block: usize, linear: &str) -> u8 {
        self.overrides
            .iter()
            .find(|(b, l, _)| *b == block && l == linear)
            .map(|&(_, _, bits)| bits)
            .unwrap_or(self.bits_w)
    }

    /// Clip level for `(block, linear)` weights — [`qmax`] of its bits.
    pub fn qmax_w(&self, block: usize, linear: &str) -> f32 {
        qmax(self.weight_bits(block, linear))
    }

    /// Clip level for activations — [`qmax`] of `bits_a`.
    pub fn qmax_a(&self) -> f32 {
        qmax(self.bits_a)
    }

    /// Activation quantization enabled?
    pub fn act_enabled(&self) -> bool {
        self.bits_a < 16
    }

    /// Table label, e.g. `W2A16*` (the star marks per-layer overrides).
    pub fn label(&self) -> String {
        let star = if self.overrides.is_empty() { "" } else { "*" };
        format!("W{}A{}{}", self.bits_w, self.bits_a, star)
    }

    /// JSON encoding for the CBQS snapshot header.
    pub fn to_json(&self) -> crate::json::Value {
        use crate::json::Value;
        Value::obj(vec![
            ("w", Value::num(self.bits_w as f64)),
            ("a", Value::num(self.bits_a as f64)),
            (
                "overrides",
                Value::arr(
                    self.overrides
                        .iter()
                        .map(|(b, l, bits)| {
                            Value::arr(vec![
                                Value::num(*b as f64),
                                Value::str(l.clone()),
                                Value::num(*bits as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Inverse of [`Self::to_json`] (reading a CBQS snapshot header).
    pub fn from_json(v: &crate::json::Value) -> anyhow::Result<Self> {
        let mut s = Self::new(v.get("w")?.as_usize()? as u8, v.get("a")?.as_usize()? as u8);
        for o in v.get("overrides")?.as_arr()? {
            let o = o.as_arr()?;
            anyhow::ensure!(o.len() == 3, "override must be [block, linear, bits]");
            s.overrides.push((
                o[0].as_usize()?,
                o[1].as_str()?.to_string(),
                o[2].as_usize()? as u8,
            ));
        }
        Ok(s)
    }
}

/// Symmetric clip level for a signed `bits`-bit grid: `2^(bits-1) - 1`
/// (integer levels span `[-qmax-1, qmax]`).
pub fn qmax(bits: u8) -> f32 {
    ((1u32 << (bits - 1)) - 1) as f32
}

/// Outlier pre-processing strategy (paper Table 3a comparators + CFP).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreprocMethod {
    /// No outlier pre-processing.
    None,
    /// OMSE-style: per-channel clip minimizing quantization MSE.
    Omse,
    /// Percentile clipping (Zhou et al. 2017).
    Percentile,
    /// Outlier Suppression: fold norm weights into consumers.
    OutlierSuppression,
    /// SmoothQuant: alpha-balanced activation->weight scale migration.
    SmoothQuant,
    /// CFP on activations only (Table 3a row "CFP-Activation").
    CfpActivation,
    /// CFP weight truncation only (the weight-only-quantization variant).
    CfpWeight,
    /// Full CFP: weight truncation + activation scaling (Sec. 3.4).
    CfpFull,
}

impl PreprocMethod {
    /// Short table-row label (matches the paper's Table 3a names).
    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Omse => "OMSE",
            Self::Percentile => "Percentile",
            Self::OutlierSuppression => "OS",
            Self::SmoothQuant => "SmoothQuant",
            Self::CfpActivation => "CFP-Act",
            Self::CfpWeight => "CFP-W",
            Self::CfpFull => "CFP-W+A",
        }
    }
}

/// Weight rounding strategy (paper Table 3b).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundingMode {
    /// Round-to-nearest: no learned offsets.
    Nearest,
    /// Dense AdaRound: a full-size V matrix per linear (memory baseline).
    DenseAdaRound,
    /// LoRA-Rounding: V = A1 @ A2 at effective rank `rank` (Sec. 3.2).
    Lora,
}

impl RoundingMode {
    /// Stable identifier used in the CBQS snapshot header.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Nearest => "nearest",
            Self::DenseAdaRound => "dense",
            Self::Lora => "lora",
        }
    }

    /// Inverse of [`Self::name`]; unknown names are an error.
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "nearest" => Self::Nearest,
            "dense" => Self::DenseAdaRound,
            "lora" => Self::Lora,
            other => anyhow::bail!("unknown rounding mode `{other}`"),
        })
    }
}

/// Top-level method selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Round-to-nearest, no reconstruction.
    Rtn,
    /// GPTQ on captured calibration activations.
    Gptq,
    /// Block/cross-block reconstruction (CBQ; window=1, overlap=0, no
    /// rounding learn ~= an OmniQuant-style baseline).
    Cbq,
}

/// A full quantization job — everything a bench row needs.
#[derive(Clone, Debug)]
pub struct QuantJob {
    /// Quantization algorithm (RTN / GPTQ / CBQ).
    pub method: Method,
    /// Weight/activation bit widths (+ per-layer overrides).
    pub bits: BitSpec,
    /// Outlier pre-processing strategy applied before quantization.
    pub preproc: PreprocMethod,
    /// Weight rounding strategy (only CBQ learns offsets).
    pub rounding: RoundingMode,
    /// CBD window size (#blocks optimized jointly, Sec. 3.1).
    pub window: usize,
    /// CBD overlap between consecutive windows.
    pub overlap: usize,
    /// Optimization epochs per window (paper: 3).
    pub epochs: usize,
    /// Effective LoRA rank r (paper: 5); projected from the padded rank.
    pub rank: usize,
    /// Calibration segments (paper: 128 x 2048 tokens of C4; here 128
    /// batch-rows of the synthetic C4-style corpus).
    pub calib_sequences: usize,
    /// Learning rate of the per-channel weight step sizes.
    pub lr_s_w: f32,
    /// Learning rate of the activation clip scalars.
    pub lr_alpha: f32,
    /// Learning rate of the LoRA-Rounding factors A1/A2.
    pub lr_lora: f32,
    /// Weight of the L2 reconstruction term in the window loss.
    pub l2_weight: f32,
    /// Weight of the KLD term in the window loss (Eq. 12).
    pub kld_weight: f32,
    /// gamma in Eq. 13 balancing L_com.
    pub gamma_c: f32,
    /// Fraction of each window's steps run with HARD rounding at the end
    /// (the paper's late-phase DeltaW-forcing): rounding offsets freeze and
    /// the step sizes adapt to the rounding the finalized model will use.
    pub hard_frac: f32,
    /// SmoothQuant migration strength (only for PreprocMethod::SmoothQuant).
    pub sq_alpha: f32,
}

impl QuantJob {
    /// Paper-default CBQ configuration (Sec. 5.1 implementation details):
    /// 2-block windows with overlap 1, 3 epochs, rank 5, CFP on.
    pub fn cbq(bits: BitSpec) -> Self {
        Self {
            method: Method::Cbq,
            bits,
            preproc: PreprocMethod::CfpFull,
            rounding: RoundingMode::Lora,
            window: 2,
            overlap: 1,
            epochs: 3,
            rank: 5,
            calib_sequences: 128,
            lr_s_w: 3e-3,
            lr_alpha: 1e-4,
            lr_lora: 1e-2,
            l2_weight: 1.0,
            kld_weight: 1.0,
            gamma_c: 1e-2,
            hard_frac: 0.7,
            sq_alpha: 0.5,
        }
    }

    /// OmniQuant-style baseline: single-block reconstruction, learnable
    /// scales only, no rounding learning, SmoothQuant-style preprocessing.
    pub fn omniquant_like(bits: BitSpec) -> Self {
        Self {
            window: 1,
            overlap: 0,
            rounding: RoundingMode::Nearest,
            preproc: PreprocMethod::SmoothQuant,
            ..Self::cbq(bits)
        }
    }

    /// Round-to-nearest baseline (no reconstruction, no pre-processing).
    pub fn rtn(bits: BitSpec) -> Self {
        Self { method: Method::Rtn, preproc: PreprocMethod::None, ..Self::cbq(bits) }
    }

    /// GPTQ baseline on captured calibration activations.
    pub fn gptq(bits: BitSpec) -> Self {
        Self { method: Method::Gptq, preproc: PreprocMethod::None, ..Self::cbq(bits) }
    }

    /// Bench-row label, e.g. `CBQ W2A16*`.
    pub fn label(&self) -> String {
        let m = match self.method {
            Method::Rtn => "RTN",
            Method::Gptq => "GPTQ",
            Method::Cbq => "CBQ",
        };
        format!("{m} {}", self.bits.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(2), 1.0);
        assert_eq!(qmax(3), 3.0);
        assert_eq!(qmax(4), 7.0);
        assert_eq!(qmax(8), 127.0);
        assert_eq!(qmax(16), 32767.0);
    }

    #[test]
    fn star_overrides() {
        let s = BitSpec::w2a16_star(8);
        assert_eq!(s.weight_bits(0, "wdown"), 4);
        assert_eq!(s.weight_bits(7, "wdown"), 4);
        assert_eq!(s.weight_bits(3, "wdown"), 2);
        assert_eq!(s.weight_bits(0, "wq"), 2);
        assert_eq!(s.label(), "W2A16*");
    }

    #[test]
    fn bitspec_json_roundtrip() {
        let s = BitSpec::w2a16_star(8);
        let back = BitSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        assert_eq!(BitSpec::from_json(&BitSpec::w4a4().to_json()).unwrap(), BitSpec::w4a4());
    }

    #[test]
    fn rounding_mode_names_roundtrip() {
        for m in [RoundingMode::Nearest, RoundingMode::DenseAdaRound, RoundingMode::Lora] {
            assert_eq!(RoundingMode::from_name(m.name()).unwrap(), m);
        }
        assert!(RoundingMode::from_name("banana").is_err());
    }

    #[test]
    fn act_enable() {
        assert!(!BitSpec::w4a16().act_enabled());
        assert!(BitSpec::w4a4().act_enabled());
        assert_eq!(BitSpec::w4a16().qmax_a(), 32767.0);
    }
}
