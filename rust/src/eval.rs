//! Evaluation: perplexity on the synthetic corpora + zero-shot choice tasks
//! + the ranking task (the paper's Table 1/2 measurement instruments).
//!
//! All model compute runs through `win_fwd_w1_*` (block chain) and
//! `lm_eval_*` (final-norm + LM head + masked NLL) executables; the host
//! only does embedding gathers and score bookkeeping.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::calib::{self, corpus::Style, ChoiceItem, TaskKind};
use crate::coordinator::{Pipeline, QuantizedModel};
use crate::runtime::{Backend as _, Bindings};
use crate::tensor::{Tensor, TensorI32};

/// Zero-shot results: accuracy per task + Mutual-style ranking metrics.
#[derive(Clone, Debug, Default)]
pub struct TaskResults {
    /// Zero-shot accuracy keyed by task name.
    pub accuracy: BTreeMap<String, f64>,
    /// Mean reciprocal rank on the ranking task.
    pub mrr: f64,
    /// Fraction of ranking items whose true response ranks first.
    pub recall1: f64,
    /// Fraction of ranking items whose true response ranks in the top two.
    pub recall2: f64,
}

impl<'a> Pipeline<'a> {
    /// Forward a token batch through the quantized model, returning the
    /// final hidden states.
    ///
    /// Perf (§Perf L3 item 3): greedily covers the block chain with the
    /// *largest exported window executables* (e.g. one `win_fwd_w8` call for
    /// the 8-layer `s` model instead of eight `win_fwd_w1` calls) — fewer
    /// dispatches and XLA fuses across block boundaries.
    pub fn forward_hidden(&self, model: &QuantizedModel, tokens: &TensorI32) -> Result<Tensor> {
        let (batch, seq) = (tokens.dims[0], tokens.dims[1]);
        let mut h = model.params.embed_tokens(&tokens.data, batch, seq);
        let qmax_a = model.bits.qmax_a();
        let a_en = if model.bits.act_enabled() { 1.0 } else { 0.0 };
        let windows = self.art.windows(&self.cfg_name);
        for (k, w) in crate::coordinator::window_plan(&windows, self.cfg.n_layers) {
            let zeros = Tensor::zeros(&h.dims);
            // weights are already baked (fake-quantized) => w_en = 0;
            // activation quant stays dynamic with the learned alpha.
            let (h_out, _) = self.window_forward(
                &format!("win_fwd_w{w}_{}", self.cfg_name),
                &model.params.blocks[k..k + w],
                &model.qstate[k..k + w],
                &h,
                &zeros,
                qmax_a,
                0.0,
                a_en,
            )?;
            h = h_out;
        }
        Ok(h)
    }

    /// Masked NLL sums + counts per sequence.
    pub fn lm_nll(
        &self,
        model: &QuantizedModel,
        inputs: &TensorI32,
        targets: &TensorI32,
        mask: &Tensor,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let h = self.forward_hidden(model, inputs)?;
        let mut b = Bindings::new();
        b.set("h", h);
        b.set("final_norm", model.params.final_norm.clone());
        b.set("head", model.params.head.clone());
        b.set_i32("targets", targets.clone());
        b.set("mask", mask.clone());
        let out = self.rt.run(&format!("lm_eval_{}", self.cfg_name), b.inner())?;
        Ok((out["nll"].data.to_vec(), out["count"].data.to_vec()))
    }

    /// Perplexity over `n_batches` held-out batches of `style`.
    pub fn perplexity(
        &self,
        model: &QuantizedModel,
        style: Style,
        n_batches: usize,
    ) -> Result<f64> {
        let batches = calib::eval_stream(style, n_batches, self.cfg.batch, self.cfg.seq);
        let mask = Tensor::full(&[self.cfg.batch, self.cfg.seq], 1.0);
        let mut nll = 0.0f64;
        let mut count = 0.0f64;
        for batch in &batches {
            let (n, c) = self.lm_nll(model, &batch.inputs(), &batch.targets(), &mask)?;
            nll += n.iter().map(|&v| v as f64).sum::<f64>();
            count += c.iter().map(|&v| v as f64).sum::<f64>();
        }
        Ok((nll / count).exp())
    }

    /// Score one candidate row (prompt ++ continuation, seq+1 tokens):
    /// masked NLL over the continuation positions.
    fn score_rows(
        &self,
        model: &QuantizedModel,
        rows: &[Vec<u32>],
        prompt_lens: &[usize],
    ) -> Result<Vec<f32>> {
        let (bsz, seq) = (self.cfg.batch, self.cfg.seq);
        assert!(rows.len() <= bsz);
        let mut in_data = vec![0i32; bsz * seq];
        let mut tg_data = vec![0i32; bsz * seq];
        let mut mask = vec![0.0f32; bsz * seq];
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), seq + 1, "row must be seq+1 tokens");
            for s in 0..seq {
                in_data[r * seq + s] = row[s] as i32;
                tg_data[r * seq + s] = row[s + 1] as i32;
                // predictions of continuation tokens start at prompt_len-1
                if s + 1 >= prompt_lens[r] {
                    mask[r * seq + s] = 1.0;
                }
            }
        }
        let (nll, count) = self.lm_nll(
            model,
            &TensorI32::new(vec![bsz, seq], in_data),
            &TensorI32::new(vec![bsz, seq], tg_data),
            &Tensor::new(vec![bsz, seq], mask),
        )?;
        Ok(rows
            .iter()
            .enumerate()
            .map(|(r, _)| nll[r] / count[r].max(1.0))
            .collect())
    }

    fn item_scores(&self, model: &QuantizedModel, item: &ChoiceItem) -> Result<Vec<f32>> {
        let rows: Vec<Vec<u32>> = item
            .cands
            .iter()
            .map(|c| {
                let mut r = item.prompt.clone();
                r.extend_from_slice(c);
                r
            })
            .collect();
        let plens = vec![item.prompt.len(); rows.len()];
        let mut scores = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(self.cfg.batch) {
            let pl = &plens[..chunk.len()];
            scores.extend(self.score_rows(model, chunk, pl)?);
        }
        Ok(scores)
    }

    /// All four choice tasks + the ranking task.
    pub fn zero_shot(&self, model: &QuantizedModel, n_items: usize) -> Result<TaskResults> {
        let mut res = TaskResults::default();
        for kind in TaskKind::ALL {
            let items = calib::choice_task(kind, n_items, self.cfg.seq + 1);
            let mut correct = 0usize;
            for item in &items {
                let scores = self.item_scores(model, item)?;
                let pick = scores
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pick == item.correct {
                    correct += 1;
                }
            }
            res.accuracy
                .insert(kind.name().to_string(), correct as f64 / items.len() as f64);
        }
        // ranking (Mutual analog): 4 candidates
        let items = calib::ranking_task(n_items / 2, 4, self.cfg.seq + 1);
        let (mut mrr, mut r1, mut r2) = (0.0, 0.0, 0.0);
        for item in &items {
            let scores = self.item_scores(model, item)?;
            let mut order: Vec<usize> = (0..scores.len()).collect();
            order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
            let rank = order.iter().position(|&i| i == item.correct).unwrap() + 1;
            mrr += 1.0 / rank as f64;
            if rank <= 1 {
                r1 += 1.0;
            }
            if rank <= 2 {
                r2 += 1.0;
            }
        }
        let n = items.len() as f64;
        res.mrr = mrr / n;
        res.recall1 = r1 / n;
        res.recall2 = r2 / n;
        Ok(res)
    }

    /// FP reference model wrapped as a QuantizedModel (w_en=a_en=0 path).
    pub fn fp_model(&self) -> QuantizedModel {
        QuantizedModel {
            params: self.fp.clone(),
            qstate: self.init_qstate(
                &self.fp,
                &crate::config::BitSpec::new(8, 16),
                5,
                crate::config::RoundingMode::Nearest,
            ),
            bits: crate::config::BitSpec::new(16, 16),
            rounding: crate::config::RoundingMode::Nearest,
        }
    }
}
