//! Lazy, block-addressable view of a CBQS snapshot: the larger-than-RAM
//! serving path.
//!
//! [`LazyModel`] wraps an opened [`LazyContainer`] and materializes tensors
//! on demand:
//!
//! * f32 tensors come back **zero-copy** when the container is memory-
//!   mapped and the payload is alignment-safe (every v2 payload is 64-byte
//!   aligned, so this is the common case) — the tensor's
//!   [`Storage`](crate::tensor::Storage) then holds a view into the file
//!   mapping and zero heap bytes;
//! * packed weight codes are CRC-checked, unpacked and dequantized into
//!   owned f32 buffers with **exactly** the arithmetic the eager loader
//!   uses — the eager [`super::load`] is in fact built on this type, so
//!   eager and lazy materialization cannot diverge;
//! * every materialization re-verifies the record's CRC-32, so corruption
//!   is caught on the lazy path at first touch, not just at open.
//!
//! The serving layer ([`crate::serve::ServeEngine`]) materializes one
//! *window* of blocks at a time through [`LazyModel::block`] and keeps a
//! bounded LRU of pinned windows; dropping a window drops its owned
//! buffers, falling back to the map.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use super::format::{LazyContainer, OpenMode, RecordMeta, Source};
use super::{parse_meta, SnapshotMeta};
use crate::config::RoundingMode;
use crate::coordinator::LinearQ;
use crate::model_state::BlockParams;
use crate::quant::{EPS, LINEARS};
use crate::runtime::backend::kernels::{self, QPanels};
use crate::tensor::io::{Entry, PackedTensor, DTYPE_F32, DTYPE_I32, DTYPE_PACKED};
use crate::tensor::{Storage, Tensor};

/// One materialized transformer block: the parameters and quantization
/// state a serve engine needs to pin a window containing this block.
pub struct MaterializedBlock {
    /// Norm weights + dequantized linear weights.
    pub params: BlockParams,
    /// Per-linear quantization state (scales, clips, LoRA factors),
    /// reconstructed exactly as the eager loader does.
    pub qstate: BTreeMap<String, LinearQ>,
}

/// One linear of a [`PackedBlock`]: the quantized codes pre-panelized for
/// the native backend's packed matmul, plus the scalar clip the forward
/// pass needs. The `Arc` makes pinning cheap to share across engines.
pub struct PackedLinear {
    /// Codes + per-channel scales in the panel layout
    /// [`kernels::qmatmul`] consumes directly.
    pub panels: Arc<QPanels>,
    /// Activation clip scalar (`qblocks.*.alpha` binding).
    pub alpha: f32,
    /// Weight bit-width this linear was exported at.
    pub bits: u8,
}

/// One transformer block materialized in the *packed domain*: norm weights
/// (zero-copy from the mapping when possible) plus per-linear
/// [`PackedLinear`] panels — no dequantized f32 weight copy is ever built.
/// This is what a packed serve window pins in place of a
/// [`MaterializedBlock`], keeping 4–16x fewer resident bytes per block.
pub struct PackedBlock {
    /// Attention RMS-norm weights `[d_model]`.
    pub attn_norm: Tensor,
    /// MLP RMS-norm weights `[d_model]`.
    pub mlp_norm: Tensor,
    /// Linear name (`wq` … `wdown`) → packed panels + scalars.
    pub linears: BTreeMap<String, PackedLinear>,
}

/// A CBQS snapshot held as an open container instead of a fully decoded
/// model. Cheap to share (`Arc` inside); all accessors take `&self` and are
/// thread-safe, so several serve engines can fault in windows concurrently
/// against one mapping of the file.
pub struct LazyModel {
    meta: SnapshotMeta,
    container: Arc<LazyContainer>,
}

/// Dequantize integer grid codes with the exact arithmetic
/// `finalize_weights` (and therefore the eager loader) uses: per-output-
/// channel `w = q * max(s, EPS)` in f32.
pub(crate) fn dequant_codes(
    codes: &[i32],
    s_w: &Tensor,
    fan_in: usize,
    fan_out: usize,
) -> Vec<f32> {
    let mut data = vec![0.0f32; fan_in * fan_out];
    for r in 0..fan_in {
        for c in 0..fan_out {
            let sc = s_w.data[c].max(EPS);
            data[r * fan_out + c] = codes[r * fan_out + c] as f32 * sc;
        }
    }
    data
}

impl LazyModel {
    /// Open `path` lazily: map the file when possible (positional-read
    /// fallback otherwise; v1 frames degrade to an in-memory buffer), parse
    /// and checksum the metadata, and verify the tensor name set is exactly
    /// what the header's config promises — no payload is decoded yet.
    pub fn open(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let container = super::format::open_container(path, OpenMode::Lazy)?;
        let meta = parse_meta(&container.header)?;
        Self::from_container(Arc::new(container), meta)
    }

    /// Wrap an already opened container (the eager loader's entry point).
    pub(crate) fn from_container(
        container: Arc<LazyContainer>,
        meta: SnapshotMeta,
    ) -> Result<Self> {
        let m = Self { meta, container };
        m.validate_names()?;
        Ok(m)
    }

    /// The header metadata (config fingerprint, bit spec, rounding, label).
    pub fn meta(&self) -> &SnapshotMeta {
        &self.meta
    }

    /// The underlying container (records, source, version).
    pub fn container(&self) -> &Arc<LazyContainer> {
        &self.container
    }

    /// Is the byte source a real memory mapping (as opposed to the
    /// positional-read or in-memory fallbacks)?
    pub fn is_mapped(&self) -> bool {
        self.container.source.mapped().is_some()
    }

    /// Identity of the byte source, for "the file is mapped once per
    /// process" assertions: clones/engines sharing this model report the
    /// same value.
    pub fn source_ptr(&self) -> usize {
        match &self.container.source {
            Source::Mapped(m) => m.as_bytes().as_ptr() as usize,
            Source::Memory(v) => v.as_ptr() as usize,
            Source::File(_) => Arc::as_ptr(&self.container) as usize,
        }
    }

    /// Every tensor name `meta.cfg` + `meta.rounding` promise, in no
    /// particular order.
    fn expected_names(&self) -> Vec<String> {
        let cfg = &self.meta.cfg;
        let lora = matches!(self.meta.rounding, RoundingMode::Lora);
        let mut names = vec!["embed".to_string(), "final_norm".to_string(), "head".to_string()];
        for i in 0..cfg.n_layers {
            names.push(format!("blocks.{i}.attn_norm"));
            names.push(format!("blocks.{i}.mlp_norm"));
            for l in LINEARS {
                names.push(format!("blocks.{i}.{l}.q"));
                names.push(format!("blocks.{i}.{l}.s_w"));
                names.push(format!("blocks.{i}.{l}.alpha"));
                if lora {
                    names.push(format!("blocks.{i}.{l}.a1"));
                    names.push(format!("blocks.{i}.{l}.a2"));
                }
            }
        }
        names
    }

    /// The record set must be exactly the expected set: a missing tensor is
    /// caught here (not mid-traffic on first touch), and extras are
    /// rejected like the eager loader always did.
    fn validate_names(&self) -> Result<()> {
        let expected = self.expected_names();
        for name in &expected {
            ensure!(
                self.container.contains(name),
                "snapshot is missing tensor `{name}`"
            );
        }
        if self.container.records.len() != expected.len() {
            let known: std::collections::BTreeSet<&str> =
                expected.iter().map(|s| s.as_str()).collect();
            let extra: Vec<&str> = self
                .container
                .records
                .iter()
                .map(|r| r.name.as_str())
                .filter(|n| !known.contains(n))
                .collect();
            bail!(
                "snapshot has {} unexpected extra tensors (first: `{}`)",
                extra.len(),
                extra.first().copied().unwrap_or("?")
            );
        }
        Ok(())
    }

    /// Materialize one f32 tensor, zero-copy from the mapping when
    /// possible, decoded into an owned buffer otherwise. `want_dims`
    /// enforces the config-derived shape (`None` skips the check).
    pub fn tensor_f32(&self, name: &str, want_dims: Option<&[usize]>) -> Result<Tensor> {
        let rec = self.container.record(name)?;
        ensure!(
            rec.dtype == DTYPE_F32 || rec.dtype == DTYPE_I32,
            "`{name}`: expected f32, found packed"
        );
        if let Some(d) = want_dims {
            ensure!(rec.dims == d, "`{name}`: dims {:?}, config wants {:?}", rec.dims, d);
        }
        // zero-copy: mapped source + CRC verified + alignment/endianness ok
        if rec.dtype == DTYPE_F32 {
            if let Some(map) = self.container.source.mapped() {
                self.container.payload(rec)?; // CRC gate, borrows the map
                if let Some(st) =
                    Storage::<f32>::from_mapped(map.clone(), rec.offset as usize, rec.elems())
                {
                    return Ok(Tensor::from_storage(rec.dims.clone(), st));
                }
                // unaligned or big-endian host: fall through to owned decode
            }
        }
        match self.container.materialize(rec)? {
            Entry::F32(t) => Ok(t),
            Entry::Packed(_) => bail!("`{name}`: expected f32, found packed"),
        }
    }

    /// Materialize one packed-code tensor (CRC verified; bytes are copied —
    /// unpacking consumes them immediately, so zero-copy buys nothing).
    pub fn packed(&self, name: &str) -> Result<PackedTensor> {
        let rec = self.container.record(name)?;
        ensure!(rec.dtype == DTYPE_PACKED, "`{name}`: expected packed codes, found f32");
        match self.container.materialize(rec)? {
            Entry::Packed(p) => Ok(p),
            Entry::F32(_) => bail!("`{name}`: expected packed codes, found f32"),
        }
    }

    /// The token embedding table `[vocab, d_model]` (zero-copy candidate).
    pub fn embed(&self) -> Result<Tensor> {
        let cfg = &self.meta.cfg;
        self.tensor_f32("embed", Some(&[cfg.vocab, cfg.d_model]))
    }

    /// The final RMS-norm weights `[d_model]`.
    pub fn final_norm(&self) -> Result<Tensor> {
        self.tensor_f32("final_norm", Some(&[self.meta.cfg.d_model]))
    }

    /// The LM head `[d_model, vocab]` (zero-copy candidate — the largest
    /// f32 tensor in the file).
    pub fn head(&self) -> Result<Tensor> {
        let cfg = &self.meta.cfg;
        self.tensor_f32("head", Some(&[cfg.d_model, cfg.vocab]))
    }

    /// Materialize block `i`: unpack + dequantize its seven linears and
    /// rebuild the [`LinearQ`] state, bit-exactly equal to what the eager
    /// loader produces for the same file. This is the unit of lazy pinning:
    /// the serve engine calls this per window member on first touch and
    /// drops the result on eviction.
    pub fn block(&self, i: usize) -> Result<MaterializedBlock> {
        let cfg = &self.meta.cfg;
        ensure!(i < cfg.n_layers, "block {i} out of range (model has {})", cfg.n_layers);
        let d = cfg.d_model;
        let attn_norm = self.tensor_f32(&format!("blocks.{i}.attn_norm"), Some(&[d]))?;
        let mlp_norm = self.tensor_f32(&format!("blocks.{i}.mlp_norm"), Some(&[d]))?;
        let store_lora = matches!(self.meta.rounding, RoundingMode::Lora);
        let mut linears = BTreeMap::new();
        let mut qstate = BTreeMap::new();
        for l in LINEARS {
            let (fan_in, fan_out) = cfg.linear_shape(l);
            let packed = self.packed(&format!("blocks.{i}.{l}.q"))?;
            ensure!(
                packed.dims == [fan_in, fan_out],
                "blocks.{i}.{l}.q: dims {:?}, config wants [{fan_in}, {fan_out}]",
                packed.dims
            );
            let spec_bits = self.meta.bits.weight_bits(i, l);
            ensure!(
                packed.bits == spec_bits,
                "blocks.{i}.{l}: packed at {} bits but spec says {spec_bits}",
                packed.bits
            );
            let s_w = self.tensor_f32(&format!("blocks.{i}.{l}.s_w"), Some(&[fan_out]))?;
            let alpha = self.tensor_f32(&format!("blocks.{i}.{l}.alpha"), Some(&[]))?.item();
            let (a1, a2) = if store_lora {
                (
                    self.tensor_f32(
                        &format!("blocks.{i}.{l}.a1"),
                        Some(&[fan_in, cfg.rank_pad]),
                    )?,
                    self.tensor_f32(
                        &format!("blocks.{i}.{l}.a2"),
                        Some(&[cfg.rank_pad, fan_out]),
                    )?,
                )
            } else {
                (
                    Tensor::zeros(&[fan_in, cfg.rank_pad]),
                    Tensor::zeros(&[cfg.rank_pad, fan_out]),
                )
            };
            let codes = packed.unpack();
            let w =
                Tensor::new(vec![fan_in, fan_out], dequant_codes(&codes, &s_w, fan_in, fan_out));
            let lq = LinearQ::restore(&w, s_w, alpha, a1, a2, spec_bits);
            linears.insert(l.to_string(), w);
            qstate.insert(l.to_string(), lq);
        }
        Ok(MaterializedBlock {
            params: BlockParams { attn_norm, mlp_norm, linears },
            qstate,
        })
    }

    /// Materialize block `i` in the packed domain: CRC-check the code
    /// records and re-panelize them for [`kernels::qmatmul`], without ever
    /// building the dequantized f32 weights. Scales are folded into the
    /// panels pre-floored by `EPS`, so the packed matmul reproduces
    /// [`dequant_codes`] → f32 matmul bit-exactly.
    pub fn block_packed(&self, i: usize) -> Result<PackedBlock> {
        let cfg = &self.meta.cfg;
        ensure!(i < cfg.n_layers, "block {i} out of range (model has {})", cfg.n_layers);
        let d = cfg.d_model;
        let attn_norm = self.tensor_f32(&format!("blocks.{i}.attn_norm"), Some(&[d]))?;
        let mlp_norm = self.tensor_f32(&format!("blocks.{i}.mlp_norm"), Some(&[d]))?;
        let mut linears = BTreeMap::new();
        for l in LINEARS {
            let (fan_in, fan_out) = cfg.linear_shape(l);
            let packed = self.packed(&format!("blocks.{i}.{l}.q"))?;
            ensure!(
                packed.dims == [fan_in, fan_out],
                "blocks.{i}.{l}.q: dims {:?}, config wants [{fan_in}, {fan_out}]",
                packed.dims
            );
            let spec_bits = self.meta.bits.weight_bits(i, l);
            ensure!(
                packed.bits == spec_bits,
                "blocks.{i}.{l}: packed at {} bits but spec says {spec_bits}",
                packed.bits
            );
            let s_w = self.tensor_f32(&format!("blocks.{i}.{l}.s_w"), Some(&[fan_out]))?;
            let alpha = self.tensor_f32(&format!("blocks.{i}.{l}.alpha"), Some(&[]))?.item();
            let codes = packed.unpack();
            let panels = QPanels::pack(&codes, fan_in, fan_out, packed.bits, &s_w.data);
            linears.insert(
                l.to_string(),
                PackedLinear { panels: Arc::new(panels), alpha, bits: packed.bits },
            );
        }
        Ok(PackedBlock { attn_norm, mlp_norm, linears })
    }

    /// Heap bytes materializing block `i` costs (dequantized weights, the
    /// re-derived `v0` warm-start of equal size, scales, LoRA factors,
    /// norms) — the per-block unit behind `CBQ_RESIDENT_MB` sizing. A
    /// width-`w` pinned window keeps roughly `w` times this resident.
    pub fn block_resident_estimate(&self, i: usize) -> u64 {
        block_resident_estimate(&self.container.records, i)
    }

    /// Heap bytes pinning block `i` costs on the *packed* serving path:
    /// panelized codes + per-channel scales per linear, plus the norm
    /// weights. Compare with [`Self::block_resident_estimate`] — the ratio
    /// is roughly `32 / bits` for the weight-dominated records.
    pub fn block_packed_resident_estimate(&self, i: usize) -> u64 {
        block_packed_resident_estimate(&self.container.records, i)
    }
}

/// Per-block resident-bytes estimate from a record table: the sum of every
/// `blocks.{i}.*` tensor's f32-materialized size, counting packed code
/// tensors twice (dequantized weights + the equally-sized `v0` warm-start
/// `LinearQ` re-derives). Shared by [`LazyModel`] and `cbq snapshot-info`.
pub fn block_resident_estimate(records: &[RecordMeta], i: usize) -> u64 {
    let prefix = format!("blocks.{i}.");
    records
        .iter()
        .filter(|r| r.name.starts_with(&prefix))
        .map(|r| {
            let mult = if r.dtype == DTYPE_PACKED { 2 } else { 1 };
            mult * r.unpacked_bytes()
        })
        .sum()
}

/// Per-block resident-bytes estimate for the *packed* serving path: each
/// code record costs its panelized codes + per-channel scales (see
/// [`kernels::packed_resident_bytes`]); `s_w` is folded into the panels
/// (counted there, not again); LoRA factors are never bound when serving
/// packed (`use_lora = 0`); everything else (norms, alpha scalars) is
/// counted at f32-materialized size. Shared by [`LazyModel`] and
/// `cbq snapshot-info`.
pub fn block_packed_resident_estimate(records: &[RecordMeta], i: usize) -> u64 {
    let prefix = format!("blocks.{i}.");
    records
        .iter()
        .filter(|r| r.name.starts_with(&prefix))
        .map(|r| {
            if r.dtype == DTYPE_PACKED {
                debug_assert_eq!(r.dims.len(), 2);
                kernels::packed_resident_bytes(r.dims[0], r.dims[1], r.bits) as u64
            } else if r.name.ends_with(".s_w")
                || r.name.ends_with(".a1")
                || r.name.ends_with(".a2")
            {
                0
            } else {
                r.unpacked_bytes()
            }
        })
        .sum()
}
