//! Quantized-model snapshot store — the "quantize once, serve forever"
//! deliverable (CBQ's headline tradeoff: hours of PTQ amortized over every
//! later serving run).
//!
//! [`save`] serializes a finalized [`QuantizedModel`] into a versioned
//! `CBQS` container (see [`format`]):
//!
//! * per-linear weight **codes at their true bit-width** (2/4/8-bit
//!   bitpacked integers, not fake-quant f32) + the learned per-channel
//!   scales — a w4 snapshot is ~1/8 the size of the f32 weights for the
//!   quantized linears;
//! * the activation-quant state eval needs (per-linear `alpha` clips),
//!   the LoRA-Rounding factors, the [`BitSpec`] / [`RoundingMode`];
//! * unquantized tensors (embeddings, LM head, norms) stored f32;
//! * a header with the full model-config fingerprint and a CRC-32 content
//!   checksum.
//!
//! [`load`] reverses it **bit-exactly**: the dequantized weights are the
//! identical f32 values the in-memory pipeline produced (`w = q * s` in the
//! same arithmetic `finalize_weights` used), so perplexity measured on a
//! loaded snapshot equals the in-memory model's to the last bit.

pub mod format;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, ensure, Result};

use crate::config::{BitSpec, RoundingMode};
use crate::coordinator::{LinearQ, QuantizedModel};
use crate::json::Value;
use crate::model_state::{BlockParams, ModelParams};
use crate::quant::{EPS, LINEARS};
use crate::runtime::ModelCfg;
use crate::tensor::io::{Entry, PackedTensor};
use crate::tensor::Tensor;

/// Everything the header records about a snapshot.
#[derive(Clone, Debug)]
pub struct SnapshotMeta {
    pub cfg: ModelCfg,
    pub bits: BitSpec,
    pub rounding: RoundingMode,
    /// Human label of the producing job (e.g. "CBQ W4A16").
    pub label: String,
}

/// A loaded snapshot: metadata + the reconstructed model.
pub struct Snapshot {
    pub meta: SnapshotMeta,
    pub model: QuantizedModel,
}

/// Size accounting returned by [`save`].
#[derive(Clone, Debug)]
pub struct SaveReport {
    /// Bytes of the CBQS file on disk.
    pub file_bytes: u64,
    /// Bytes the same tensors occupy in f32 (the CBQW-equivalent payload).
    pub f32_equiv_bytes: u64,
    /// Bytes of bitpacked weight codes alone.
    pub packed_code_bytes: u64,
}

impl SaveReport {
    /// file size as a fraction of the f32 representation.
    pub fn compression_ratio(&self) -> f64 {
        self.file_bytes as f64 / self.f32_equiv_bytes.max(1) as f64
    }
}

fn entry_f32(entries: &mut Vec<(String, Entry)>, name: String, t: Tensor) {
    entries.push((name, Entry::F32(t)));
}

/// Derive the integer grid codes for a finalized weight matrix and verify
/// the snapshot dequantization (`q * s`) reproduces it bit-exactly.
fn codes_for(w: &Tensor, s_w: &Tensor, bits: u8, what: &str) -> Result<Vec<i32>> {
    let (k, n) = (w.rows(), w.cols());
    ensure!(s_w.len() == n, "{what}: {} scales for {n} output channels", s_w.len());
    let half = 1i32 << (bits - 1);
    let mut codes = vec![0i32; k * n];
    for i in 0..k {
        for j in 0..n {
            let sc = s_w.data[j].max(EPS);
            let v = w.at2(i, j);
            let q = (v / sc).round();
            ensure!(
                q.is_finite() && q >= -(half as f32) && q < half as f32,
                "{what}[{i},{j}]: code {q} outside signed {bits}-bit grid — \
                 was this model finalized at these bits?"
            );
            let qi = q as i32;
            // the round-trip contract: dequant must equal the baked weight
            ensure!(
                qi as f32 * sc == v,
                "{what}[{i},{j}]: {v} is not on the quantization grid \
                 (q={qi}, s={sc}) — only finalized quantized models export"
            );
            codes[i * n + j] = qi;
        }
    }
    Ok(codes)
}

/// Serialize a finalized quantized model to `path`.
pub fn save(path: impl AsRef<Path>, cfg: &ModelCfg, model: &QuantizedModel) -> Result<SaveReport> {
    ensure!(
        model.bits.bits_w <= 8,
        "W{} is not a packable bit-width — snapshots hold quantized models \
         (the FP reference stays in CBQW)",
        model.bits.bits_w
    );
    ensure!(
        model.params.blocks.len() == cfg.n_layers,
        "model has {} blocks, config {} says {}",
        model.params.blocks.len(),
        cfg.name,
        cfg.n_layers
    );
    let mut entries: Vec<(String, Entry)> = Vec::new();
    let mut f32_equiv = 0u64;
    let mut packed_bytes = 0u64;

    for t in [&model.params.embed, &model.params.final_norm, &model.params.head] {
        f32_equiv += 4 * t.len() as u64;
    }
    entry_f32(&mut entries, "embed".into(), model.params.embed.clone());
    entry_f32(&mut entries, "final_norm".into(), model.params.final_norm.clone());
    entry_f32(&mut entries, "head".into(), model.params.head.clone());

    let store_lora = matches!(model.rounding, RoundingMode::Lora);
    for (i, blk) in model.params.blocks.iter().enumerate() {
        f32_equiv += 4 * (blk.attn_norm.len() + blk.mlp_norm.len()) as u64;
        entry_f32(&mut entries, format!("blocks.{i}.attn_norm"), blk.attn_norm.clone());
        entry_f32(&mut entries, format!("blocks.{i}.mlp_norm"), blk.mlp_norm.clone());
        for l in LINEARS {
            let w = &blk.linears[l];
            let lq = model.qstate[i]
                .get(l)
                .ok_or_else(|| anyhow!("missing qstate for blocks.{i}.{l}"))?;
            let bits = lq.bits_w;
            if bits > 8 {
                bail!(
                    "blocks.{i}.{l} is {bits}-bit — snapshots pack at most 8 bits \
                     (FP models stay in CBQW)"
                );
            }
            ensure!(
                bits == model.bits.weight_bits(i, l),
                "blocks.{i}.{l}: qstate bits {bits} != spec {}",
                model.bits.weight_bits(i, l)
            );
            let codes = codes_for(w, &lq.s_w, bits, &format!("blocks.{i}.{l}"))?;
            let packed = PackedTensor::pack(&codes, w.dims.clone(), bits)?;
            f32_equiv += 4 * w.len() as u64;
            packed_bytes += packed.data.len() as u64;
            entries.push((format!("blocks.{i}.{l}.q"), Entry::Packed(packed)));
            entry_f32(&mut entries, format!("blocks.{i}.{l}.s_w"), lq.s_w.clone());
            entry_f32(&mut entries, format!("blocks.{i}.{l}.alpha"), Tensor::scalar(lq.alpha));
            if store_lora {
                entry_f32(&mut entries, format!("blocks.{i}.{l}.a1"), lq.a1.clone());
                entry_f32(&mut entries, format!("blocks.{i}.{l}.a2"), lq.a2.clone());
            }
        }
    }

    let header = Value::obj(vec![
        ("format", Value::str("CBQS")),
        ("version", Value::num(format::VERSION as f64)),
        ("cfg", cfg.to_json()),
        ("bits", model.bits.to_json()),
        ("rounding", Value::str(model.rounding.name())),
        ("label", Value::str(model.bits.label())),
    ]);
    let file_bytes = format::write_container(path, &header, &entries)?;
    Ok(SaveReport { file_bytes, f32_equiv_bytes: f32_equiv, packed_code_bytes: packed_bytes })
}

fn take_f32(
    entries: &mut BTreeMap<String, Entry>,
    name: &str,
    want_dims: Option<&[usize]>,
) -> Result<Tensor> {
    match entries.remove(name) {
        Some(Entry::F32(t)) => {
            if let Some(d) = want_dims {
                ensure!(t.dims == d, "`{name}`: dims {:?}, config wants {:?}", t.dims, d);
            }
            Ok(t)
        }
        Some(Entry::Packed(_)) => bail!("`{name}`: expected f32, found packed"),
        None => bail!("snapshot is missing tensor `{name}`"),
    }
}

fn take_packed(entries: &mut BTreeMap<String, Entry>, name: &str) -> Result<PackedTensor> {
    match entries.remove(name) {
        Some(Entry::Packed(p)) => Ok(p),
        Some(Entry::F32(_)) => bail!("`{name}`: expected packed codes, found f32"),
        None => bail!("snapshot is missing tensor `{name}`"),
    }
}

/// Parse + harden the CBQS header (shared by [`load`] and [`inspect`]).
/// Header numerics drive allocations (Vec::with_capacity, Tensor::zeros)
/// before any entry is cross-checked, so they are bounded here: a crafted
/// file with a valid CRC must produce an error, not an allocation abort.
fn parse_meta(header: &Value) -> Result<SnapshotMeta> {
    ensure!(
        header.get("format")?.as_str()? == "CBQS",
        "header format field is not CBQS"
    );
    let cfg = ModelCfg::from_json(header.get("cfg")?)?;
    for (field, v, cap) in [
        ("n_layers", cfg.n_layers, 1usize << 10),
        ("d_model", cfg.d_model, 1 << 17),
        ("d_ffn", cfg.d_ffn, 1 << 19),
        ("vocab", cfg.vocab, 1 << 21),
        ("seq", cfg.seq, 1 << 17),
        ("batch", cfg.batch, 1 << 12),
        ("rank_pad", cfg.rank_pad, 1 << 10),
    ] {
        ensure!(v >= 1 && v <= cap, "snapshot header {field} = {v} outside sane range [1, {cap}]");
    }
    let bits = BitSpec::from_json(header.get("bits")?)?;
    let rounding = RoundingMode::from_name(header.get("rounding")?.as_str()?)?;
    let label = header.get("label")?.as_str()?.to_string();
    Ok(SnapshotMeta { cfg, bits, rounding, label })
}

/// Load a snapshot, reconstructing the bit-exact [`QuantizedModel`].
pub fn load(path: impl AsRef<Path>) -> Result<Snapshot> {
    let (header, mut entries) = format::read_container(path)?;
    let meta = parse_meta(&header)?;
    let SnapshotMeta { cfg, bits, rounding, label } = meta;

    let d = cfg.d_model;
    let embed = take_f32(&mut entries, "embed", Some(&[cfg.vocab, d]))?;
    let final_norm = take_f32(&mut entries, "final_norm", Some(&[d]))?;
    let head = take_f32(&mut entries, "head", Some(&[d, cfg.vocab]))?;

    let store_lora = matches!(rounding, RoundingMode::Lora);
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    let mut qstate: Vec<BTreeMap<String, LinearQ>> = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let attn_norm = take_f32(&mut entries, &format!("blocks.{i}.attn_norm"), Some(&[d]))?;
        let mlp_norm = take_f32(&mut entries, &format!("blocks.{i}.mlp_norm"), Some(&[d]))?;
        let mut linears = BTreeMap::new();
        let mut lqs = BTreeMap::new();
        for l in LINEARS {
            let (fan_in, fan_out) = cfg.linear_shape(l);
            let packed = take_packed(&mut entries, &format!("blocks.{i}.{l}.q"))?;
            ensure!(
                packed.dims == [fan_in, fan_out],
                "blocks.{i}.{l}.q: dims {:?}, config wants [{fan_in}, {fan_out}]",
                packed.dims
            );
            let spec_bits = bits.weight_bits(i, l);
            ensure!(
                packed.bits == spec_bits,
                "blocks.{i}.{l}: packed at {} bits but spec says {spec_bits}",
                packed.bits
            );
            let s_w =
                take_f32(&mut entries, &format!("blocks.{i}.{l}.s_w"), Some(&[fan_out]))?;
            let alpha =
                take_f32(&mut entries, &format!("blocks.{i}.{l}.alpha"), Some(&[]))?.item();
            let (a1, a2) = if store_lora {
                (
                    take_f32(
                        &mut entries,
                        &format!("blocks.{i}.{l}.a1"),
                        Some(&[fan_in, cfg.rank_pad]),
                    )?,
                    take_f32(
                        &mut entries,
                        &format!("blocks.{i}.{l}.a2"),
                        Some(&[cfg.rank_pad, fan_out]),
                    )?,
                )
            } else {
                (
                    Tensor::zeros(&[fan_in, cfg.rank_pad]),
                    Tensor::zeros(&[cfg.rank_pad, fan_out]),
                )
            };
            // dequantize: the exact arithmetic finalize_weights used
            let codes = packed.unpack();
            let mut data = vec![0.0f32; fan_in * fan_out];
            for r in 0..fan_in {
                for c in 0..fan_out {
                    let sc = s_w.data[c].max(EPS);
                    data[r * fan_out + c] = codes[r * fan_out + c] as f32 * sc;
                }
            }
            let w = Tensor::new(vec![fan_in, fan_out], data);
            let lq = LinearQ::restore(&w, s_w, alpha, a1, a2, spec_bits);
            linears.insert(l.to_string(), w);
            lqs.insert(l.to_string(), lq);
        }
        blocks.push(BlockParams { attn_norm, mlp_norm, linears });
        qstate.push(lqs);
    }
    ensure!(
        entries.is_empty(),
        "snapshot has {} unexpected extra tensors (first: `{}`)",
        entries.len(),
        entries.keys().next().unwrap()
    );

    let model = QuantizedModel {
        params: ModelParams { embed, final_norm, head, blocks },
        qstate,
        bits: bits.clone(),
        rounding,
    };
    Ok(Snapshot { meta: SnapshotMeta { cfg, bits, rounding, label }, model })
}

/// One entry's metadata as reported by [`inspect`].
#[derive(Clone, Debug)]
pub struct TensorInfo {
    pub name: String,
    /// "f32" or "packed"
    pub dtype: &'static str,
    /// storage bits per element (32 for f32, 2/4/8 for packed codes)
    pub bits: u8,
    pub dims: Vec<usize>,
    /// payload bytes on disk
    pub bytes: usize,
}

/// Header + per-tensor summary of a CBQS file, without reconstructing the
/// model (the `cbq snapshot-info` inspector).
#[derive(Clone, Debug)]
pub struct SnapshotInfo {
    pub meta: SnapshotMeta,
    pub version: u32,
    pub file_bytes: u64,
    pub tensors: Vec<TensorInfo>,
    pub packed_code_bytes: u64,
    pub f32_bytes: u64,
    /// `inspect` only returns when the container CRC verified, so this is
    /// always true on success — carried for report serialization.
    pub checksum_ok: bool,
}

impl SnapshotInfo {
    /// (bits, tensor count, payload bytes) aggregated over packed tensors.
    pub fn packed_by_bits(&self) -> Vec<(u8, usize, u64)> {
        let mut agg: BTreeMap<u8, (usize, u64)> = BTreeMap::new();
        for t in self.tensors.iter().filter(|t| t.dtype == "packed") {
            let e = agg.entry(t.bits).or_insert((0, 0));
            e.0 += 1;
            e.1 += t.bytes as u64;
        }
        agg.into_iter().map(|(bits, (n, bytes))| (bits, n, bytes)).collect()
    }
}

/// Read a snapshot's header and entry metadata (CRC-validated) without
/// dequantizing anything.
pub fn inspect(path: impl AsRef<Path>) -> Result<SnapshotInfo> {
    let file_bytes = std::fs::metadata(path.as_ref())
        .map(|m| m.len())
        .unwrap_or(0);
    let (header, entries) = format::read_container(path)?;
    let meta = parse_meta(&header)?;
    let version = header.get("version")?.as_usize()? as u32;
    let mut tensors = Vec::with_capacity(entries.len());
    let mut packed_code_bytes = 0u64;
    let mut f32_bytes = 0u64;
    for (name, e) in &entries {
        let info = match e {
            Entry::F32(t) => TensorInfo {
                name: name.clone(),
                dtype: "f32",
                bits: 32,
                dims: t.dims.clone(),
                bytes: 4 * t.len(),
            },
            Entry::Packed(p) => TensorInfo {
                name: name.clone(),
                dtype: "packed",
                bits: p.bits,
                dims: p.dims.clone(),
                bytes: p.data.len(),
            },
        };
        match info.dtype {
            "packed" => packed_code_bytes += info.bytes as u64,
            _ => f32_bytes += info.bytes as u64,
        }
        tensors.push(info);
    }
    Ok(SnapshotInfo {
        meta,
        version,
        file_bytes,
        tensors,
        packed_code_bytes,
        f32_bytes,
        checksum_ok: true,
    })
}

/// Compare a snapshot's config fingerprint against the artifacts' config.
/// Returns the list of mismatched fields (empty = compatible).
pub fn fingerprint_mismatches(snap: &ModelCfg, art: &ModelCfg) -> Vec<String> {
    fn chk<T: PartialEq + std::fmt::Display>(
        out: &mut Vec<String>,
        field: &str,
        a: &T,
        b: &T,
    ) {
        if a != b {
            out.push(format!("{field}: snapshot {a} vs artifacts {b}"));
        }
    }
    // full destructuring, no `..`: adding a ModelCfg field fails to compile
    // here until the fingerprint covers it
    let ModelCfg {
        name,
        d_model,
        n_layers,
        n_heads,
        d_ffn,
        vocab,
        seq,
        batch,
        rank_pad,
        head_dim,
        outlier_channels,
        outlier_gain,
    } = snap;
    let mut out = Vec::new();
    chk(&mut out, "name", name, &art.name);
    chk(&mut out, "d_model", d_model, &art.d_model);
    chk(&mut out, "n_layers", n_layers, &art.n_layers);
    chk(&mut out, "n_heads", n_heads, &art.n_heads);
    chk(&mut out, "d_ffn", d_ffn, &art.d_ffn);
    chk(&mut out, "vocab", vocab, &art.vocab);
    chk(&mut out, "seq", seq, &art.seq);
    chk(&mut out, "batch", batch, &art.batch);
    chk(&mut out, "rank_pad", rank_pad, &art.rank_pad);
    chk(&mut out, "head_dim", head_dim, &art.head_dim);
    chk(&mut out, "outlier_channels", outlier_channels, &art.outlier_channels);
    chk(&mut out, "outlier_gain", outlier_gain, &art.outlier_gain);
    out
}
