//! Quantized-model snapshot store — the "quantize once, serve forever"
//! deliverable (CBQ's headline tradeoff: hours of PTQ amortized over every
//! later serving run).
//!
//! [`save`] serializes a finalized [`QuantizedModel`] into a versioned
//! `CBQS` container (see [`format`]; byte-level spec in `docs/FORMAT.md`):
//!
//! * per-linear weight **codes at their true bit-width** (2/4/8-bit
//!   bitpacked integers, not fake-quant f32) + the learned per-channel
//!   scales — a w4 snapshot is ~1/8 the size of the f32 weights for the
//!   quantized linears;
//! * the activation-quant state eval needs (per-linear `alpha` clips),
//!   the LoRA-Rounding factors, the [`BitSpec`] / [`RoundingMode`];
//! * unquantized tensors (embeddings, LM head, norms) stored f32;
//! * a header with the full model-config fingerprint, plus (v2) a
//!   per-tensor record table with 64-byte-aligned file offsets and
//!   per-tensor CRC-32s.
//!
//! Two load paths reverse it:
//!
//! * [`load`] — eager: the fully decoded [`QuantizedModel`],
//!   **bit-exactly** the f32 values the in-memory pipeline produced
//!   (`w = q * s` in the same arithmetic `finalize_weights` used), so
//!   perplexity measured on a loaded snapshot equals the in-memory model's
//!   to the last bit. Reads v1 and v2 frames identically.
//! * [`load_lazy`] — the larger-than-RAM path: the file is memory-mapped
//!   (or positionally read where mapping is unavailable) and a
//!   [`lazy::LazyModel`] hands out tensors on demand — f32 tensors
//!   zero-copy from the map, packed codes dequantized per *block* on first
//!   touch. The eager loader is built on the same materialization code, so
//!   the two paths cannot diverge.

pub mod format;
pub mod lazy;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Result};

use crate::config::{BitSpec, RoundingMode};
use crate::coordinator::{LinearQ, QuantizedModel};
use crate::json::Value;
use crate::model_state::ModelParams;
use crate::quant::{EPS, LINEARS};
use crate::runtime::ModelCfg;
use crate::tensor::io::{Entry, PackedTensor, DTYPE_PACKED};
use crate::tensor::Tensor;

pub use lazy::{LazyModel, MaterializedBlock};

/// Everything the header records about a snapshot.
#[derive(Clone, Debug)]
pub struct SnapshotMeta {
    /// Full model-config fingerprint of the producing artifacts.
    pub cfg: ModelCfg,
    /// Weight/activation bit widths (incl. per-layer overrides).
    pub bits: BitSpec,
    /// Rounding mode the model was finalized with.
    pub rounding: RoundingMode,
    /// Human label of the producing job (e.g. "CBQ W4A16").
    pub label: String,
}

/// A loaded snapshot: metadata + the reconstructed model.
pub struct Snapshot {
    /// Parsed header metadata.
    pub meta: SnapshotMeta,
    /// The bit-exact reconstructed model.
    pub model: QuantizedModel,
}

/// A lazily opened snapshot: metadata + the on-demand model view.
pub struct LazySnapshot {
    /// Parsed header metadata.
    pub meta: SnapshotMeta,
    /// The on-demand model (see [`lazy::LazyModel`]).
    pub model: LazyModel,
}

/// A snapshot-backed model in either residency mode, with uniform
/// accessors. The serve registry stores this so one engine code path can
/// bind eagerly decoded and memory-mapped models alike.
pub enum SnapshotModel {
    /// Fully decoded in RAM ([`load`]).
    Eager(QuantizedModel),
    /// Materialized on demand from the container ([`load_lazy`]).
    Lazy(LazyModel),
}

impl SnapshotModel {
    /// Is this the lazy (mapped / on-demand) representation?
    pub fn is_lazy(&self) -> bool {
        matches!(self, SnapshotModel::Lazy(_))
    }

    /// The eager model, when resident (registry paths that need the whole
    /// `QuantizedModel`, e.g. perplexity eval over all blocks at once).
    pub fn eager(&self) -> Option<&QuantizedModel> {
        match self {
            SnapshotModel::Eager(m) => Some(m),
            SnapshotModel::Lazy(_) => None,
        }
    }

    /// The lazy view, when this model is one.
    pub fn lazy(&self) -> Option<&LazyModel> {
        match self {
            SnapshotModel::Lazy(m) => Some(m),
            SnapshotModel::Eager(_) => None,
        }
    }

    /// Like [`SnapshotModel::eager`] but an error naming the remedy.
    pub fn expect_eager(&self) -> Result<&QuantizedModel> {
        self.eager().ok_or_else(|| {
            anyhow!("this operation needs an eagerly loaded model (loaded with --mmap?)")
        })
    }

    /// The token embedding table (eager: a shared handle; lazy: zero-copy
    /// from the map when possible).
    pub fn embed(&self) -> Result<Tensor> {
        match self {
            SnapshotModel::Eager(m) => Ok(m.params.embed.clone()),
            SnapshotModel::Lazy(m) => m.embed(),
        }
    }

    /// The final RMS-norm weights.
    pub fn final_norm(&self) -> Result<Tensor> {
        match self {
            SnapshotModel::Eager(m) => Ok(m.params.final_norm.clone()),
            SnapshotModel::Lazy(m) => m.final_norm(),
        }
    }

    /// The LM head.
    pub fn head(&self) -> Result<Tensor> {
        match self {
            SnapshotModel::Eager(m) => Ok(m.params.head.clone()),
            SnapshotModel::Lazy(m) => m.head(),
        }
    }

    /// Materialize block `i` (eager: shared handles, no decode; lazy:
    /// unpack + dequantize on the spot). Both paths yield bit-identical
    /// tensors for the same file.
    pub fn block(&self, i: usize) -> Result<MaterializedBlock> {
        match self {
            SnapshotModel::Eager(m) => {
                ensure!(
                    i < m.params.blocks.len(),
                    "block {i} out of range (model has {})",
                    m.params.blocks.len()
                );
                Ok(MaterializedBlock {
                    params: m.params.blocks[i].clone(),
                    qstate: m.qstate[i].clone(),
                })
            }
            SnapshotModel::Lazy(m) => m.block(i),
        }
    }
}

/// Size accounting returned by [`save`].
#[derive(Clone, Debug)]
pub struct SaveReport {
    /// Bytes of the CBQS file on disk.
    pub file_bytes: u64,
    /// Bytes the same tensors occupy in f32 (the CBQW-equivalent payload).
    pub f32_equiv_bytes: u64,
    /// Bytes of bitpacked weight codes alone.
    pub packed_code_bytes: u64,
}

impl SaveReport {
    /// file size as a fraction of the f32 representation.
    pub fn compression_ratio(&self) -> f64 {
        self.file_bytes as f64 / self.f32_equiv_bytes.max(1) as f64
    }
}

/// Derive the integer grid codes for a finalized weight matrix and verify
/// the snapshot dequantization (`q * s`) reproduces it bit-exactly.
fn codes_for(w: &Tensor, s_w: &Tensor, bits: u8, what: &str) -> Result<Vec<i32>> {
    let (k, n) = (w.rows(), w.cols());
    ensure!(s_w.len() == n, "{what}: {} scales for {n} output channels", s_w.len());
    let half = 1i32 << (bits - 1);
    let mut codes = vec![0i32; k * n];
    for i in 0..k {
        for j in 0..n {
            let sc = s_w.data[j].max(EPS);
            let v = w.at2(i, j);
            let q = (v / sc).round();
            ensure!(
                q.is_finite() && q >= -(half as f32) && q < half as f32,
                "{what}[{i},{j}]: code {q} outside signed {bits}-bit grid — \
                 was this model finalized at these bits?"
            );
            let qi = q as i32;
            // the round-trip contract: dequant must equal the baked weight
            ensure!(
                qi as f32 * sc == v,
                "{what}[{i},{j}]: {v} is not on the quantization grid \
                 (q={qi}, s={sc}) — only finalized quantized models export"
            );
            codes[i * n + j] = qi;
        }
    }
    Ok(codes)
}

/// Build the header + grouped entry list shared by the v2 and v1 writers.
fn build_entries(
    cfg: &ModelCfg,
    model: &QuantizedModel,
    version: u32,
) -> Result<(Value, Vec<(String, Entry, i32)>, u64, u64)> {
    ensure!(
        model.bits.bits_w <= 8,
        "W{} is not a packable bit-width — snapshots hold quantized models \
         (the FP reference stays in CBQW)",
        model.bits.bits_w
    );
    ensure!(
        model.params.blocks.len() == cfg.n_layers,
        "model has {} blocks, config {} says {}",
        model.params.blocks.len(),
        cfg.name,
        cfg.n_layers
    );
    let mut entries: Vec<(String, Entry, i32)> = Vec::new();
    let mut f32_equiv = 0u64;
    let mut packed_bytes = 0u64;
    let push_f32 = |entries: &mut Vec<(String, Entry, i32)>, name: String, t: Tensor, g: i32| {
        entries.push((name, Entry::F32(t), g));
    };

    for t in [&model.params.embed, &model.params.final_norm, &model.params.head] {
        f32_equiv += 4 * t.len() as u64;
    }
    push_f32(&mut entries, "embed".into(), model.params.embed.clone(), -1);
    push_f32(&mut entries, "final_norm".into(), model.params.final_norm.clone(), -1);
    push_f32(&mut entries, "head".into(), model.params.head.clone(), -1);

    let store_lora = matches!(model.rounding, RoundingMode::Lora);
    for (i, blk) in model.params.blocks.iter().enumerate() {
        let g = i as i32;
        f32_equiv += 4 * (blk.attn_norm.len() + blk.mlp_norm.len()) as u64;
        push_f32(&mut entries, format!("blocks.{i}.attn_norm"), blk.attn_norm.clone(), g);
        push_f32(&mut entries, format!("blocks.{i}.mlp_norm"), blk.mlp_norm.clone(), g);
        for l in LINEARS {
            let w = &blk.linears[l];
            let lq = model.qstate[i]
                .get(l)
                .ok_or_else(|| anyhow!("missing qstate for blocks.{i}.{l}"))?;
            let bits = lq.bits_w;
            if bits > 8 {
                bail!(
                    "blocks.{i}.{l} is {bits}-bit — snapshots pack at most 8 bits \
                     (FP models stay in CBQW)"
                );
            }
            ensure!(
                bits == model.bits.weight_bits(i, l),
                "blocks.{i}.{l}: qstate bits {bits} != spec {}",
                model.bits.weight_bits(i, l)
            );
            let codes = codes_for(w, &lq.s_w, bits, &format!("blocks.{i}.{l}"))?;
            let packed = PackedTensor::pack(&codes, w.dims.clone(), bits)?;
            f32_equiv += 4 * w.len() as u64;
            packed_bytes += packed.data.len() as u64;
            entries.push((format!("blocks.{i}.{l}.q"), Entry::Packed(packed), g));
            push_f32(&mut entries, format!("blocks.{i}.{l}.s_w"), lq.s_w.clone(), g);
            push_f32(&mut entries, format!("blocks.{i}.{l}.alpha"), Tensor::scalar(lq.alpha), g);
            if store_lora {
                push_f32(&mut entries, format!("blocks.{i}.{l}.a1"), lq.a1.clone(), g);
                push_f32(&mut entries, format!("blocks.{i}.{l}.a2"), lq.a2.clone(), g);
            }
        }
    }

    let header = Value::obj(vec![
        ("format", Value::str("CBQS")),
        ("version", Value::num(version as f64)),
        ("cfg", cfg.to_json()),
        ("bits", model.bits.to_json()),
        ("rounding", Value::str(model.rounding.name())),
        ("label", Value::str(model.bits.label())),
    ]);
    Ok((header, entries, f32_equiv, packed_bytes))
}

/// Serialize a finalized quantized model to `path` as a v2 container
/// (offset table + per-tensor CRCs; lazily loadable via [`load_lazy`]).
pub fn save(path: impl AsRef<Path>, cfg: &ModelCfg, model: &QuantizedModel) -> Result<SaveReport> {
    let (header, entries, f32_equiv, packed_bytes) =
        build_entries(cfg, model, format::VERSION)?;
    let file_bytes = format::write_container(path, &header, &entries)?;
    Ok(SaveReport { file_bytes, f32_equiv_bytes: f32_equiv, packed_code_bytes: packed_bytes })
}

/// Serialize as a **legacy v1** container (whole-payload CRC, no offset
/// table — not lazily loadable). Exists for compatibility testing and for
/// producing files older readers can consume.
pub fn save_v1(
    path: impl AsRef<Path>,
    cfg: &ModelCfg,
    model: &QuantizedModel,
) -> Result<SaveReport> {
    let (header, entries, f32_equiv, packed_bytes) =
        build_entries(cfg, model, format::VERSION_V1)?;
    let flat: Vec<(String, Entry)> =
        entries.into_iter().map(|(n, e, _)| (n, e)).collect();
    let file_bytes = format::write_container_v1(path, &header, &flat)?;
    Ok(SaveReport { file_bytes, f32_equiv_bytes: f32_equiv, packed_code_bytes: packed_bytes })
}

/// Parse + harden the CBQS header (shared by [`load`], [`load_lazy`] and
/// [`inspect`]). Header numerics drive allocations (Vec::with_capacity,
/// Tensor::zeros) before any entry is cross-checked, so they are bounded
/// here: a crafted file with a valid CRC must produce an error, not an
/// allocation abort.
pub(crate) fn parse_meta(header: &Value) -> Result<SnapshotMeta> {
    ensure!(
        header.get("format")?.as_str()? == "CBQS",
        "header format field is not CBQS"
    );
    let cfg = ModelCfg::from_json(header.get("cfg")?)?;
    for (field, v, cap) in [
        ("n_layers", cfg.n_layers, 1usize << 10),
        ("d_model", cfg.d_model, 1 << 17),
        ("d_ffn", cfg.d_ffn, 1 << 19),
        ("vocab", cfg.vocab, 1 << 21),
        ("seq", cfg.seq, 1 << 17),
        ("batch", cfg.batch, 1 << 12),
        ("rank_pad", cfg.rank_pad, 1 << 10),
    ] {
        ensure!(v >= 1 && v <= cap, "snapshot header {field} = {v} outside sane range [1, {cap}]");
    }
    let bits = BitSpec::from_json(header.get("bits")?)?;
    let rounding = RoundingMode::from_name(header.get("rounding")?.as_str()?)?;
    let label = header.get("label")?.as_str()?.to_string();
    Ok(SnapshotMeta { cfg, bits, rounding, label })
}

/// Load a snapshot **eagerly**, reconstructing the bit-exact
/// [`QuantizedModel`]. Reads v1 and v2 frames; both materialize through
/// the same [`lazy::LazyModel`] code the mmap path uses, so eager, lazy,
/// v1 and v2 all decode to identical tensors.
pub fn load(path: impl AsRef<Path>) -> Result<Snapshot> {
    let container = format::open_container(path, format::OpenMode::Eager)?;
    let meta = parse_meta(&container.header)?;
    let lazy = LazyModel::from_container(Arc::new(container), meta.clone())?;
    let model = materialize_model(&lazy)?;
    Ok(Snapshot { meta, model })
}

/// Open a snapshot **lazily** for larger-than-RAM serving: metadata is
/// parsed and checksummed now, tensors materialize on first touch (see
/// [`lazy::LazyModel`]). v1 frames work too, but degrade to an in-memory
/// byte source (their whole-payload CRC requires a full read) — re-export
/// to v2 to get true mapped loading.
pub fn load_lazy(path: impl AsRef<Path>) -> Result<LazySnapshot> {
    let model = LazyModel::open(path)?;
    Ok(LazySnapshot { meta: model.meta().clone(), model })
}

/// Materialize every block of a lazy view into a full [`QuantizedModel`]
/// (the eager loader's second half).
fn materialize_model(lazy: &LazyModel) -> Result<QuantizedModel> {
    let meta = lazy.meta();
    let cfg = &meta.cfg;
    let embed = lazy.embed()?;
    let final_norm = lazy.final_norm()?;
    let head = lazy.head()?;
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    let mut qstate: Vec<BTreeMap<String, LinearQ>> = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let mb = lazy.block(i)?;
        blocks.push(mb.params);
        qstate.push(mb.qstate);
    }
    Ok(QuantizedModel {
        params: ModelParams { embed, final_norm, head, blocks },
        qstate,
        bits: meta.bits.clone(),
        rounding: meta.rounding,
    })
}

/// One entry's metadata as reported by [`inspect`].
#[derive(Clone, Debug)]
pub struct TensorInfo {
    /// Tensor name.
    pub name: String,
    /// "f32" or "packed"
    pub dtype: &'static str,
    /// storage bits per element (32 for f32, 2/4/8 for packed codes)
    pub bits: u8,
    /// Logical shape.
    pub dims: Vec<usize>,
    /// payload bytes on disk
    pub bytes: usize,
    /// Bytes once materialized for execution (f32 everywhere): elems × 4.
    pub unpacked_bytes: u64,
    /// Absolute payload offset in the file (64-byte aligned in v2 frames;
    /// reconstructed parse positions for v1).
    pub offset: u64,
    /// Producing block index, -1 for globals (v2 record field; derived
    /// from the name for v1 frames).
    pub group: i32,
}

/// Header + per-tensor summary of a CBQS file, without reconstructing the
/// model (the `cbq snapshot-info` inspector).
#[derive(Clone, Debug)]
pub struct SnapshotInfo {
    /// Parsed header metadata.
    pub meta: SnapshotMeta,
    /// Frame version found on disk (1 or 2).
    pub version: u32,
    /// Total file size.
    pub file_bytes: u64,
    /// Per-tensor records, in file order.
    pub tensors: Vec<TensorInfo>,
    /// Bytes of bitpacked weight codes on disk.
    pub packed_code_bytes: u64,
    /// Bytes of f32 tensors on disk.
    pub f32_bytes: u64,
    /// Sum of every tensor's f32-materialized size: what a **mapped** load
    /// would occupy if every tensor were promoted to owned at once.
    pub unpacked_bytes: u64,
    /// Estimated heap bytes of a full **eager** load: `unpacked_bytes`
    /// plus a second copy of each packed tensor (the `v0` warm-start
    /// `LinearQ` re-derives per linear).
    pub resident_estimate_bytes: u64,
    /// The largest single block's eager-residency estimate — multiply by
    /// the window width to size `CBQ_RESIDENT_MB` / `--resident-windows`.
    pub max_block_resident_bytes: u64,
    /// Sum of every block's *packed* pinning cost (panelized codes +
    /// per-channel scales + norms — what `--packed` serving keeps resident
    /// instead of dequantized f32 weights).
    pub packed_resident_estimate_bytes: u64,
    /// The largest single block's packed pinning cost — the `--packed`
    /// counterpart of [`Self::max_block_resident_bytes`].
    pub max_block_packed_resident_bytes: u64,
    /// `inspect` only returns when every checksum verified (metadata and
    /// all payloads), so this is always true on success — carried for
    /// report serialization.
    pub checksum_ok: bool,
}

impl SnapshotInfo {
    /// (bits, tensor count, payload bytes) aggregated over packed tensors.
    pub fn packed_by_bits(&self) -> Vec<(u8, usize, u64)> {
        let mut agg: BTreeMap<u8, (usize, u64)> = BTreeMap::new();
        for t in self.tensors.iter().filter(|t| t.dtype == "packed") {
            let e = agg.entry(t.bits).or_insert((0, 0));
            e.0 += 1;
            e.1 += t.bytes as u64;
        }
        agg.into_iter().map(|(bits, (n, bytes))| (bits, n, bytes)).collect()
    }
}

/// Read a snapshot's header and entry metadata (all checksums validated)
/// without dequantizing anything. Opens through the lazy source so
/// inspecting a larger-than-RAM snapshot never buffers the whole file:
/// payload CRCs stream through the mapping page by page (v1 frames still
/// require a full read — their single CRC leaves no choice).
pub fn inspect(path: impl AsRef<Path>) -> Result<SnapshotInfo> {
    let c = format::open_container(path, format::OpenMode::Lazy)?;
    let meta = parse_meta(&c.header)?;
    let mut tensors = Vec::with_capacity(c.records.len());
    let mut packed_code_bytes = 0u64;
    let mut f32_bytes = 0u64;
    let mut unpacked_bytes = 0u64;
    let mut resident = 0u64;
    for rec in &c.records {
        // validate every payload checksum — inspect's contract is "the
        // whole file is intact", same as the v1 whole-payload CRC gave
        c.payload(rec)?;
        let packed = rec.dtype == DTYPE_PACKED;
        let group = if rec.group >= 0 {
            rec.group
        } else {
            // v1 records carry no group; recover it from the name
            rec.name
                .strip_prefix("blocks.")
                .and_then(|s| s.split('.').next())
                .and_then(|s| s.parse::<i32>().ok())
                .unwrap_or(-1)
        };
        let info = TensorInfo {
            name: rec.name.clone(),
            dtype: if packed { "packed" } else { "f32" },
            bits: if packed { rec.bits } else { 32 },
            dims: rec.dims.clone(),
            bytes: rec.len as usize,
            unpacked_bytes: rec.unpacked_bytes(),
            offset: rec.offset,
            group,
        };
        if packed {
            packed_code_bytes += info.bytes as u64;
            resident += 2 * rec.unpacked_bytes(); // dequant weights + v0
        } else {
            f32_bytes += info.bytes as u64;
            resident += rec.unpacked_bytes();
        }
        unpacked_bytes += rec.unpacked_bytes();
        tensors.push(info);
    }
    let max_block_resident_bytes = (0..meta.cfg.n_layers)
        .map(|i| lazy::block_resident_estimate(&c.records, i))
        .max()
        .unwrap_or(0);
    let packed_per_block: Vec<u64> = (0..meta.cfg.n_layers)
        .map(|i| lazy::block_packed_resident_estimate(&c.records, i))
        .collect();
    Ok(SnapshotInfo {
        meta,
        version: c.version,
        file_bytes: c.file_bytes,
        tensors,
        packed_code_bytes,
        f32_bytes,
        unpacked_bytes,
        resident_estimate_bytes: resident,
        max_block_resident_bytes,
        packed_resident_estimate_bytes: packed_per_block.iter().sum(),
        max_block_packed_resident_bytes: packed_per_block.into_iter().max().unwrap_or(0),
        checksum_ok: true,
    })
}

/// Compare a snapshot's config fingerprint against the artifacts' config.
/// Returns the list of mismatched fields (empty = compatible).
pub fn fingerprint_mismatches(snap: &ModelCfg, art: &ModelCfg) -> Vec<String> {
    fn chk<T: PartialEq + std::fmt::Display>(
        out: &mut Vec<String>,
        field: &str,
        a: &T,
        b: &T,
    ) {
        if a != b {
            out.push(format!("{field}: snapshot {a} vs artifacts {b}"));
        }
    }
    // full destructuring, no `..`: adding a ModelCfg field fails to compile
    // here until the fingerprint covers it
    let ModelCfg {
        name,
        d_model,
        n_layers,
        n_heads,
        d_ffn,
        vocab,
        seq,
        batch,
        rank_pad,
        head_dim,
        outlier_channels,
        outlier_gain,
    } = snap;
    let mut out = Vec::new();
    chk(&mut out, "name", name, &art.name);
    chk(&mut out, "d_model", d_model, &art.d_model);
    chk(&mut out, "n_layers", n_layers, &art.n_layers);
    chk(&mut out, "n_heads", n_heads, &art.n_heads);
    chk(&mut out, "d_ffn", d_ffn, &art.d_ffn);
    chk(&mut out, "vocab", vocab, &art.vocab);
    chk(&mut out, "seq", seq, &art.seq);
    chk(&mut out, "batch", batch, &art.batch);
    chk(&mut out, "rank_pad", rank_pad, &art.rank_pad);
    chk(&mut out, "head_dim", head_dim, &art.head_dim);
    chk(&mut out, "outlier_channels", outlier_channels, &art.outlier_channels);
    chk(&mut out, "outlier_gain", outlier_gain, &art.outlier_gain);
    out
}
