//! `CBQS` binary container: the on-disk frame around a quantized-model
//! snapshot.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! [magic "CBQS"][version u32][payload_len u32][payload][crc32(payload) u32]
//! payload = [header_len u32][header JSON utf-8][n_entries u32][entry...]
//! ```
//!
//! Entries use the shared codec in `tensor::io` (`write_entry`/`read_entry`),
//! which is where the packed-integer dtype lives. The CRC covers the whole
//! payload (header + entries), so a flipped bit anywhere — metadata or
//! weights — is detected at load time before any tensor is interpreted.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::json::{self, Value};
use crate::tensor::io::{read_entry, write_entry, ByteReader, Entry};

pub const MAGIC: &[u8; 4] = b"CBQS";
pub const VERSION: u32 = 1;

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320), table-driven: the
/// checksum runs over the whole payload on every save *and* load, and
/// payloads scale with model size, so the 1 KiB table is worth it.
pub fn crc32(bytes: &[u8]) -> u32 {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = (c >> 1) ^ (0xEDB8_8320 & (c & 1).wrapping_neg());
            }
            *e = c;
        }
        t
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = (crc >> 8) ^ table[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// Write a container. Returns bytes written.
pub fn write_container(
    path: impl AsRef<Path>,
    header: &Value,
    entries: &[(String, Entry)],
) -> Result<u64> {
    let header_json = json::dump(header);
    ensure!(header_json.len() <= u32::MAX as usize, "snapshot header exceeds u32 framing");
    let mut payload = Vec::new();
    payload.extend_from_slice(&(header_json.len() as u32).to_le_bytes());
    payload.extend_from_slice(header_json.as_bytes());
    payload.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for (name, e) in entries {
        write_entry(&mut payload, name, e)?;
    }
    ensure!(
        payload.len() <= u32::MAX as usize,
        "snapshot payload is {} bytes — exceeds the v1 u32 framing limit; \
         shard the model before export",
        payload.len()
    );
    let mut raw = Vec::with_capacity(payload.len() + 16);
    raw.extend_from_slice(MAGIC);
    raw.extend_from_slice(&VERSION.to_le_bytes());
    raw.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    raw.extend_from_slice(&payload);
    raw.extend_from_slice(&crc32(&payload).to_le_bytes());
    std::fs::write(path.as_ref(), &raw)
        .with_context(|| format!("writing snapshot {:?}", path.as_ref()))?;
    Ok(raw.len() as u64)
}

/// Read and fully validate a container: magic, version, framing, checksum,
/// and per-entry hardening (duplicates, truncation, overflow) all checked.
pub fn read_container(path: impl AsRef<Path>) -> Result<(Value, BTreeMap<String, Entry>)> {
    let raw = std::fs::read(path.as_ref())
        .with_context(|| format!("reading snapshot {:?}", path.as_ref()))?;
    let mut r = ByteReader::new(&raw);
    let magic = r.take(4)?;
    ensure!(magic == MAGIC, "not a CBQS snapshot (magic {:?})", magic);
    let version = r.u32()?;
    ensure!(version == VERSION, "unsupported CBQS version {version} (expected {VERSION})");
    let payload_len = r.u32()? as usize;
    ensure!(
        r.remaining() == payload_len + 4,
        "corrupt framing: payload {payload_len}B + crc vs {}B remaining",
        r.remaining()
    );
    let payload = r.take(payload_len)?;
    let stored_crc = r.u32()?;
    let actual = crc32(payload);
    ensure!(
        stored_crc == actual,
        "checksum mismatch: stored {stored_crc:#010x}, computed {actual:#010x} — snapshot corrupt"
    );

    let mut p = ByteReader::new(payload);
    let header_len = p.u32()? as usize;
    let header_raw = std::str::from_utf8(p.take(header_len)?)?;
    let header = json::parse(header_raw).context("parsing snapshot header")?;
    let n = p.u32()? as usize;
    let mut entries = BTreeMap::new();
    for _ in 0..n {
        let (name, e) = read_entry(&mut p)?;
        ensure!(entries.insert(name.clone(), e).is_none(), "duplicate entry `{name}`");
    }
    ensure!(p.is_done(), "{} trailing bytes after last entry", p.remaining());
    Ok((header, entries))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::io::PackedTensor;
    use crate::tensor::Tensor;

    fn sample() -> (Value, Vec<(String, Entry)>) {
        let header = Value::obj(vec![("format", Value::str("CBQS")), ("v", Value::num(1.0))]);
        let entries = vec![
            ("w".to_string(), Entry::F32(Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]))),
            (
                "q".to_string(),
                Entry::Packed(PackedTensor::pack(&[-8, 7, 0, 1, 2, -1], vec![6], 4).unwrap()),
            ),
        ];
        (header, entries)
    }

    #[test]
    fn crc32_known_vector() {
        // standard test vector: CRC-32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn container_roundtrip() {
        let (header, entries) = sample();
        let p = std::env::temp_dir().join("cbqs_fmt_roundtrip.bin");
        write_container(&p, &header, &entries).unwrap();
        let (h, m) = read_container(&p).unwrap();
        assert_eq!(h, header);
        assert_eq!(m.len(), 2);
        assert_eq!(m["w"], entries[0].1);
        assert_eq!(m["q"], entries[1].1);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn detects_bit_flip_anywhere() {
        let (header, entries) = sample();
        let p = std::env::temp_dir().join("cbqs_fmt_bitflip.bin");
        write_container(&p, &header, &entries).unwrap();
        let clean = std::fs::read(&p).unwrap();
        // flip one bit in every payload byte position in turn
        for pos in 12..clean.len() - 4 {
            let mut bad = clean.clone();
            bad[pos] ^= 0x10;
            std::fs::write(&p, &bad).unwrap();
            assert!(read_container(&p).is_err(), "bit flip at {pos} not detected");
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_version_and_magic() {
        let (header, entries) = sample();
        let p = std::env::temp_dir().join("cbqs_fmt_ver.bin");
        write_container(&p, &header, &entries).unwrap();
        let clean = std::fs::read(&p).unwrap();

        let mut bad_magic = clean.clone();
        bad_magic[0] = b'X';
        std::fs::write(&p, &bad_magic).unwrap();
        let e = read_container(&p).unwrap_err();
        assert!(format!("{e:#}").contains("magic"), "{e:#}");

        let mut bad_ver = clean.clone();
        bad_ver[4] = 99;
        std::fs::write(&p, &bad_ver).unwrap();
        let e = read_container(&p).unwrap_err();
        assert!(format!("{e:#}").contains("version"), "{e:#}");

        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_truncation() {
        let (header, entries) = sample();
        let p = std::env::temp_dir().join("cbqs_fmt_trunc.bin");
        write_container(&p, &header, &entries).unwrap();
        let clean = std::fs::read(&p).unwrap();
        for cut in [1usize, 5, clean.len() / 2] {
            let bad = clean[..clean.len() - cut].to_vec();
            std::fs::write(&p, &bad).unwrap();
            assert!(read_container(&p).is_err(), "truncation by {cut} not detected");
        }
        std::fs::remove_file(p).ok();
    }
}
