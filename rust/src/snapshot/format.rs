//! `CBQS` binary container: the on-disk frame around a quantized-model
//! snapshot. The normative byte-level specification lives in
//! `docs/FORMAT.md` at the repo root; this module is the reference
//! implementation.
//!
//! Two frame versions exist:
//!
//! * **v1** (legacy, still read bit-exactly):
//!
//!   ```text
//!   [magic "CBQS"][version u32 = 1][payload_len u32][payload][crc32(payload) u32]
//!   payload = [header_len u32][header JSON utf-8][n_entries u32][entry...]
//!   ```
//!
//!   Entries use the shared codec in `tensor::io` (`write_entry` /
//!   `read_entry`). One CRC-32 covers the whole payload, so the file can
//!   only be validated by reading **all** of it — fine for models that fit
//!   in RAM, useless for lazy loading.
//!
//! * **v2** (current, written by [`write_container`]):
//!
//!   ```text
//!   [magic "CBQS"][version u32 = 2][meta_len u64]
//!   [meta: header_len u32, header JSON, n_records u32, record...]
//!   [meta_crc u32 = crc32(bytes 0 .. 16+meta_len)]
//!   [64-byte-aligned tensor payloads, zero padding between]
//!   record = [name_len u32][name][dtype u8][bits u8][ndim u8][dims u32...]
//!            [group i32][offset u64][len u64][crc32(payload) u32]
//!   ```
//!
//!   The record table carries absolute payload offsets (64-byte aligned so
//!   mapped f32 views are always alignment-safe) and a **per-tensor**
//!   CRC-32, so a lazy loader can validate the header cheaply up front and
//!   each tensor independently on first touch. `group` is the producing
//!   block index (`-1` for globals like `embed`) — the per-window tensor
//!   index the serving layer groups by. v2 frames use u64 lengths: the v1
//!   4 GiB payload cap is gone.
//!
//! [`open_container`] dispatches on the version tag and returns a
//! [`LazyContainer`] over a byte [`Source`] (mmap, positional reads, or an
//! in-memory buffer); [`read_container`] is the eager convenience on top,
//! and is what v1 files always get (their whole-payload CRC forces a full
//! read anyway).

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::json::{self, Value};
use crate::tensor::io::{
    write_entry, ByteReader, Entry, PackedTensor, DTYPE_F32, DTYPE_I32, DTYPE_PACKED,
    MAX_NAME_LEN, MAX_NDIM,
};
use crate::tensor::Tensor;

/// The four magic bytes every CBQS file starts with.
pub const MAGIC: &[u8; 4] = b"CBQS";
/// Frame version this code writes ([`write_container`]).
pub const VERSION: u32 = 2;
/// The legacy frame version ([`write_container_v1`]), still readable.
pub const VERSION_V1: u32 = 1;
/// Alignment of every v2 tensor payload. 64 divides the page size on every
/// supported platform, so a 64-aligned file offset yields a 64-aligned
/// pointer inside a page-aligned mapping — safe to reinterpret as f32/i32.
pub const PAYLOAD_ALIGN: u64 = 64;
/// Sanity cap on v2 `group` ids (block indices; -1 means "global").
const MAX_GROUP: i32 = 1 << 20;

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320), table-driven: the
/// checksum runs over headers and payloads on every save *and* load, and
/// payloads scale with model size, so the 1 KiB table is worth it.
pub fn crc32(bytes: &[u8]) -> u32 {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = (c >> 1) ^ (0xEDB8_8320 & (c & 1).wrapping_neg());
            }
            *e = c;
        }
        t
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = (crc >> 8) ^ table[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

fn align_up(v: u64, align: u64) -> u64 {
    v.div_ceil(align) * align
}

// ---------------------------------------------------------------------------
// record metadata
// ---------------------------------------------------------------------------

/// One tensor's entry in the v2 record table (or the equivalent
/// reconstructed from a v1 frame during parsing).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RecordMeta {
    /// Tensor name (e.g. `blocks.3.wq.q`).
    pub name: String,
    /// Dtype tag: [`DTYPE_F32`], [`DTYPE_I32`] (v1 legacy) or
    /// [`DTYPE_PACKED`].
    pub dtype: u8,
    /// Storage bits per element: 32 for f32/i32, the packed bit-width
    /// (1..=8) for packed codes.
    pub bits: u8,
    /// Logical tensor shape.
    pub dims: Vec<usize>,
    /// Producing block index, `-1` for global tensors (embed, head, ...).
    /// This is the per-window index key the lazy serving path groups by.
    pub group: i32,
    /// Absolute file offset of the payload (64-byte aligned in v2 frames;
    /// arbitrary in records reconstructed from v1 frames).
    pub offset: u64,
    /// Payload length in bytes.
    pub len: u64,
    /// CRC-32 of the payload bytes, verified on every materialization.
    pub crc: u32,
}

impl RecordMeta {
    /// Number of logical elements (`dims` product).
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    /// Bytes this tensor occupies once materialized for execution: f32
    /// everywhere (packed codes dequantize to f32), i.e. `elems * 4`. The
    /// `cbq snapshot-info` resident estimates sum this.
    pub fn unpacked_bytes(&self) -> u64 {
        4 * self.elems() as u64
    }
}

// ---------------------------------------------------------------------------
// byte sources
// ---------------------------------------------------------------------------

/// Where a container's payload bytes come from.
pub enum Source {
    /// A shared read-only memory mapping: zero-copy, pages fault in on
    /// demand (the larger-than-RAM serving path).
    Mapped(Arc<mmap::Mmap>),
    /// Positional reads from the file (pure-Rust fallback when mapping is
    /// unavailable): lazy but each touched range is copied to the heap.
    File(mmap::ReadAtFile),
    /// The whole file resident in memory (eager loads and all v1 frames,
    /// whose whole-payload CRC forces a full read regardless).
    Memory(Arc<Vec<u8>>),
}

/// A byte range handed out by [`Source::bytes`]: borrowed (zero-copy) from
/// a mapping or in-memory buffer, or owned when it had to be read from
/// disk.
pub enum SourceBytes<'a> {
    /// Zero-copy view into the source.
    Borrowed(&'a [u8]),
    /// Freshly read copy (the [`Source::File`] path).
    Owned(Vec<u8>),
}

impl std::ops::Deref for SourceBytes<'_> {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        match self {
            SourceBytes::Borrowed(b) => b,
            SourceBytes::Owned(v) => v.as_slice(),
        }
    }
}

impl Source {
    /// Total length of the underlying file/buffer in bytes.
    pub fn len(&self) -> u64 {
        match self {
            Source::Mapped(m) => m.len() as u64,
            Source::File(f) => f.len(),
            Source::Memory(v) => v.len() as u64,
        }
    }

    /// Is the source empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fetch `len` bytes at `offset` (bounds-checked).
    pub fn bytes(&self, offset: u64, len: u64) -> Result<SourceBytes<'_>> {
        let end = offset
            .checked_add(len)
            .ok_or_else(|| anyhow!("byte range {offset}+{len} overflows"))?;
        ensure!(
            end <= self.len(),
            "truncated container: byte range {offset}+{len} exceeds file length {}",
            self.len()
        );
        // cast only inside the in-memory arms (there the range fits usize
        // by construction); the File arm keeps the u64 offset so >4 GiB
        // snapshots read correctly even where usize is 32-bit
        Ok(match self {
            Source::Mapped(m) => {
                SourceBytes::Borrowed(&m.as_bytes()[offset as usize..end as usize])
            }
            Source::Memory(v) => SourceBytes::Borrowed(&v[offset as usize..end as usize]),
            Source::File(f) => SourceBytes::Owned(f.read_at(offset, len as usize)?),
        })
    }

    /// The shared mapping, when this source is one (the zero-copy tensor
    /// construction path checks this).
    pub fn mapped(&self) -> Option<&Arc<mmap::Mmap>> {
        match self {
            Source::Mapped(m) => Some(m),
            _ => None,
        }
    }
}

impl std::fmt::Debug for Source {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Source::Mapped(m) => write!(f, "Source::Mapped[{} bytes]", m.len()),
            Source::File(r) => write!(f, "Source::File[{} bytes]", r.len()),
            Source::Memory(v) => write!(f, "Source::Memory[{} bytes]", v.len()),
        }
    }
}

// ---------------------------------------------------------------------------
// the container handle
// ---------------------------------------------------------------------------

/// How [`open_container`] should source payload bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpenMode {
    /// Read the whole file into memory up front (the classic path).
    Eager,
    /// Map the file read-only when possible, falling back to positional
    /// reads; payloads are validated and decoded on first touch. v1 frames
    /// degrade to an in-memory source (their CRC requires a full read).
    Lazy,
}

/// An opened CBQS container: validated header + record table over a byte
/// [`Source`]. Payloads are fetched and CRC-checked per record via
/// [`LazyContainer::materialize`] / [`LazyContainer::payload`].
pub struct LazyContainer {
    /// Frame version actually found in the file (1 or 2).
    pub version: u32,
    /// The parsed header JSON.
    pub header: Value,
    /// Per-tensor record table, in file order.
    pub records: Vec<RecordMeta>,
    /// Payload byte source.
    pub source: Source,
    /// Total file size in bytes.
    pub file_bytes: u64,
    by_name: BTreeMap<String, usize>,
}

impl LazyContainer {
    /// Look up a record by tensor name.
    pub fn record(&self, name: &str) -> Result<&RecordMeta> {
        self.by_name
            .get(name)
            .map(|&i| &self.records[i])
            .ok_or_else(|| anyhow!("snapshot is missing tensor `{name}`"))
    }

    /// Does the container hold a tensor by this name?
    pub fn contains(&self, name: &str) -> bool {
        self.by_name.contains_key(name)
    }

    /// Fetch one record's payload bytes and verify its CRC-32. This is the
    /// lazy path's integrity gate: every materialization revalidates, so a
    /// bit flip under an already-open container is still caught on the
    /// next touch.
    pub fn payload(&self, rec: &RecordMeta) -> Result<SourceBytes<'_>> {
        let bytes = self.source.bytes(rec.offset, rec.len)?;
        let actual = crc32(&bytes);
        ensure!(
            actual == rec.crc,
            "checksum mismatch on `{}`: stored {:#010x}, computed {actual:#010x} — \
             snapshot corrupt",
            rec.name,
            rec.crc
        );
        Ok(bytes)
    }

    /// Decode one record into an owned [`Entry`] (payload CRC verified).
    /// The zero-copy mapped-tensor path lives in `snapshot::lazy` instead;
    /// this is the always-correct fallback and the eager loader's builder.
    pub fn materialize(&self, rec: &RecordMeta) -> Result<Entry> {
        let bytes = self.payload(rec)?;
        decode_entry(rec, &bytes)
    }
}

/// Decode a record's payload bytes into an [`Entry`] (dtype dispatch; the
/// legacy v1 i32 dtype converts to f32 exactly as the CBQW reader did).
fn decode_entry(rec: &RecordMeta, bytes: &[u8]) -> Result<Entry> {
    match rec.dtype {
        DTYPE_F32 | DTYPE_I32 => {
            let data: Vec<f32> = if rec.dtype == DTYPE_F32 {
                bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect()
            } else {
                bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f32)
                    .collect()
            };
            ensure!(
                data.len() == rec.elems(),
                "`{}`: {} decoded values for dims {:?}",
                rec.name,
                data.len(),
                rec.dims
            );
            Ok(Entry::F32(Tensor::new(rec.dims.clone(), data)))
        }
        DTYPE_PACKED => Ok(Entry::Packed(PackedTensor {
            dims: rec.dims.clone(),
            bits: rec.bits,
            data: bytes.to_vec(),
        })),
        d => bail!("unknown dtype {d} for `{}`", rec.name),
    }
}

// ---------------------------------------------------------------------------
// writers
// ---------------------------------------------------------------------------

fn entry_payload(e: &Entry) -> (u8, u8, Vec<usize>, Vec<u8>) {
    match e {
        Entry::F32(t) => {
            let mut bytes = Vec::with_capacity(4 * t.len());
            for &v in &t.data {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            (DTYPE_F32, 32, t.dims.clone(), bytes)
        }
        Entry::Packed(p) => (DTYPE_PACKED, p.bits, p.dims.clone(), p.data.clone()),
    }
}

fn check_entry_shape(name: &str, dims: &[usize], dtype: u8, bits: u8) -> Result<()> {
    ensure!(name.len() <= MAX_NAME_LEN, "tensor name too long ({})", name.len());
    ensure!(dims.len() <= MAX_NDIM, "rank {} too high for {name}", dims.len());
    ensure!(
        dims.iter().all(|&d| d > 0) || dims.is_empty(),
        "zero-sized dim in {name}: {dims:?}"
    );
    if dtype == DTYPE_PACKED {
        ensure!((1..=8).contains(&bits), "bad packed bits {bits} for {name}");
    }
    Ok(())
}

/// Write a file via a `.tmp` sibling + atomic rename: re-exporting over a
/// snapshot that is currently mmap-served must never truncate the live
/// inode (`File::create` in place would — the serving process's next page
/// fault past the new EOF is a SIGBUS). The old file keeps serving until
/// the rename, and its pages stay valid afterwards.
fn replace_file(path: &Path, write: impl FnOnce(&Path) -> Result<()>) -> Result<()> {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    let tmp = std::path::PathBuf::from(os);
    match write(&tmp) {
        Ok(()) => std::fs::rename(&tmp, path)
            .with_context(|| format!("replacing snapshot {path:?}")),
        Err(e) => {
            std::fs::remove_file(&tmp).ok();
            Err(e)
        }
    }
}

/// Write a v2 container. `entries` carry a `group` id per tensor (the
/// producing block index, `-1` for globals) which lands in the record
/// table as the per-window index. The file is written to a `.tmp` sibling
/// and atomically renamed into place (safe against live mmap readers).
/// Returns bytes written.
pub fn write_container(
    path: impl AsRef<Path>,
    header: &Value,
    entries: &[(String, Entry, i32)],
) -> Result<u64> {
    let header_json = json::dump(header);
    ensure!(header_json.len() <= u32::MAX as usize, "snapshot header exceeds u32 framing");
    ensure!(entries.len() <= u32::MAX as usize, "too many snapshot entries");

    // pass 1: payload bytes + fixed-width record sizes (offsets are u64,
    // so the meta block's length is known before offsets are assigned)
    let mut payloads = Vec::with_capacity(entries.len());
    let mut meta_len = 4 + header_json.len() + 4; // header_len + header + n_records
    for (name, e, group) in entries {
        let (dtype, bits, dims, bytes) = entry_payload(e);
        check_entry_shape(name, &dims, dtype, bits)?;
        ensure!(
            (-1..=MAX_GROUP).contains(group),
            "group id {group} for {name} outside [-1, {MAX_GROUP}]"
        );
        // name_len + name + dtype + bits + ndim + dims + group + offset + len + crc
        meta_len += 4 + name.len() + 1 + 1 + 1 + 4 * dims.len() + 4 + 8 + 8 + 4;
        payloads.push((name, dtype, bits, dims, *group, bytes));
    }

    // pass 2: assign 64-byte-aligned absolute offsets after the meta CRC
    let meta_end = 16 + meta_len as u64; // magic + version + meta_len field
    let mut cursor = align_up(meta_end + 4, PAYLOAD_ALIGN);
    let mut offsets = Vec::with_capacity(payloads.len());
    for (_, _, _, _, _, bytes) in &payloads {
        offsets.push(cursor);
        cursor = align_up(cursor + bytes.len() as u64, PAYLOAD_ALIGN);
    }

    // serialize the meta block
    let mut meta = Vec::with_capacity(meta_len);
    meta.extend_from_slice(&(header_json.len() as u32).to_le_bytes());
    meta.extend_from_slice(header_json.as_bytes());
    meta.extend_from_slice(&(payloads.len() as u32).to_le_bytes());
    for ((name, dtype, bits, dims, group, bytes), &offset) in payloads.iter().zip(&offsets) {
        meta.extend_from_slice(&(name.len() as u32).to_le_bytes());
        meta.extend_from_slice(name.as_bytes());
        meta.push(*dtype);
        meta.push(*bits);
        meta.push(dims.len() as u8);
        for &d in dims {
            meta.extend_from_slice(&(d as u32).to_le_bytes());
        }
        meta.extend_from_slice(&group.to_le_bytes());
        meta.extend_from_slice(&offset.to_le_bytes());
        meta.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
        meta.extend_from_slice(&crc32(bytes).to_le_bytes());
    }
    debug_assert_eq!(meta.len(), meta_len);

    // stream out: prefix + meta + meta_crc + aligned payloads
    let mut prefix = Vec::with_capacity(16);
    prefix.extend_from_slice(MAGIC);
    prefix.extend_from_slice(&VERSION.to_le_bytes());
    prefix.extend_from_slice(&(meta_len as u64).to_le_bytes());
    let meta_crc = {
        let mut covered = prefix.clone();
        covered.extend_from_slice(&meta);
        crc32(&covered)
    };

    let mut written = meta_end + 4;
    replace_file(path.as_ref(), |tmp| {
        let file = std::fs::File::create(tmp)
            .with_context(|| format!("writing snapshot {tmp:?}"))?;
        let mut w = std::io::BufWriter::new(file);
        w.write_all(&prefix)?;
        w.write_all(&meta)?;
        w.write_all(&meta_crc.to_le_bytes())?;
        for ((_, _, _, _, _, bytes), &offset) in payloads.iter().zip(&offsets) {
            let pad = offset - written;
            w.write_all(&vec![0u8; pad as usize])?;
            w.write_all(bytes)?;
            written = offset + bytes.len() as u64;
        }
        w.flush()?;
        Ok(())
    })?;
    Ok(written)
}

/// Write a legacy v1 container (whole-payload CRC, u32 framing, no offset
/// table). Kept for compatibility tests and downgrade tooling; new
/// snapshots are written by [`write_container`].
pub fn write_container_v1(
    path: impl AsRef<Path>,
    header: &Value,
    entries: &[(String, Entry)],
) -> Result<u64> {
    let header_json = json::dump(header);
    ensure!(header_json.len() <= u32::MAX as usize, "snapshot header exceeds u32 framing");
    let mut payload = Vec::new();
    payload.extend_from_slice(&(header_json.len() as u32).to_le_bytes());
    payload.extend_from_slice(header_json.as_bytes());
    payload.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for (name, e) in entries {
        write_entry(&mut payload, name, e)?;
    }
    ensure!(
        payload.len() <= u32::MAX as usize,
        "snapshot payload is {} bytes — exceeds the v1 u32 framing limit; \
         export a v2 snapshot instead",
        payload.len()
    );
    let mut raw = Vec::with_capacity(payload.len() + 16);
    raw.extend_from_slice(MAGIC);
    raw.extend_from_slice(&VERSION_V1.to_le_bytes());
    raw.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    raw.extend_from_slice(&payload);
    raw.extend_from_slice(&crc32(&payload).to_le_bytes());
    replace_file(path.as_ref(), |tmp| {
        std::fs::write(tmp, &raw).with_context(|| format!("writing snapshot {tmp:?}"))
    })?;
    Ok(raw.len() as u64)
}

// ---------------------------------------------------------------------------
// readers
// ---------------------------------------------------------------------------

/// Open a container, dispatching on the version tag. Always validates
/// magic, version, framing, the metadata checksum and every record's
/// bounds; [`OpenMode::Eager`] additionally implies payload CRCs get
/// verified as [`read_container`] materializes them.
pub fn open_container(path: impl AsRef<Path>, mode: OpenMode) -> Result<LazyContainer> {
    let path = path.as_ref();
    // sniff the 16-byte prefix to learn the version without committing to
    // a full read
    let prefix = {
        let f = mmap::ReadAtFile::open(path)
            .with_context(|| format!("reading snapshot {path:?}"))?;
        ensure!(f.len() >= 16, "not a CBQS snapshot ({} bytes — too short)", f.len());
        f.read_at(0, 16)?
    };
    ensure!(&prefix[..4] == MAGIC, "not a CBQS snapshot (magic {:?})", &prefix[..4]);
    let version = u32::from_le_bytes([prefix[4], prefix[5], prefix[6], prefix[7]]);
    match version {
        VERSION_V1 => open_v1(path),
        VERSION => open_v2(path, mode),
        v => bail!("unsupported CBQS version {v} (this build reads 1 and {VERSION})"),
    }
}

fn index_records(records: &[RecordMeta]) -> Result<BTreeMap<String, usize>> {
    let mut by_name = BTreeMap::new();
    for (i, r) in records.iter().enumerate() {
        ensure!(by_name.insert(r.name.clone(), i).is_none(), "duplicate entry `{}`", r.name);
    }
    Ok(by_name)
}

/// v1: the whole-payload CRC forces a full read; entries are parsed with
/// absolute payload offsets recorded so the lazy machinery works uniformly
/// (over an in-memory source — v1 has no larger-than-RAM story).
fn open_v1(path: &Path) -> Result<LazyContainer> {
    let raw = std::fs::read(path).with_context(|| format!("reading snapshot {path:?}"))?;
    let file_bytes = raw.len() as u64;
    let mut r = ByteReader::new(&raw);
    let magic = r.take(4)?;
    ensure!(magic == MAGIC, "not a CBQS snapshot (magic {:?})", magic);
    let version = r.u32()?;
    ensure!(version == VERSION_V1, "unsupported CBQS version {version}");
    let payload_len = r.u32()? as usize;
    ensure!(
        r.remaining() == payload_len + 4,
        "corrupt framing: payload {payload_len}B + crc vs {}B remaining",
        r.remaining()
    );
    let payload_base = r.pos() as u64;
    let payload = r.take(payload_len)?;
    let stored_crc = r.u32()?;
    let actual = crc32(payload);
    ensure!(
        stored_crc == actual,
        "checksum mismatch: stored {stored_crc:#010x}, computed {actual:#010x} — snapshot corrupt"
    );

    let mut p = ByteReader::new(payload);
    let header_len = p.u32()? as usize;
    let header_raw = std::str::from_utf8(p.take(header_len)?)?;
    let header = json::parse(header_raw).context("parsing snapshot header")?;
    let n = p.u32()? as usize;
    let mut records = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        records.push(parse_record_v1(&mut p, payload_base)?);
    }
    ensure!(p.is_done(), "{} trailing bytes after last entry", p.remaining());
    let by_name = index_records(&records)?;
    Ok(LazyContainer {
        version: VERSION_V1,
        header,
        records,
        source: Source::Memory(Arc::new(raw)),
        file_bytes,
        by_name,
    })
}

/// Parse one v1 entry *header*, skipping over (but locating and
/// checksumming) its payload. `base` is the payload region's absolute file
/// offset, so recorded offsets are file-absolute like v2's.
fn parse_record_v1(r: &mut ByteReader, base: u64) -> Result<RecordMeta> {
    let name_len = r.u32()? as usize;
    ensure!(name_len <= MAX_NAME_LEN, "tensor name length {name_len} exceeds cap");
    let name = String::from_utf8(r.take(name_len)?.to_vec())?;
    let dtype = r.u8()?;
    let ndim = r.u8()? as usize;
    ensure!(ndim <= MAX_NDIM, "rank {ndim} exceeds cap for {name}");
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        dims.push(r.u32()? as usize);
    }
    ensure!(dims.iter().all(|&d| d > 0), "zero-sized dim in {name}: {dims:?}");
    let count = checked_count(&dims)?.max(1);
    let (bits, payload_len) = match dtype {
        DTYPE_F32 | DTYPE_I32 => {
            let len = count
                .checked_mul(4)
                .ok_or_else(|| anyhow!("payload size overflow for {name}: {dims:?}"))?;
            (32u8, len)
        }
        DTYPE_PACKED => {
            let bits = r.u8()?;
            ensure!((1..=8).contains(&bits), "bad packed bits {bits} for {name}");
            let byte_len = r.u32()? as usize;
            let want = count
                .checked_mul(bits as usize)
                .map(|b| b.div_ceil(8))
                .ok_or_else(|| anyhow!("packed size overflow for {name}: {dims:?}"))?;
            ensure!(byte_len == want, "packed payload of {name}: {byte_len} bytes, want {want}");
            (bits, byte_len)
        }
        d => bail!("unknown dtype {d} for {name}"),
    };
    let offset = base + r.pos() as u64;
    let payload = r.take(payload_len)?;
    Ok(RecordMeta {
        name,
        dtype,
        bits,
        dims,
        group: -1, // v1 carries no group field; snapshot::lazy derives it from the name
        offset,
        len: payload_len as u64,
        crc: crc32(payload),
    })
}

fn checked_count(dims: &[usize]) -> Result<usize> {
    let mut count = 1usize;
    for &d in dims {
        count = count
            .checked_mul(d)
            .ok_or_else(|| anyhow!("dimension product overflow: {dims:?}"))?;
    }
    Ok(count)
}

fn open_v2(path: &Path, mode: OpenMode) -> Result<LazyContainer> {
    // pick the byte source first; the meta block is then read through it
    let source = match mode {
        OpenMode::Eager => Source::Memory(Arc::new(
            std::fs::read(path).with_context(|| format!("reading snapshot {path:?}"))?,
        )),
        OpenMode::Lazy => match mmap::Mmap::open(path) {
            Ok(m) => Source::Mapped(Arc::new(m)),
            Err(_) => Source::File(
                mmap::ReadAtFile::open(path)
                    .with_context(|| format!("reading snapshot {path:?}"))?,
            ),
        },
    };
    let file_bytes = source.len();
    ensure!(file_bytes >= 20, "corrupt framing: {file_bytes}B is too short for a v2 frame");
    let prefix = source.bytes(0, 16)?;
    ensure!(&prefix[..4] == MAGIC, "not a CBQS snapshot (magic {:?})", &prefix[..4]);
    let version = u32::from_le_bytes([prefix[4], prefix[5], prefix[6], prefix[7]]);
    ensure!(version == VERSION, "unsupported CBQS version {version} (expected {VERSION})");
    let meta_len = u64::from_le_bytes([
        prefix[8], prefix[9], prefix[10], prefix[11], prefix[12], prefix[13], prefix[14],
        prefix[15],
    ]);
    let meta_end = 16u64
        .checked_add(meta_len)
        .filter(|v| v.checked_add(4).is_some())
        .ok_or_else(|| anyhow!("corrupt framing: meta length {meta_len} overflows"))?;
    ensure!(
        meta_end + 4 <= file_bytes,
        "corrupt framing: meta block {meta_len}B + crc exceeds file length {file_bytes}"
    );
    drop(prefix);

    // metadata checksum covers prefix + meta block
    let covered = source.bytes(0, meta_end)?;
    let stored_crc = {
        let b = source.bytes(meta_end, 4)?;
        u32::from_le_bytes([b[0], b[1], b[2], b[3]])
    };
    let actual = crc32(&covered);
    ensure!(
        stored_crc == actual,
        "checksum mismatch in metadata: stored {stored_crc:#010x}, computed {actual:#010x} — \
         snapshot corrupt"
    );

    let mut p = ByteReader::new(&covered[16..]);
    let header_len = p.u32()? as usize;
    let header_raw = std::str::from_utf8(p.take(header_len)?)?;
    let header = json::parse(header_raw).context("parsing snapshot header")?;
    let n = p.u32()? as usize;
    let mut records = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let rec = parse_record_v2(&mut p)?;
        ensure!(
            rec.offset % PAYLOAD_ALIGN == 0,
            "record `{}` payload offset {} is not {PAYLOAD_ALIGN}-byte aligned",
            rec.name,
            rec.offset
        );
        ensure!(
            rec.offset >= meta_end + 4
                && rec.offset.checked_add(rec.len).map(|e| e <= file_bytes).unwrap_or(false),
            "truncated container: record `{}` payload {}+{} exceeds file length {file_bytes}",
            rec.name,
            rec.offset,
            rec.len
        );
        records.push(rec);
    }
    ensure!(p.is_done(), "{} trailing bytes after the record table", p.remaining());
    drop(covered);
    // exact framing (the v1 invariant carried forward): the file ends at
    // the last payload byte, so trailing garbage — a concatenated or
    // partially overwritten container — is rejected, not silently carried
    let expected_end = records
        .iter()
        .map(|r| r.offset + r.len)
        .max()
        .unwrap_or(meta_end + 4);
    ensure!(
        expected_end == file_bytes,
        "corrupt framing: {} trailing bytes after the last payload",
        file_bytes.saturating_sub(expected_end)
    );
    let by_name = index_records(&records)?;
    Ok(LazyContainer { version: VERSION, header, records, source, file_bytes, by_name })
}

fn parse_record_v2(r: &mut ByteReader) -> Result<RecordMeta> {
    let name_len = r.u32()? as usize;
    ensure!(name_len <= MAX_NAME_LEN, "tensor name length {name_len} exceeds cap");
    let name = String::from_utf8(r.take(name_len)?.to_vec())?;
    let dtype = r.u8()?;
    let bits = r.u8()?;
    let ndim = r.u8()? as usize;
    ensure!(ndim <= MAX_NDIM, "rank {ndim} exceeds cap for {name}");
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        dims.push(r.u32()? as usize);
    }
    ensure!(dims.iter().all(|&d| d > 0), "zero-sized dim in {name}: {dims:?}");
    let count = checked_count(&dims)?.max(1);
    let group = r.i32()?;
    ensure!((-1..=MAX_GROUP).contains(&group), "group id {group} for {name} out of range");
    let offset = r.u64()?;
    let len = r.u64()?;
    let crc = r.u32()?;
    let want = match dtype {
        DTYPE_F32 => count
            .checked_mul(4)
            .ok_or_else(|| anyhow!("payload size overflow for {name}: {dims:?}"))?
            as u64,
        DTYPE_PACKED => {
            ensure!((1..=8).contains(&bits), "bad packed bits {bits} for {name}");
            count
                .checked_mul(bits as usize)
                .map(|b| b.div_ceil(8))
                .ok_or_else(|| anyhow!("packed size overflow for {name}: {dims:?}"))?
                as u64
        }
        d => bail!("unknown dtype {d} for {name}"),
    };
    ensure!(len == want, "payload of {name}: {len} bytes, want {want}");
    if dtype == DTYPE_F32 {
        ensure!(bits == 32, "f32 record {name} claims {bits} storage bits");
    }
    Ok(RecordMeta { name, dtype, bits, dims, group, offset, len, crc })
}

/// Read and fully validate a container of either version: magic, version,
/// framing, metadata checksum, per-entry hardening (duplicates, truncation,
/// overflow) and every payload CRC. This is the eager path [`crate::snapshot::load`]
/// uses — a v1 file and its v2 re-export decode to identical entries.
pub fn read_container(path: impl AsRef<Path>) -> Result<(Value, BTreeMap<String, Entry>)> {
    let c = open_container(path, OpenMode::Eager)?;
    let mut entries = BTreeMap::new();
    for rec in &c.records {
        let e = c.materialize(rec)?;
        entries.insert(rec.name.clone(), e);
    }
    Ok((c.header, entries))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::io::PackedTensor;
    use crate::tensor::Tensor;

    fn sample() -> (Value, Vec<(String, Entry, i32)>) {
        let header = Value::obj(vec![("format", Value::str("CBQS")), ("v", Value::num(2.0))]);
        let entries = vec![
            (
                "w".to_string(),
                Entry::F32(Tensor::new(vec![2, 2], vec![1., 2., 3., 4.])),
                -1,
            ),
            (
                "q".to_string(),
                Entry::Packed(PackedTensor::pack(&[-8, 7, 0, 1, 2, -1], vec![6], 4).unwrap()),
                0,
            ),
        ];
        (header, entries)
    }

    fn v1_entries(e: &[(String, Entry, i32)]) -> Vec<(String, Entry)> {
        e.iter().map(|(n, e, _)| (n.clone(), e.clone())).collect()
    }

    #[test]
    fn crc32_known_vector() {
        // standard test vector: CRC-32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn container_roundtrip_v2() {
        let (header, entries) = sample();
        let p = std::env::temp_dir().join("cbqs_fmt_roundtrip.bin");
        write_container(&p, &header, &entries).unwrap();
        let (h, m) = read_container(&p).unwrap();
        assert_eq!(h, header);
        assert_eq!(m.len(), 2);
        assert_eq!(m["w"], entries[0].1);
        assert_eq!(m["q"], entries[1].1);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn v1_and_v2_decode_identically() {
        let (header, entries) = sample();
        let p1 = std::env::temp_dir().join("cbqs_fmt_v1.bin");
        let p2 = std::env::temp_dir().join("cbqs_fmt_v2.bin");
        write_container_v1(&p1, &header, &v1_entries(&entries)).unwrap();
        write_container(&p2, &header, &entries).unwrap();
        let (h1, m1) = read_container(&p1).unwrap();
        let (h2, m2) = read_container(&p2).unwrap();
        assert_eq!(h1, h2);
        assert_eq!(m1, m2, "v1 and v2 frames must decode to identical entries");
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();
    }

    #[test]
    fn v2_offsets_are_aligned_and_grouped() {
        let (header, entries) = sample();
        let p = std::env::temp_dir().join("cbqs_fmt_align.bin");
        let written = write_container(&p, &header, &entries).unwrap();
        assert_eq!(written, std::fs::metadata(&p).unwrap().len());
        let c = open_container(&p, OpenMode::Eager).unwrap();
        assert_eq!(c.version, VERSION);
        assert_eq!(c.records.len(), 2);
        for r in &c.records {
            assert_eq!(r.offset % PAYLOAD_ALIGN, 0, "{}: offset {}", r.name, r.offset);
        }
        assert_eq!(c.record("w").unwrap().group, -1);
        assert_eq!(c.record("q").unwrap().group, 0);
        assert_eq!(c.record("w").unwrap().unpacked_bytes(), 16);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn detects_bit_flip_in_covered_regions_v2() {
        // v2 CRCs cover the prefix+meta block and every payload; alignment
        // padding is structurally dead (offsets/lengths pin the live
        // ranges), so flips are injected into covered regions only.
        let (header, entries) = sample();
        let p = std::env::temp_dir().join("cbqs_fmt_bitflip.bin");
        write_container(&p, &header, &entries).unwrap();
        let c = open_container(&p, OpenMode::Eager).unwrap();
        let meta_end = {
            // prefix + meta + crc: everything before the first payload that
            // the meta checksum covers
            let b = std::fs::read(&p).unwrap();
            16 + u64::from_le_bytes(b[8..16].try_into().unwrap()) as usize + 4
        };
        let mut covered: Vec<usize> = (0..meta_end).collect();
        for r in &c.records {
            covered.extend((r.offset as usize)..(r.offset + r.len) as usize);
        }
        let clean = std::fs::read(&p).unwrap();
        for pos in covered {
            let mut bad = clean.clone();
            bad[pos] ^= 0x10;
            std::fs::write(&p, &bad).unwrap();
            assert!(read_container(&p).is_err(), "bit flip at {pos} not detected");
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn detects_bit_flip_anywhere_v1() {
        let (header, entries) = sample();
        let p = std::env::temp_dir().join("cbqs_fmt_bitflip_v1.bin");
        write_container_v1(&p, &header, &v1_entries(&entries)).unwrap();
        let clean = std::fs::read(&p).unwrap();
        // flip one bit in every payload byte position in turn
        for pos in 12..clean.len() - 4 {
            let mut bad = clean.clone();
            bad[pos] ^= 0x10;
            std::fs::write(&p, &bad).unwrap();
            assert!(read_container(&p).is_err(), "bit flip at {pos} not detected");
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn lazy_open_validates_meta_and_defers_payloads() {
        let (header, entries) = sample();
        let p = std::env::temp_dir().join("cbqs_fmt_lazy.bin");
        write_container(&p, &header, &entries).unwrap();

        // corrupt one payload byte: lazy open succeeds (meta is intact),
        // materializing the damaged record fails, the other still loads
        let c0 = open_container(&p, OpenMode::Eager).unwrap();
        let w_off = c0.record("w").unwrap().offset as usize;
        let mut bad = std::fs::read(&p).unwrap();
        bad[w_off] ^= 0x01;
        std::fs::write(&p, &bad).unwrap();

        let c = open_container(&p, OpenMode::Lazy).unwrap();
        let e = c.materialize(c.record("w").unwrap()).unwrap_err();
        assert!(format!("{e:#}").contains("checksum"), "{e:#}");
        assert!(c.materialize(c.record("q").unwrap()).is_ok());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_version_and_magic() {
        let (header, entries) = sample();
        let p = std::env::temp_dir().join("cbqs_fmt_ver.bin");
        write_container(&p, &header, &entries).unwrap();
        let clean = std::fs::read(&p).unwrap();

        let mut bad_magic = clean.clone();
        bad_magic[0] = b'X';
        std::fs::write(&p, &bad_magic).unwrap();
        let e = read_container(&p).unwrap_err();
        assert!(format!("{e:#}").contains("magic"), "{e:#}");

        let mut bad_ver = clean.clone();
        bad_ver[4] = 99;
        std::fs::write(&p, &bad_ver).unwrap();
        let e = read_container(&p).unwrap_err();
        assert!(format!("{e:#}").contains("version"), "{e:#}");

        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_truncation_v1_and_v2() {
        let (header, entries) = sample();
        for v1 in [false, true] {
            let p = std::env::temp_dir().join(format!("cbqs_fmt_trunc_{v1}.bin"));
            if v1 {
                write_container_v1(&p, &header, &v1_entries(&entries)).unwrap();
            } else {
                write_container(&p, &header, &entries).unwrap();
            }
            let clean = std::fs::read(&p).unwrap();
            for cut in [1usize, 5, clean.len() / 2] {
                let bad = clean[..clean.len() - cut].to_vec();
                std::fs::write(&p, &bad).unwrap();
                assert!(
                    read_container(&p).is_err(),
                    "truncation by {cut} not detected (v1={v1})"
                );
            }
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn rejects_trailing_garbage_v2() {
        let (header, entries) = sample();
        let p = std::env::temp_dir().join("cbqs_fmt_trailing.bin");
        write_container(&p, &header, &entries).unwrap();
        let mut raw = std::fs::read(&p).unwrap();
        raw.extend_from_slice(&[0xAB; 17]);
        std::fs::write(&p, &raw).unwrap();
        let e = open_container(&p, OpenMode::Lazy).unwrap_err();
        assert!(format!("{e:#}").contains("trailing"), "{e:#}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_duplicate_names() {
        let header = Value::obj(vec![("format", Value::str("CBQS"))]);
        let t = Entry::F32(Tensor::scalar(1.0));
        let entries =
            vec![("dup".to_string(), t.clone(), -1), ("dup".to_string(), t, -1)];
        let p = std::env::temp_dir().join("cbqs_fmt_dup.bin");
        write_container(&p, &header, &entries).unwrap();
        let e = read_container(&p).unwrap_err();
        assert!(format!("{e:#}").contains("duplicate"), "{e:#}");
        std::fs::remove_file(p).ok();
    }
}
