//! Minimal JSON parser — substrate for manifest.json / corpus_ref.json.
//! (The build environment vendors only the `xla` crate's dependency
//! closure, so serde_json is hand-rolled; the manifest grammar is plain
//! JSON with no escapes beyond \" \\ \/ \n \t \r \u.)

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value (the usual six-variant sum type).
///
/// Numbers are uniformly `f64` — the manifest grammar never needs exact
/// 64-bit integers, and [`dump`] prints integral values without an
/// exponent so round trips stay readable.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string (escapes already resolved).
    Str(String),
    /// An ordered array.
    Arr(Vec<Value>),
    /// An object; keys are sorted (BTreeMap) so [`dump`] is deterministic.
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Member `key` of an object, as an error if absent or not an object.
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key `{key}`")),
            _ => bail!("not an object (looking for `{key}`)"),
        }
    }

    /// Member `key` of an object, `None` if absent (or not an object).
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The object's key → value map, or an error for non-objects.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// The array's items, or an error for non-arrays.
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    /// The string's contents, or an error for non-strings.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    /// The number as `f64`, or an error for non-numbers.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    /// The number truncated to `usize` (manifest counts and sizes).
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }
}

/// Convenience constructors for building documents to [`dump`].
impl Value {
    /// A number value.
    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    /// A string value.
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    /// An array value.
    pub fn arr(items: Vec<Value>) -> Value {
        Value::Arr(items)
    }

    /// An object value from `(key, value)` pairs (later duplicates win).
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

/// Serialize a [`Value`] to compact JSON (the inverse of [`parse`]): the
/// snapshot header and the CLI `--json` outputs go through this.
pub fn dump(v: &Value) -> String {
    let mut out = String::new();
    dump_into(v, &mut out);
    out
}

fn dump_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.is_finite() {
                if *n == n.trunc() && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            } else {
                // JSON has no inf/nan; null is the least-wrong encoding
                out.push_str("null");
            }
        }
        Value::Str(s) => dump_str(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                dump_into(item, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, item)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                dump_str(k, out);
                out.push(':');
                dump_into(item, out);
            }
            out.push('}');
        }
    }
}

fn dump_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document (trailing garbage is an error).
///
/// Supports the full value grammar the repo's documents use: nested
/// objects/arrays, numbers with exponents, and the `\" \\ \/ \n \t \r \b
/// \f \uXXXX` string escapes. Surrogate pairs are not combined (`\u`
/// outside the BMP yields U+FFFD) — nothing in the manifest needs them.
pub fn parse(s: &str) -> Result<Value> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        self.skip_ws();
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            let k = self.string()?;
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected , or }} got `{}` at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                c => bail!("expected , or ] got `{}` at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| anyhow!("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| anyhow!("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => out.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        self.skip_ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number `{s}`: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let v = parse(
            r#"{"version": 1, "configs": {"t": {"d_model": 64, "name": "t"}},
               "arr": [1, 2.5, -3e2], "flag": true, "none": null,
               "s": "a\"b\nc"}"#,
        )
        .unwrap();
        assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            v.get("configs").unwrap().get("t").unwrap().get("d_model").unwrap().as_usize().unwrap(),
            64
        );
        let arr = v.get("arr").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].as_f64().unwrap(), -300.0);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\"b\nc");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}x").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("{}").unwrap(), Value::Obj(BTreeMap::new()));
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn dump_parse_roundtrip() {
        let v = Value::obj(vec![
            ("name", Value::str("cbq \"snap\"\n")),
            ("bits", Value::num(4.0)),
            ("ratio", Value::num(0.1625)),
            ("flags", Value::arr(vec![Value::Bool(true), Value::Null])),
            ("nested", Value::obj(vec![("k", Value::num(-3.0))])),
        ]);
        let s = dump(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn dump_integers_without_exponent() {
        assert_eq!(dump(&Value::num(96.0)), "96");
        assert_eq!(dump(&Value::num(1.5)), "1.5");
    }
}
