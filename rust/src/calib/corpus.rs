//! Synthetic corpus generator — bit-exact mirror of python/compile/data.py.
//!
//! The pretrained models (built in Python) and the calibration/eval sets
//! (generated here at run time) must come from the *same* distribution, so
//! both sides implement the identical xorshift64*-driven generator; parity
//! is asserted against artifacts/corpus_ref.json in the integration tests.

/// Tokens per topic segment (each segment opens with its topic marker).
pub const SEGMENT_LEN: usize = 32;
/// Content-token alphabet size: tokens `0..CONTENT_V` carry the affine /
/// counting / zipf mixture.
pub const CONTENT_V: u64 = 240;
/// First topic-marker token id (`TOPIC_BASE + topic` opens a segment).
pub const TOPIC_BASE: u32 = 240;
/// Number of distinct topics, each with its own affine parameters.
pub const N_TOPICS: u64 = 8;
/// Wiki-style section-header template token.
pub const HEADER_TOK: u32 = 250;
/// Wiki-style separator template token.
pub const SEP_TOK: u32 = 251;

/// Corpus flavour — the C4-like and Wikitext-like streams differ in their
/// mixture weights and template tokens, mirroring the paper's two eval
/// corpora.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Style {
    /// C4-like: no template tokens, noisier mixture.
    C4,
    /// Wikitext-like: periodic header/separator tokens, more deterministic.
    Wiki,
}

impl Style {
    /// Short name used in CLI tables and JSON reports.
    pub fn name(&self) -> &'static str {
        match self {
            Style::C4 => "c4",
            Style::Wiki => "wiki",
        }
    }
}

/// xorshift64* — mirrored in data.py.
#[derive(Clone, Debug)]
pub struct XorShift64Star {
    state: u64,
}

impl XorShift64Star {
    /// Seed the generator (`seed | 1` guards against the all-zero state).
    pub fn new(seed: u64) -> Self {
        Self { state: seed | 1 }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform-ish draw in `0..n` (modulo bias is part of the mirrored
    /// contract — data.py does the same).
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }
}

/// The affine process parameters `(a, b)` of a topic: `a` is forced
/// coprime with `CONTENT_V` so `a*cur + b` permutes the content alphabet.
pub fn topic_params(topic: u64) -> (u64, u64) {
    let mut a = (7 * topic + 11) % CONTENT_V;
    while a % 2 == 0 || a % 3 == 0 || a % 5 == 0 {
        a = (a + 1) % CONTENT_V;
    }
    let b = (13 * topic + 3) % CONTENT_V;
    (a, b)
}

fn zipfish(rng: &mut XorShift64Star) -> u64 {
    let r = rng.next_u64();
    let t1 = r & 0xFF;
    let t2 = (r >> 8) & 0xFF;
    t1.min(t2) % CONTENT_V
}

/// Generate `n_tokens` tokens; deterministic in (style, seed).
pub fn generate(style: Style, seed: u64, n_tokens: usize) -> Vec<u32> {
    let seed = match style {
        Style::C4 => seed,
        Style::Wiki => seed ^ 0x9E37_79B9_7F4A_7C15,
    };
    let mut rng = XorShift64Star::new(seed);
    let mut out = Vec::with_capacity(n_tokens);
    let mut cur: u64 = 0;
    let mut topic: u64 = 0;
    let mut pos_in_seg = SEGMENT_LEN; // force a topic draw at position 0
    while out.len() < n_tokens {
        if pos_in_seg >= SEGMENT_LEN {
            pos_in_seg = 0;
            topic = rng.next_below(N_TOPICS);
            out.push(TOPIC_BASE + topic as u32);
            cur = rng.next_below(CONTENT_V);
            pos_in_seg += 1;
            continue;
        }
        if style == Style::Wiki && pos_in_seg % 8 == 0 {
            out.push(if (pos_in_seg / 8) % 2 == 0 { HEADER_TOK } else { SEP_TOK });
            pos_in_seg += 1;
            continue;
        }
        let (a, b) = topic_params(topic);
        let r = rng.next_below(100);
        let (det_p, cnt_p) = match style {
            Style::C4 => (55, 25),
            Style::Wiki => (70, 20),
        };
        cur = if r < det_p {
            (a * cur + b) % CONTENT_V
        } else if r < det_p + cnt_p {
            (cur + 1) % CONTENT_V
        } else {
            zipfish(&mut rng)
        };
        out.push(cur as u32);
        pos_in_seg += 1;
    }
    out.truncate(n_tokens);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(generate(Style::C4, 7, 256), generate(Style::C4, 7, 256));
    }

    #[test]
    fn styles_and_seeds_differ() {
        assert_ne!(generate(Style::C4, 7, 256), generate(Style::Wiki, 7, 256));
        assert_ne!(generate(Style::C4, 7, 256), generate(Style::C4, 8, 256));
    }

    #[test]
    fn segment_structure() {
        let t = generate(Style::Wiki, 11, 1024);
        for seg in t.chunks(SEGMENT_LEN) {
            assert!(seg[0] >= TOPIC_BASE && seg[0] < TOPIC_BASE + N_TOPICS as u32);
        }
        assert!(t.iter().all(|&x| x < 256));
    }

    #[test]
    fn wiki_has_template_tokens() {
        let t = generate(Style::Wiki, 3, 4096);
        assert!(t.iter().any(|&x| x == HEADER_TOK));
        assert!(t.iter().any(|&x| x == SEP_TOK));
        // c4 style never emits them
        let c = generate(Style::C4, 3, 4096);
        assert!(c.iter().all(|&x| x != HEADER_TOK && x != SEP_TOK));
    }

    #[test]
    fn xorshift_known_sequence_stability() {
        // Guard against accidental edits: fixed seed, fixed prefix.
        let mut r = XorShift64Star::new(42);
        let v: Vec<u64> = (0..4).map(|_| r.next_below(1000)).collect();
        assert_eq!(v, {
            let mut r2 = XorShift64Star::new(42);
            (0..4).map(|_| r2.next_below(1000)).collect::<Vec<_>>()
        });
    }
}
