//! Calibration & evaluation data pipeline.
//!
//! Mirrors the paper's protocol (Sec. 5.1): calibration uses 128 randomly
//! selected segments of the C4-style corpus; evaluation uses held-out
//! streams of both corpora (perplexity) plus synthetic two-choice
//! continuation tasks (the zero-shot accuracy analog — lm-eval scores
//! PIQA/HellaSwag/ARC exactly this way, by comparing continuation NLLs).

pub mod corpus;

use corpus::{Style, XorShift64Star, CONTENT_V, N_TOPICS, SEGMENT_LEN, TOPIC_BASE};

use crate::tensor::TensorI32;

/// Calibration stream seed — distinct from the pretraining stream (the
/// python reference trains with seed 42) so calibration never replays
/// training data.
pub const CALIB_SEED: u64 = 1001;
/// Held-out evaluation stream seed, disjoint from both training and
/// calibration.
pub const EVAL_SEED: u64 = 2002;
/// Seed for the synthetic zero-shot choice/ranking task generators.
pub const TASK_SEED: u64 = 3003;

/// A [B, S+1] token batch: inputs are `[.., :S]`, next-token targets `[.., 1:]`.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Number of rows `B`.
    pub batch: usize,
    /// Model sequence length `S` (rows store `S + 1` tokens).
    pub seq: usize,
    tokens: Vec<u32>,
}

impl Batch {
    /// The `[B, S]` input tokens (each row's first `S` tokens).
    pub fn inputs(&self) -> TensorI32 {
        self.select(0)
    }

    /// The `[B, S]` next-token targets (each row shifted left by one).
    pub fn targets(&self) -> TensorI32 {
        self.select(1)
    }

    fn select(&self, off: usize) -> TensorI32 {
        let mut data = Vec::with_capacity(self.batch * self.seq);
        for b in 0..self.batch {
            let row = &self.tokens[b * (self.seq + 1)..(b + 1) * (self.seq + 1)];
            data.extend(row[off..off + self.seq].iter().map(|&t| t as i32));
        }
        TensorI32::new(vec![self.batch, self.seq], data)
    }

    /// Row `b`'s full `S + 1` token window (inputs plus the final target).
    pub fn row(&self, b: usize) -> &[u32] {
        &self.tokens[b * (self.seq + 1)..(b + 1) * (self.seq + 1)]
    }
}

/// Contiguous batches of (seq+1)-token rows from one corpus stream.
pub fn batches(style: Style, seed: u64, n_batches: usize, batch: usize, seq: usize) -> Vec<Batch> {
    let toks = corpus::generate(style, seed, n_batches * batch * (seq + 1));
    toks.chunks(batch * (seq + 1))
        .take(n_batches)
        .map(|c| Batch { batch, seq, tokens: c.to_vec() })
        .collect()
}

/// Calibration set: `n_sequences` rows of the C4-style corpus, grouped into
/// executable-sized batches.
pub fn calibration(n_sequences: usize, batch: usize, seq: usize) -> Vec<Batch> {
    let n_batches = n_sequences.div_ceil(batch);
    batches(Style::C4, CALIB_SEED, n_batches, batch, seq)
}

/// Held-out evaluation stream for perplexity.
pub fn eval_stream(style: Style, n_batches: usize, batch: usize, seq: usize) -> Vec<Batch> {
    batches(style, EVAL_SEED, n_batches, batch, seq)
}

// ---------------------------------------------------------------------------
// zero-shot choice tasks (Table 1 analog)
// ---------------------------------------------------------------------------

/// One two-choice item: a shared prompt and two candidate continuations,
/// of which `correct` follows the true topic process and the other is a
/// corrupted continuation.
#[derive(Clone, Debug)]
pub struct ChoiceItem {
    /// Shared prompt tokens scored ahead of every candidate.
    pub prompt: Vec<u32>,
    /// Candidate continuations (each `prompt.len() + cand.len() == seq`).
    pub cands: Vec<Vec<u32>>,
    /// Index into `cands` of the true continuation.
    pub correct: usize,
}

/// Task flavours — each stresses a different aspect of the distribution,
/// standing in for the paper's PIQA/HellaSwag/ARC-C/ARC-E spread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// continuation follows the same topic's affine process vs a different
    /// topic's (PIQA-like: easy, local evidence)
    TopicMatch,
    /// continuation continues the counting run vs breaks it
    /// (HellaSwag-like: longer-range consistency)
    CountRun,
    /// corrupted candidate is the true one with a few tokens resampled
    /// (ARC-C-like: harder, fine-grained)
    Perturbed,
    /// candidate shifted by a constant offset (ARC-E-like)
    Shifted,
}

impl TaskKind {
    /// Every task flavour, in reporting order.
    pub const ALL: [TaskKind; 4] =
        [TaskKind::TopicMatch, TaskKind::CountRun, TaskKind::Perturbed, TaskKind::Shifted];

    /// Human-readable task name used in tables and JSON reports.
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::TopicMatch => "TopicMatch",
            TaskKind::CountRun => "CountRun",
            TaskKind::Perturbed => "Perturbed",
            TaskKind::Shifted => "Shifted",
        }
    }
}

fn gen_segment(rng: &mut XorShift64Star, topic: u64, len: usize) -> Vec<u32> {
    // topic-marker + affine/count/zipf mixture, c4 parameters
    let mut out = Vec::with_capacity(len);
    out.push(TOPIC_BASE + topic as u32);
    let mut cur = rng.next_below(CONTENT_V);
    let (a, b) = corpus::topic_params(topic);
    while out.len() < len {
        let r = rng.next_below(100);
        cur = if r < 55 {
            (a * cur + b) % CONTENT_V
        } else if r < 80 {
            (cur + 1) % CONTENT_V
        } else {
            rng.next_below(CONTENT_V)
        };
        out.push(cur as u32);
    }
    out
}

/// Build `n` two-choice items for a task kind. Prompt+continuation lengths
/// always total `seq` tokens so one lm_eval call scores one candidate row.
pub fn choice_task(kind: TaskKind, n: usize, seq: usize) -> Vec<ChoiceItem> {
    let mut rng = XorShift64Star::new(TASK_SEED ^ (kind as u64).wrapping_mul(0x9E37));
    let cont_len = SEGMENT_LEN / 2;
    let prompt_len = seq - cont_len;
    (0..n)
        .map(|_| {
            let topic = rng.next_below(N_TOPICS);
            let full = gen_segment(&mut rng, topic, seq);
            let prompt = full[..prompt_len].to_vec();
            let true_cont = full[prompt_len..].to_vec();
            let wrong = match kind {
                TaskKind::TopicMatch => {
                    let other = (topic + 1 + rng.next_below(N_TOPICS - 1)) % N_TOPICS;
                    let alt = gen_segment(&mut rng, other, seq);
                    alt[prompt_len..].to_vec()
                }
                TaskKind::CountRun => {
                    // break local structure by reversing the continuation
                    let mut w = true_cont.clone();
                    w.reverse();
                    w
                }
                TaskKind::Perturbed => {
                    let mut w = true_cont.clone();
                    for _ in 0..3 {
                        let i = rng.next_below(w.len() as u64) as usize;
                        w[i] = rng.next_below(CONTENT_V) as u32;
                    }
                    w
                }
                TaskKind::Shifted => true_cont
                    .iter()
                    .map(|&t| ((t as u64 + 17) % CONTENT_V) as u32)
                    .collect(),
            };
            let correct = (rng.next_below(2)) as usize;
            let cands = if correct == 0 {
                vec![true_cont, wrong]
            } else {
                vec![wrong, true_cont]
            };
            ChoiceItem { prompt, cands, correct }
        })
        .collect()
}

/// Ranking task (Mutual analog): one true continuation ranked against
/// `n_cands-1` distractors; scored by MRR / R@1 / R@2.
pub fn ranking_task(n: usize, n_cands: usize, seq: usize) -> Vec<ChoiceItem> {
    let mut rng = XorShift64Star::new(TASK_SEED ^ 0xABCD);
    let cont_len = SEGMENT_LEN / 2;
    let prompt_len = seq - cont_len;
    (0..n)
        .map(|_| {
            let topic = rng.next_below(N_TOPICS);
            let full = gen_segment(&mut rng, topic, seq);
            let prompt = full[..prompt_len].to_vec();
            let true_cont = full[prompt_len..].to_vec();
            let correct = rng.next_below(n_cands as u64) as usize;
            let mut cands = Vec::with_capacity(n_cands);
            for i in 0..n_cands {
                if i == correct {
                    cands.push(true_cont.clone());
                } else {
                    let other = (topic + 1 + rng.next_below(N_TOPICS - 1)) % N_TOPICS;
                    let alt = gen_segment(&mut rng, other, seq);
                    cands.push(alt[prompt_len..].to_vec());
                }
            }
            ChoiceItem { prompt, cands, correct }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_shift() {
        let bs = batches(Style::C4, 1, 2, 4, 16);
        assert_eq!(bs.len(), 2);
        let x = bs[0].inputs();
        let y = bs[0].targets();
        assert_eq!(x.dims, vec![4, 16]);
        // target row is input row shifted by one
        assert_eq!(x.data[1], y.data[0]);
    }

    #[test]
    fn calibration_row_count() {
        let c = calibration(10, 4, 8);
        assert_eq!(c.len(), 3); // ceil(10/4)
    }

    #[test]
    fn choice_items_well_formed() {
        for kind in TaskKind::ALL {
            let items = choice_task(kind, 16, 96);
            assert_eq!(items.len(), 16);
            for it in &items {
                assert_eq!(it.cands.len(), 2);
                assert!(it.correct < 2);
                assert_eq!(it.prompt.len() + it.cands[0].len(), 96);
                assert_ne!(it.cands[0], it.cands[1]);
            }
        }
    }

    #[test]
    fn ranking_items_well_formed() {
        let items = ranking_task(8, 4, 96);
        for it in &items {
            assert_eq!(it.cands.len(), 4);
            assert!(it.correct < 4);
        }
    }

    #[test]
    fn tasks_deterministic() {
        let a = choice_task(TaskKind::TopicMatch, 4, 96);
        let b = choice_task(TaskKind::TopicMatch, 4, 96);
        assert_eq!(a[0].prompt, b[0].prompt);
        assert_eq!(a[0].correct, b[0].correct);
    }
}
