//! # CBQ: Cross-Block Quantization for Large Language Models
//!
//! Production-quality reproduction of CBQ (ICLR 2025) as a three-layer
//! Rust + JAX + Pallas system. This crate is **Layer 3**: the quantization
//! coordinator. All model compute dispatches through an execution
//! [`runtime::Backend`] — either PJRT over AOT-compiled HLO artifacts
//! (lowered once, at build time, from the JAX/Pallas layers in `python/`)
//! or the **native CPU backend**, which interprets the same executable
//! semantics (including the `win_grad_*` STE gradients) directly in Rust so
//! the entire PTQ pipeline — calibration, coarse-to-fine pre-processing,
//! cross-block sliding-window reconstruction with LoRA-Rounding, baselines
//! (RTN, GPTQ, SmoothQuant/OS/percentile/OMSE, dense AdaRound), and
//! evaluation — runs on any machine, Python never on the execution path.
//!
//! ## Quick tour
//! - [`runtime`] — artifacts + manifest, the [`runtime::Backend`] trait
//!   (PJRT + native CPU), and the [`runtime::synth`] artifact generator.
//! - [`coordinator`] — the paper's contribution: CBD sliding windows
//!   (Sec. 3.1), LoRA-Rounding (Sec. 3.2), Adam, schedules.
//! - [`cfp`] — coarse-to-fine outlier pre-processing (Sec. 3.4, Alg. 1).
//! - [`gptq`] — GPTQ baseline on captured calibration activations.
//! - [`quant`] — shared fake-quant math (bit-exact with the L1 kernels).
//! - [`eval`] — perplexity + zero-shot choice tasks.
//! - [`hessian`] — finite-difference dependency analysis (paper Fig. 1).
//! - [`snapshot`] — the `CBQS` store: a quantized model serialized with
//!   true-bit-width packed codes + quant state, round-tripping bit-exactly
//!   (`cbq export` / `cbq load-eval` / `cbq snapshot-info`). The v2
//!   container carries a 64-byte-aligned offset table + per-tensor CRCs
//!   (spec: `docs/FORMAT.md`), so [`snapshot::load_lazy`] can memory-map a
//!   file larger than RAM and materialize it window-by-window.
//! - [`serve`] — snapshot registry + batched serving engine with pinned
//!   window bindings, a request batcher, a bounded admission queue and a
//!   live-arrival priority scheduler (`cbq serve-bench`). Under `--mmap`
//!   the engine pins windows lazily into a bounded LRU
//!   (`--resident-windows` / `CBQ_RESIDENT_MB`) — bitwise-identical
//!   responses at a fraction of the resident footprint.
//! - [`fuzzing`] — seeded, structure-aware adversarial harness (`cbq
//!   fuzz`): mutates real `CBQS` containers and serve traces, and runs
//!   differential oracles across engines and SIMD tiers; failures persist
//!   as minimized fixtures the regression suite replays (`docs/TESTING.md`).
//!
//! The layer map and end-to-end data flow are drawn out in
//! `docs/ARCHITECTURE.md`.
//!
//! ## Quantize once…
//! ```no_run
//! use cbq::prelude::*;
//! use cbq::calib::corpus::Style;
//! // `cbq synth` (or make artifacts) produced this directory
//! let art = Artifacts::load("artifacts")?;
//! let rt = cbq::runtime::create_selected(&art, None)?; // --backend / CBQ_BACKEND / auto
//! let mut pipe = Pipeline::new(&art, rt.as_ref(), art.default_model())?;
//! let (model, summary) = pipe.run(&QuantJob::cbq(BitSpec::w4a4()))?;
//! println!("ppl: {:.2}", pipe.perplexity(&model, Style::C4, 8)?);
//! // …persist the deliverable: packed codes + scales + quant state
//! cbq::snapshot::save("model_w4a4.cbqs", &pipe.cfg, &model)?;
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! ## …serve forever
//! ```no_run
//! use cbq::prelude::*;
//! use cbq::serve::{Batcher, ModelRegistry, ServeEngine};
//! let art = Artifacts::load("artifacts")?;
//! let rt = cbq::runtime::create_selected(&art, None)?;
//! let mut reg = ModelRegistry::new();
//! let snap = reg.load("w4a4", "model_w4a4.cbqs")?;
//! let engine = ServeEngine::new(rt.as_ref(), &art, snap)?;
//! let requests = cbq::serve::batcher::standard_mix(32, 32, 8, 8);
//! let (responses, stats) = Batcher::coalescing(&engine)
//!     .with_queue_cap(256) // bounded admission: overload is rejected, not queued
//!     .with_dispatch(4)    // up to 4 window batches in flight at once
//!     .run(&engine, &requests)?;
//! println!("{:.0} tok/s at {:.0}% occupancy, {} rejected",
//!          stats.tokens_per_s(), stats.occupancy() * 100.0, stats.rejected);
//! # Ok::<(), anyhow::Error>(())
//! ```

// Index-heavy numerical kernels read clearer with explicit loops; several
// executables take wide-but-flat argument lists mirroring the manifest.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]
// Public API documentation is enforced crate-wide with no local opt-outs
// (CI denies rustdoc warnings via the `docs` job).
#![warn(missing_docs)]

pub mod calib;
pub mod cfp;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod fuzzing;
pub mod gptq;
pub mod hessian;
pub mod json;
pub mod linalg;
pub mod model_state;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod snapshot;
pub mod tensor;

/// The handful of types most callers start from (see the crate examples).
pub mod prelude {
    pub use crate::config::{BitSpec, Method, PreprocMethod, QuantJob};
    pub use crate::coordinator::{Pipeline, QuantSummary};
    pub use crate::runtime::{Artifacts, Backend, BackendKind, NativeBackend, PjrtBackend};
    pub use crate::tensor::Tensor;
}
