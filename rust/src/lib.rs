//! # CBQ: Cross-Block Quantization for Large Language Models
//!
//! Production-quality reproduction of CBQ (ICLR 2025) as a three-layer
//! Rust + JAX + Pallas system. This crate is **Layer 3**: the quantization
//! coordinator. It loads AOT-compiled HLO artifacts (lowered once, at build
//! time, from the JAX/Pallas layers in `python/`) and runs the entire PTQ
//! pipeline — calibration, coarse-to-fine pre-processing, cross-block
//! sliding-window reconstruction with LoRA-Rounding, baselines (RTN, GPTQ,
//! SmoothQuant/OS/percentile/OMSE, dense AdaRound), and evaluation — with
//! Python never on the execution path.
//!
//! ## Quick tour
//! - [`runtime`] — PJRT client + manifest-driven executable registry.
//! - [`coordinator`] — the paper's contribution: CBD sliding windows
//!   (Sec. 3.1), LoRA-Rounding (Sec. 3.2), Adam, schedules.
//! - [`cfp`] — coarse-to-fine outlier pre-processing (Sec. 3.4, Alg. 1).
//! - [`gptq`] — GPTQ baseline on captured calibration activations.
//! - [`quant`] — shared fake-quant math (bit-exact with the L1 kernels).
//! - [`eval`] — perplexity + zero-shot choice tasks.
//! - [`hessian`] — finite-difference dependency analysis (paper Fig. 1).
//! - [`snapshot`] — the `CBQS` store: a quantized model serialized with
//!   true-bit-width packed codes + quant state, round-tripping bit-exactly
//!   (`cbq export` / `cbq load-eval`).
//! - [`serve`] — snapshot registry + batched serving engine with pinned
//!   window bindings and a request batcher (`cbq serve-bench`).
//!
//! ## Quantize once…
//! ```no_run
//! use cbq::prelude::*;
//! use cbq::calib::corpus::Style;
//! let art = Artifacts::load("artifacts")?;
//! let rt = Runtime::new(&art)?;
//! let mut pipe = Pipeline::new(&art, &rt, "t")?;
//! let (model, summary) = pipe.run(&QuantJob::cbq(BitSpec::w4a4()))?;
//! println!("ppl: {:.2}", pipe.perplexity(&model, Style::C4, 8)?);
//! // …persist the deliverable: packed codes + scales + quant state
//! cbq::snapshot::save("t_w4a4.cbqs", &pipe.cfg, &model)?;
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! ## …serve forever
//! ```no_run
//! use cbq::prelude::*;
//! use cbq::serve::{Batcher, ModelRegistry, ServeEngine};
//! let art = Artifacts::load("artifacts")?;
//! let rt = Runtime::new(&art)?;
//! let mut reg = ModelRegistry::new();
//! let snap = reg.load("t-w4a4", "t_w4a4.cbqs")?;
//! let mut engine = ServeEngine::new(&rt, &art, snap)?;
//! let requests = cbq::serve::batcher::standard_mix(96, 32, 8, 8);
//! let (responses, stats) = Batcher::coalescing(&engine).run(&mut engine, &requests)?;
//! println!("{:.0} tok/s at {:.0}% occupancy",
//!          stats.tokens_per_s(), stats.occupancy() * 100.0);
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod calib;
pub mod cfp;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod gptq;
pub mod hessian;
pub mod json;
pub mod linalg;
pub mod model_state;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod snapshot;
pub mod tensor;

pub mod prelude {
    pub use crate::config::{BitSpec, Method, PreprocMethod, QuantJob};
    pub use crate::coordinator::{Pipeline, QuantSummary};
    pub use crate::runtime::{Artifacts, Runtime};
    pub use crate::tensor::Tensor;
}
