//! Synthetic artifact generator: `cbq synth` / [`generate`].
//!
//! Produces everything [`Artifacts`](super::Artifacts) expects —
//! `manifest.json` (configs, executable input/output specs following the
//! flatten_spec contract, window list), `weights_{cfg}.bin`, and
//! `corpus_ref.json` — without Python, JAX, or a PJRT plugin, so the full
//! pipeline (`quantize`, `export`, `load-eval`, `serve-bench`, `hessian`)
//! runs end-to-end offline on the native backend.
//!
//! The weights are not random noise: a small host-side FP pretraining loop
//! (plain-Rust forward/backward over `backend::kernels`, Adam) fits the
//! model to the synthetic corpus first, then injects the same
//! function-preserving activation/weight outliers `python/compile/
//! pretrain.inject_outliers` does — so quantization-error *dynamics*
//! (W8 near-lossless, W2 catastrophic, CFP finds outlier channels) hold on
//! the synthetic models too, just with fewer pretraining tokens.
//!
//! The manifest's `file` fields are placeholders: no HLO text is written,
//! so synthetic artifacts execute on the **native backend only**.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::backend::kernels::{self, Attention};
use super::manifest::ModelCfg;
use crate::calib::{self, corpus};
use crate::coordinator::qstate::Adam;
use crate::json::Value;
use crate::quant::LINEARS;
use crate::tensor::{io, Tensor};

/// Mirrors python/compile/pretrain.CORPUS_SEED.
pub const CORPUS_SEED: u64 = 42;

/// Specification of one synthetic model family.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// Config name the manifest will register.
    pub name: String,
    /// Hidden width.
    pub d_model: usize,
    /// Transformer block count.
    pub n_layers: usize,
    /// Attention head count.
    pub n_heads: usize,
    /// MLP hidden width.
    pub d_ffn: usize,
    /// Vocabulary size (must cover the corpus token range).
    pub vocab: usize,
    /// Sequence length the executables are shaped for.
    pub seq: usize,
    /// Batch rows the executables are shaped for.
    pub batch: usize,
    /// Padded LoRA rank.
    pub rank_pad: usize,
    /// Window sizes to export executables for.
    pub windows: Vec<usize>,
    /// Outlier channels to inject into the pretrained weights.
    pub outlier_channels: usize,
    /// Gain of the injected outlier channels.
    pub outlier_gain: f64,
    /// Host pretraining steps.
    pub pretrain_steps: usize,
    /// Host pretraining batch rows.
    pub pretrain_batch: usize,
    /// Host pretraining learning rate.
    pub pretrain_lr: f32,
    /// RNG seed for init + pretraining data order.
    pub seed: u64,
}

impl SynthSpec {
    /// The default `tiny` model: 2 blocks, d=32 — seconds to pretrain on a
    /// laptop, large enough for real quantization-error dynamics.
    pub fn tiny() -> Self {
        Self {
            name: "tiny".into(),
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 64,
            // the corpus emits token ids up to corpus::SEP_TOK (251)
            vocab: 256,
            seq: 32,
            batch: 4,
            rank_pad: 8,
            windows: vec![1, 2],
            outlier_channels: 3,
            outlier_gain: 8.0,
            // schedule validated against a JAX simulation of the same
            // architecture + corpus: eval ppl lands near ~90 (vs 256 for an
            // untrained model), enough for real quantization-error dynamics
            pretrain_steps: 400,
            pretrain_batch: 6,
            pretrain_lr: 4e-3,
            seed: 7,
        }
    }

    /// The [`ModelCfg`] this spec synthesizes.
    pub fn cfg(&self) -> ModelCfg {
        ModelCfg {
            name: self.name.clone(),
            d_model: self.d_model,
            n_layers: self.n_layers,
            n_heads: self.n_heads,
            d_ffn: self.d_ffn,
            vocab: self.vocab,
            seq: self.seq,
            batch: self.batch,
            rank_pad: self.rank_pad,
            head_dim: self.d_model / self.n_heads,
            outlier_channels: self.outlier_channels,
            outlier_gain: self.outlier_gain,
        }
    }
}

/// Deterministic gaussian source (Box-Muller over xorshift64*).
struct Gauss {
    rng: corpus::XorShift64Star,
    spare: Option<f64>,
}

impl Gauss {
    fn new(seed: u64) -> Self {
        Self { rng: corpus::XorShift64Star::new(seed), spare: None }
    }

    fn uniform(&mut self) -> f64 {
        ((self.rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64).max(1e-12)
    }

    fn next(&mut self) -> f32 {
        if let Some(z) = self.spare.take() {
            return z as f32;
        }
        let (u1, u2) = (self.uniform(), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * th.sin());
        (r * th.cos()) as f32
    }

    fn vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.next() * scale).collect()
    }

    /// `count` distinct indices below `n` (partial Fisher-Yates).
    fn choose(&mut self, n: usize, count: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let count = count.min(n);
        for i in 0..count {
            let j = i + self.rng.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(count);
        idx
    }
}

// ---------------------------------------------------------------------------
// FP model: host-side pretraining (forward + backward + Adam)
// ---------------------------------------------------------------------------

struct FpBlock {
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
    /// keyed in LINEARS order
    lin: BTreeMap<&'static str, Vec<f32>>,
}

struct FpParams {
    embed: Vec<f32>,
    final_norm: Vec<f32>,
    head: Vec<f32>,
    blocks: Vec<FpBlock>,
}

impl FpParams {
    fn init(spec: &SynthSpec, g: &mut Gauss) -> Self {
        let cfg = spec.cfg();
        let d = cfg.d_model;
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            let mut lin = BTreeMap::new();
            for l in LINEARS {
                let (fan_in, fan_out) = cfg.linear_shape(l);
                lin.insert(l, g.vec(fan_in * fan_out, 1.0 / (fan_in as f32).sqrt()));
            }
            blocks.push(FpBlock {
                attn_norm: vec![1.0; d],
                mlp_norm: vec![1.0; d],
                lin,
            });
        }
        Self {
            embed: g.vec(cfg.vocab * d, 0.02),
            final_norm: vec![1.0; d],
            head: g.vec(d * cfg.vocab, 1.0 / (d as f32).sqrt()),
            blocks,
        }
    }
}

struct BlockTape {
    h_in: Vec<f32>,
    a: Vec<f32>,
    heads: Vec<kernels::HeadCache>,
    mix: Vec<f32>,
    h_mid: Vec<f32>,
    m: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    act: Vec<f32>,
}

/// One FP block forward with tape (plain linears, no quantization).
fn fp_block_fwd(p: &FpBlock, h: &[f32], rows: usize, cfg: &ModelCfg, attn: &Attention) -> (Vec<f32>, BlockTape) {
    let (d, f) = (cfg.d_model, cfg.d_ffn);
    let a = kernels::rmsnorm(h, d, &p.attn_norm);
    let q = kernels::matmul(&a, rows, d, &p.lin["wq"], d);
    let k = kernels::matmul(&a, rows, d, &p.lin["wk"], d);
    let v = kernels::matmul(&a, rows, d, &p.lin["wv"], d);
    let (mix, heads) = attn.forward(&q, &k, &v, true);
    let wo_y = kernels::matmul(&mix, rows, d, &p.lin["wo"], d);
    let h_mid: Vec<f32> = h.iter().zip(&wo_y).map(|(&x, &y)| x + y).collect();
    let m = kernels::rmsnorm(&h_mid, d, &p.mlp_norm);
    let gate = kernels::matmul(&m, rows, d, &p.lin["wgate"], f);
    let up = kernels::matmul(&m, rows, d, &p.lin["wup"], f);
    let act: Vec<f32> = gate.iter().zip(&up).map(|(&g, &u)| kernels::silu(g) * u).collect();
    let down = kernels::matmul(&act, rows, f, &p.lin["wdown"], d);
    let h_out: Vec<f32> = h_mid.iter().zip(&down).map(|(&x, &y)| x + y).collect();
    (h_out, BlockTape { h_in: h.to_vec(), a, heads, mix, h_mid, m, gate, up, act })
}

/// Per-block parameter gradients.
#[derive(Default)]
struct BlockGrads {
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
    lin: BTreeMap<&'static str, Vec<f32>>,
}

/// FP block backward: returns (dh_in, grads).
fn fp_block_bwd(
    p: &FpBlock,
    tape: &BlockTape,
    rows: usize,
    cfg: &ModelCfg,
    attn: &Attention,
    dh_out: &[f32],
) -> (Vec<f32>, BlockGrads) {
    let (d, f) = (cfg.d_model, cfg.d_ffn);
    let mut g = BlockGrads {
        attn_norm: vec![0.0; d],
        mlp_norm: vec![0.0; d],
        lin: BTreeMap::new(),
    };
    // h_out = h_mid + act @ wdown
    g.lin.insert("wdown", kernels::matmul_transa(&tape.act, rows, f, dh_out, d));
    let dact = kernels::matmul_transb(dh_out, rows, d, &p.lin["wdown"], f);
    // act = silu(gate) * up
    let mut dgate = vec![0.0f32; rows * f];
    let mut dup = vec![0.0f32; rows * f];
    for i in 0..rows * f {
        dgate[i] = dact[i] * tape.up[i] * kernels::silu_d(tape.gate[i]);
        dup[i] = dact[i] * kernels::silu(tape.gate[i]);
    }
    g.lin.insert("wgate", kernels::matmul_transa(&tape.m, rows, d, &dgate, f));
    g.lin.insert("wup", kernels::matmul_transa(&tape.m, rows, d, &dup, f));
    let dm1 = kernels::matmul_transb(&dgate, rows, f, &p.lin["wgate"], d);
    let dm2 = kernels::matmul_transb(&dup, rows, f, &p.lin["wup"], d);
    let dm: Vec<f32> = dm1.iter().zip(&dm2).map(|(&x, &y)| x + y).collect();
    let dmid_norm =
        kernels::rmsnorm_bwd(&tape.h_mid, d, &p.mlp_norm, &dm, Some(&mut g.mlp_norm));
    let dh_mid: Vec<f32> = dh_out.iter().zip(&dmid_norm).map(|(&x, &y)| x + y).collect();
    // h_mid = h_in + mix @ wo
    g.lin.insert("wo", kernels::matmul_transa(&tape.mix, rows, d, &dh_mid, d));
    let dmix = kernels::matmul_transb(&dh_mid, rows, d, &p.lin["wo"], d);
    let (dq, dk, dv) = attn.backward(&tape.heads, &dmix);
    g.lin.insert("wq", kernels::matmul_transa(&tape.a, rows, d, &dq, d));
    g.lin.insert("wk", kernels::matmul_transa(&tape.a, rows, d, &dk, d));
    g.lin.insert("wv", kernels::matmul_transa(&tape.a, rows, d, &dv, d));
    let da1 = kernels::matmul_transb(&dq, rows, d, &p.lin["wq"], d);
    let da2 = kernels::matmul_transb(&dk, rows, d, &p.lin["wk"], d);
    let da3 = kernels::matmul_transb(&dv, rows, d, &p.lin["wv"], d);
    let da: Vec<f32> = da1
        .iter()
        .zip(&da2)
        .zip(&da3)
        .map(|((&x, &y), &z)| x + y + z)
        .collect();
    let din_norm =
        kernels::rmsnorm_bwd(&tape.h_in, d, &p.attn_norm, &da, Some(&mut g.attn_norm));
    let dh_in: Vec<f32> = dh_mid.iter().zip(&din_norm).map(|(&x, &y)| x + y).collect();
    (dh_in, g)
}

/// Optimizer state mirroring the parameter tree.
struct OptState {
    embed: Adam,
    final_norm: Adam,
    head: Adam,
    blocks: Vec<(Adam, Adam, BTreeMap<&'static str, Adam>)>,
}

impl OptState {
    fn new(p: &FpParams) -> Self {
        Self {
            embed: Adam::new(p.embed.len()),
            final_norm: Adam::new(p.final_norm.len()),
            head: Adam::new(p.head.len()),
            blocks: p
                .blocks
                .iter()
                .map(|b| {
                    (
                        Adam::new(b.attn_norm.len()),
                        Adam::new(b.mlp_norm.len()),
                        b.lin.iter().map(|(&l, w)| (l, Adam::new(w.len()))).collect(),
                    )
                })
                .collect(),
        }
    }
}

/// Train the FP model on the synthetic corpus. Returns the mean xent loss
/// over the final 10% of steps.
fn pretrain(spec: &SynthSpec, params: &mut FpParams) -> f32 {
    let cfg = spec.cfg();
    let (b, s, d, v) = (spec.pretrain_batch, cfg.seq, cfg.d_model, cfg.vocab);
    let rows = b * s;
    let attn = Attention::new(b, s, cfg.n_heads, cfg.head_dim);
    let mut opt = OptState::new(params);
    let lr = spec.pretrain_lr;
    // alternate corpus styles, cycling through a fixed stream
    let n_batches = (spec.pretrain_steps / 2 + 1).max(1);
    let c4 = calib::batches(corpus::Style::C4, CORPUS_SEED, n_batches, b, s);
    let wiki = calib::batches(corpus::Style::Wiki, CORPUS_SEED, n_batches, b, s);
    let mut tail_loss = 0.0f64;
    let mut tail_n = 0usize;
    for step in 0..spec.pretrain_steps {
        let batch = if step % 2 == 0 { &c4[(step / 2) % c4.len()] } else { &wiki[(step / 2) % wiki.len()] };
        let x = batch.inputs();
        let y = batch.targets();
        // forward
        let mut h = vec![0.0f32; rows * d];
        for (r, &t) in x.data.iter().enumerate() {
            let row = &params.embed[t as usize * d..(t as usize + 1) * d];
            h[r * d..(r + 1) * d].copy_from_slice(row);
        }
        let mut tapes = Vec::with_capacity(cfg.n_layers);
        for blk in &params.blocks {
            let (h_out, tape) = fp_block_fwd(blk, &h, rows, &cfg, &attn);
            h = h_out;
            tapes.push(tape);
        }
        let hn = kernels::rmsnorm(&h, d, &params.final_norm);
        let logits = kernels::matmul(&hn, rows, d, &params.head, v);
        let logp = kernels::log_softmax_rows(&logits, v);
        let mut loss = 0.0f64;
        for (r, &t) in y.data.iter().enumerate() {
            loss -= logp[r * v + t as usize] as f64;
        }
        loss /= rows as f64;
        if step >= spec.pretrain_steps.saturating_sub(spec.pretrain_steps / 10 + 1) {
            tail_loss += loss;
            tail_n += 1;
        }
        // backward: dlogits = (softmax - onehot) / rows
        let mut dlogits = vec![0.0f32; rows * v];
        let inv_rows = 1.0 / rows as f32;
        for r in 0..rows {
            for j in 0..v {
                dlogits[r * v + j] = logp[r * v + j].exp() * inv_rows;
            }
            dlogits[r * v + y.data[r] as usize] -= inv_rows;
        }
        let dhead = kernels::matmul_transa(&hn, rows, d, &dlogits, v);
        let dhn = kernels::matmul_transb(&dlogits, rows, v, &params.head, d);
        let mut dfinal = vec![0.0f32; d];
        let mut dh = kernels::rmsnorm_bwd(&h, d, &params.final_norm, &dhn, Some(&mut dfinal));
        let mut block_grads: Vec<BlockGrads> = Vec::with_capacity(cfg.n_layers);
        for j in (0..cfg.n_layers).rev() {
            let (dh_in, g) = fp_block_bwd(&params.blocks[j], &tapes[j], rows, &cfg, &attn, &dh);
            dh = dh_in;
            block_grads.push(g);
        }
        block_grads.reverse();
        // embed scatter-add
        let mut dembed = vec![0.0f32; params.embed.len()];
        for (r, &t) in x.data.iter().enumerate() {
            let dst = &mut dembed[t as usize * d..(t as usize + 1) * d];
            for (o, &g) in dst.iter_mut().zip(&dh[r * d..(r + 1) * d]) {
                *o += g;
            }
        }
        // apply
        opt.embed.step(&mut params.embed, &dembed, lr);
        opt.final_norm.step(&mut params.final_norm, &dfinal, lr);
        opt.head.step(&mut params.head, &dhead, lr);
        for (j, g) in block_grads.iter().enumerate() {
            let blk = &mut params.blocks[j];
            let (oa, om, olin) = &mut opt.blocks[j];
            oa.step(&mut blk.attn_norm, &g.attn_norm, lr);
            om.step(&mut blk.mlp_norm, &g.mlp_norm, lr);
            for l in LINEARS {
                olin.get_mut(l).unwrap().step(blk.lin.get_mut(l).unwrap(), &g.lin[l], lr);
            }
        }
    }
    (tail_loss / tail_n.max(1) as f64) as f32
}

/// Function-preserving activation/weight outlier injection (mirrors
/// python/compile/pretrain.inject_outliers).
fn inject_outliers(spec: &SynthSpec, params: &mut FpParams, g: &mut Gauss) {
    let cfg = spec.cfg();
    let (d, f) = (cfg.d_model, cfg.d_ffn);
    let gain = spec.outlier_gain as f32;
    if spec.outlier_channels == 0 || gain == 0.0 {
        return;
    }
    for blk in params.blocks.iter_mut() {
        // activation outliers: attn path (norm up, consumers down)
        for ch in g.choose(d, spec.outlier_channels) {
            blk.attn_norm[ch] *= gain;
            for name in ["wq", "wk", "wv"] {
                let w = blk.lin.get_mut(name).unwrap();
                for x in w[ch * d..(ch + 1) * d].iter_mut() {
                    *x /= gain;
                }
            }
        }
        // activation outliers: mlp path
        for ch in g.choose(d, spec.outlier_channels) {
            blk.mlp_norm[ch] *= gain;
            for name in ["wgate", "wup"] {
                let w = blk.lin.get_mut(name).unwrap();
                for x in w[ch * f..(ch + 1) * f].iter_mut() {
                    *x *= 1.0 / gain;
                }
            }
        }
        // weight outliers: v-channel pairs
        for ch in g.choose(d, (spec.outlier_channels / 2).max(1)) {
            let wv = blk.lin.get_mut("wv").unwrap();
            for r in 0..d {
                wv[r * d + ch] *= gain;
            }
            let wo = blk.lin.get_mut("wo").unwrap();
            for x in wo[ch * d..(ch + 1) * d].iter_mut() {
                *x /= gain;
            }
        }
        // weight outliers: up-channel pairs
        for ch in g.choose(f, (spec.outlier_channels / 2).max(1)) {
            let wup = blk.lin.get_mut("wup").unwrap();
            for r in 0..d {
                wup[r * f + ch] *= gain;
            }
            let wdown = blk.lin.get_mut("wdown").unwrap();
            for x in wdown[ch * d..(ch + 1) * d].iter_mut() {
                *x /= gain;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// manifest spec builders (flatten_spec ordering)
// ---------------------------------------------------------------------------

fn tspec(name: String, shape: &[usize], dtype: &str) -> Value {
    Value::obj(vec![
        ("name", Value::str(name)),
        ("shape", Value::arr(shape.iter().map(|&d| Value::num(d as f64)).collect())),
        ("dtype", Value::str(dtype)),
    ])
}

fn f32spec(name: String, shape: &[usize]) -> Value {
    tspec(name, shape, "float32")
}

/// sorted block-weight entries for window position `j`.
fn block_specs(cfg: &ModelCfg, j: usize, out: &mut Vec<Value>) {
    let d = cfg.d_model;
    out.push(f32spec(format!("blocks.{j}.attn_norm"), &[d]));
    out.push(f32spec(format!("blocks.{j}.mlp_norm"), &[d]));
    // sorted: wdown, wgate, wk, wo, wq, wup, wv
    for l in ["wdown", "wgate", "wk", "wo", "wq", "wup", "wv"] {
        let (fan_in, fan_out) = cfg.linear_shape(l);
        out.push(f32spec(format!("blocks.{j}.{l}"), &[fan_in, fan_out]));
    }
}

/// sorted qblock entries for window position `j`.
fn qblock_specs(cfg: &ModelCfg, j: usize, dense: bool, out: &mut Vec<Value>) {
    for l in ["wdown", "wgate", "wk", "wo", "wq", "wup", "wv"] {
        let (fan_in, fan_out) = cfg.linear_shape(l);
        let p = format!("qblocks.{j}.{l}");
        if !dense {
            out.push(f32spec(format!("{p}.a1"), &[fan_in, cfg.rank_pad]));
            out.push(f32spec(format!("{p}.a2"), &[cfg.rank_pad, fan_out]));
        }
        out.push(f32spec(format!("{p}.a_en"), &[]));
        out.push(f32spec(format!("{p}.alpha"), &[]));
        out.push(f32spec(format!("{p}.qmax_a"), &[]));
        out.push(f32spec(format!("{p}.qmax_w"), &[]));
        out.push(f32spec(format!("{p}.s_w"), &[fan_out]));
        if dense {
            out.push(f32spec(format!("{p}.v"), &[fan_in, fan_out]));
        }
        out.push(f32spec(format!("{p}.v0"), &[fan_in, fan_out]));
        out.push(f32spec(format!("{p}.w_en"), &[]));
    }
}

fn window_inputs(cfg: &ModelCfg, w: usize, dense: bool) -> Vec<Value> {
    let mut inputs = Vec::new();
    for j in 0..w {
        block_specs(cfg, j, &mut inputs);
    }
    for g in ["beta", "gamma_c", "kld_w", "l2_w", "use_lora"] {
        inputs.push(f32spec(format!("globals.{g}"), &[]));
    }
    let hshape = [cfg.batch, cfg.seq, cfg.d_model];
    inputs.push(f32spec("h_in".into(), &hshape));
    for j in 0..w {
        qblock_specs(cfg, j, dense, &mut inputs);
    }
    inputs.push(f32spec("target".into(), &hshape));
    inputs
}

fn exec_entry(file: String, inputs: Vec<Value>, outputs: Vec<Value>) -> Value {
    Value::obj(vec![
        ("file", Value::str(file)),
        ("inputs", Value::arr(inputs)),
        ("outputs", Value::arr(outputs)),
    ])
}

fn executables(cfg: &ModelCfg, windows: &[usize]) -> Vec<(String, Value)> {
    let name = &cfg.name;
    let hshape = [cfg.batch, cfg.seq, cfg.d_model];
    let mut out = Vec::new();
    for &w in windows {
        // win_fwd
        let fwd_outputs = vec![
            f32spec("h_out".into(), &hshape),
            f32spec("kld".into(), &[]),
            f32spec("loss".into(), &[]),
            f32spec("mse".into(), &[]),
        ];
        out.push((
            format!("win_fwd_w{w}_{name}"),
            exec_entry(format!("win_fwd_w{w}_{name}.hlo.txt"), window_inputs(cfg, w, false), fwd_outputs),
        ));
        // win_grad
        out.push((
            format!("win_grad_w{w}_{name}"),
            exec_entry(
                format!("win_grad_w{w}_{name}.hlo.txt"),
                window_inputs(cfg, w, false),
                grad_outputs(cfg, w, false),
            ),
        ));
    }
    // dense-AdaRound grad variant at w=2 (memory/speed baseline)
    if windows.contains(&2) {
        out.push((
            format!("win_grad_dense_w2_{name}"),
            exec_entry(
                format!("win_grad_dense_w2_{name}.hlo.txt"),
                window_inputs(cfg, 2, true),
                grad_outputs(cfg, 2, true),
            ),
        ));
    }
    // capture
    let mut cap_outputs = Vec::new();
    let rows = cfg.batch * cfg.seq;
    for l in ["wdown", "wgate", "wk", "wo", "wq", "wup", "wv"] {
        let (fan_in, _) = cfg.linear_shape(l);
        cap_outputs.push(f32spec(format!("captures.{l}"), &[rows, fan_in]));
    }
    cap_outputs.push(f32spec("h_out".into(), &hshape));
    out.push((
        format!("capture_{name}"),
        exec_entry(format!("capture_{name}.hlo.txt"), window_inputs(cfg, 1, false), cap_outputs),
    ));
    // lm_eval
    let lm_inputs = vec![
        f32spec("final_norm".into(), &[cfg.d_model]),
        f32spec("h".into(), &hshape),
        f32spec("head".into(), &[cfg.d_model, cfg.vocab]),
        f32spec("mask".into(), &[cfg.batch, cfg.seq]),
        tspec("targets".into(), &[cfg.batch, cfg.seq], "int32"),
    ];
    let lm_outputs = vec![
        f32spec("count".into(), &[cfg.batch]),
        f32spec("nll".into(), &[cfg.batch]),
    ];
    out.push((
        format!("lm_eval_{name}"),
        exec_entry(format!("lm_eval_{name}.hlo.txt"), lm_inputs, lm_outputs),
    ));
    out
}

fn grad_outputs(cfg: &ModelCfg, w: usize, dense: bool) -> Vec<Value> {
    let mut out = vec![f32spec("com".into(), &[])];
    for j in 0..w {
        for l in ["wdown", "wgate", "wk", "wo", "wq", "wup", "wv"] {
            let (fan_in, fan_out) = cfg.linear_shape(l);
            let p = format!("grads.{j}.{l}");
            if dense {
                out.push(f32spec(format!("{p}.alpha"), &[]));
                out.push(f32spec(format!("{p}.s_w"), &[fan_out]));
                out.push(f32spec(format!("{p}.v"), &[fan_in, fan_out]));
            } else {
                out.push(f32spec(format!("{p}.a1"), &[fan_in, cfg.rank_pad]));
                out.push(f32spec(format!("{p}.a2"), &[cfg.rank_pad, fan_out]));
                out.push(f32spec(format!("{p}.alpha"), &[]));
                out.push(f32spec(format!("{p}.s_w"), &[fan_out]));
            }
        }
    }
    out.push(f32spec("kld".into(), &[]));
    out.push(f32spec("loss".into(), &[]));
    out.push(f32spec("mse".into(), &[]));
    out
}

// ---------------------------------------------------------------------------
// generation entry point
// ---------------------------------------------------------------------------

/// What [`generate`] produced.
#[derive(Clone, Debug)]
pub struct SynthReport {
    /// The generated model configuration.
    pub cfg: ModelCfg,
    /// Final host-pretraining loss.
    pub pretrain_loss: f32,
    /// Executables listed in the generated manifest.
    pub n_executables: usize,
    /// Quantizable weight parameters of the model.
    pub weight_params: usize,
}

/// Generate a synthetic artifacts directory at `dir`.
pub fn generate(dir: impl AsRef<Path>, spec: &SynthSpec) -> Result<SynthReport> {
    let dir = dir.as_ref();
    ensure!(spec.n_layers >= 1 && !spec.windows.is_empty(), "degenerate synth spec");
    ensure!(
        spec.d_model % spec.n_heads == 0 && (spec.d_model / spec.n_heads) % 2 == 0,
        "d_model/n_heads must give an even head_dim (RoPE)"
    );
    ensure!(
        spec.vocab > corpus::SEP_TOK as usize,
        "vocab {} must exceed the corpus token range ({})",
        spec.vocab,
        corpus::SEP_TOK
    );
    ensure!(
        spec.seq + 1 > corpus::SEGMENT_LEN / 2,
        "seq {} too short for the choice tasks (needs > {})",
        spec.seq,
        corpus::SEGMENT_LEN / 2
    );
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    let cfg = spec.cfg();

    // 1. init + pretrain + outlier injection
    let mut g = Gauss::new(spec.seed.wrapping_mul(0x9E3779B97F4A7C15) | 1);
    let mut params = FpParams::init(spec, &mut g);
    let loss = pretrain(spec, &mut params);
    inject_outliers(spec, &mut params, &mut g);

    // 2. weights container
    let d = cfg.d_model;
    let mut tensors: BTreeMap<String, Tensor> = BTreeMap::new();
    tensors.insert("embed".into(), Tensor::new(vec![cfg.vocab, d], params.embed.clone()));
    tensors.insert("final_norm".into(), Tensor::new(vec![d], params.final_norm.clone()));
    tensors.insert("head".into(), Tensor::new(vec![d, cfg.vocab], params.head.clone()));
    let mut weight_params = 0usize;
    for (i, blk) in params.blocks.iter().enumerate() {
        tensors.insert(format!("blocks.{i}.attn_norm"), Tensor::new(vec![d], blk.attn_norm.clone()));
        tensors.insert(format!("blocks.{i}.mlp_norm"), Tensor::new(vec![d], blk.mlp_norm.clone()));
        for l in LINEARS {
            let (fan_in, fan_out) = cfg.linear_shape(l);
            weight_params += fan_in * fan_out;
            tensors.insert(
                format!("blocks.{i}.{l}"),
                Tensor::new(vec![fan_in, fan_out], blk.lin[l].clone()),
            );
        }
    }
    io::write_tensors(dir.join(format!("weights_{}.bin", cfg.name)), &tensors)?;

    // 3. corpus parity vectors (generated by the same Rust corpus the
    // pipeline consumes, so the file-format contract stays covered)
    let corpus_ref = Value::obj(vec![
        (
            "c4",
            Value::arr(
                corpus::generate(corpus::Style::C4, CORPUS_SEED, 2048)
                    .into_iter()
                    .map(|t| Value::num(t as f64))
                    .collect(),
            ),
        ),
        (
            "wiki",
            Value::arr(
                corpus::generate(corpus::Style::Wiki, CORPUS_SEED, 2048)
                    .into_iter()
                    .map(|t| Value::num(t as f64))
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(dir.join("corpus_ref.json"), crate::json::dump(&corpus_ref))?;

    // 4. manifest
    let execs = executables(&cfg, &spec.windows);
    let n_executables = execs.len();
    let manifest = Value::obj(vec![
        ("version", Value::num(1.0)),
        ("configs", Value::obj(vec![(cfg.name.as_str(), cfg.to_json())])),
        (
            "executables",
            Value::Obj(execs.into_iter().collect()),
        ),
        (
            "pretrain_loss",
            Value::obj(vec![(cfg.name.as_str(), Value::num(loss as f64))]),
        ),
        (
            "linears",
            Value::arr(LINEARS.iter().map(|&l| Value::str(l)).collect()),
        ),
        (
            "windows",
            Value::obj(vec![(
                cfg.name.as_str(),
                Value::arr(spec.windows.iter().map(|&w| Value::num(w as f64)).collect()),
            )]),
        ),
    ]);
    std::fs::write(dir.join("manifest.json"), crate::json::dump(&manifest))?;

    Ok(SynthReport { cfg, pretrain_loss: loss, n_executables, weight_params })
}
