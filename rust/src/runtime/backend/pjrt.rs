//! PJRT execution backend: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client, and
//! executes them via the name-bound `Backend` interface driven by the
//! manifest's flatten_spec contract. Semantics identical to the pre-trait
//! `Runtime` — this file is the old implementation behind the new seam.
//!
//! Hot-path notes (see EXPERIMENTS.md §Perf): executables are compiled
//! lazily and cached for the process lifetime; static inputs (model
//! weights) can be pinned as device buffers via [`Backend::pin`] so
//! steady-state window steps only upload the small learnable tensors.

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use super::kernels::SeqKv;
use super::{check_shape, lock_or_recover as lock, Backend, Pinned, PinnedInner, RuntimeStats};
use crate::runtime::manifest::{ExecSpec, Manifest};
use crate::runtime::{Artifacts, Value};
use crate::tensor::Tensor;

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

impl Value {
    pub(crate) fn to_literal(&self, name: &str) -> Result<xla::Literal> {
        let lit = match self {
            Value::F32(t) => {
                if t.dims.is_empty() {
                    xla::Literal::scalar(t.data[0])
                } else {
                    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(&t.data).reshape(&dims).map_err(xerr)?
                }
            }
            Value::I32(t) => {
                if t.dims.is_empty() {
                    xla::Literal::scalar(t.data[0])
                } else {
                    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(&t.data).reshape(&dims).map_err(xerr)?
                }
            }
            Value::Packed(_) => bail!(
                "input `{name}`: packed-domain weights are native-backend \
                 only — rerun with `--backend native`, or disable packed \
                 pinning with `--no-packed` / `CBQ_PACKED=0` to serve f32 \
                 weights through PJRT"
            ),
        };
        Ok(lit)
    }
}

struct LoadedExec {
    exe: xla::PjRtLoadedExecutable,
    spec: ExecSpec,
}

/// Pinned device buffers for an executable's static inputs (weights): the
/// steady-state optimization loop re-uploads only learnable tensors.
///
/// The source literals are retained: TfrtCpuBuffer's CopyFromLiteral is
/// asynchronous and reads the literal after `buffer_from_host_literal`
/// returns — dropping the literal early is a use-after-free.
pub struct PjrtPinned {
    /// input index -> device buffer
    buffers: HashMap<usize, xla::PjRtBuffer>,
    _literals: Vec<xla::Literal>,
}

/// The PJRT execution backend: compiles the artifacts' AOT HLO text on a
/// PJRT client and executes on device (semantics identical to the native
/// interpreter; requires a real `xla` binding — the vendored stub errors
/// at client construction).
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    execs: Mutex<HashMap<String, Arc<LoadedExec>>>,
    manifest: Manifest,
    stats: Mutex<RuntimeStats>,
}

impl PjrtBackend {
    /// Bring up a PJRT CPU client over `artifacts`.
    pub fn new(artifacts: &Artifacts) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Self {
            client,
            dir: artifacts.dir.clone(),
            execs: Mutex::new(HashMap::new()),
            manifest: artifacts.manifest.clone(),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    fn load(&self, name: &str) -> Result<Arc<LoadedExec>> {
        if let Some(e) = lock(&self.execs).get(name) {
            return Ok(e.clone());
        }
        let spec = self.spec(name)?.clone();
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(xerr)
        .with_context(|| format!("loading HLO {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xerr)?;
        lock(&self.stats).compile_ms += t0.elapsed().as_secs_f64() * 1e3;
        let e = Arc::new(LoadedExec { exe, spec });
        // under a concurrent race the second compile wins the slot; both
        // handles stay valid — compilation is idempotent
        lock(&self.execs).insert(name.to_string(), e.clone());
        Ok(e)
    }

    fn run_inner(
        &self,
        exec_name: &str,
        values: &BTreeMap<String, Value>,
        pinned: Option<&PjrtPinned>,
    ) -> Result<BTreeMap<String, Tensor>> {
        let exec = self.load(exec_name)?;
        // Fresh (dynamic) uploads, keyed by input index; pinned buffers are
        // borrowed directly — PJRT `Execute` with default options does not
        // donate inputs, so reuse across calls is sound. Source literals are
        // kept alive until execution completes (async host->device copies).
        let mut fresh: HashMap<usize, xla::PjRtBuffer> = HashMap::new();
        let mut fresh_lits: Vec<xla::Literal> = Vec::new();
        let mut upload = 0u64;
        for (idx, spec) in exec.spec.inputs.iter().enumerate() {
            if let Some(p) = pinned {
                if p.buffers.contains_key(&idx) {
                    continue;
                }
            }
            let v = values.get(&spec.name).ok_or_else(|| {
                anyhow!("missing input `{}` for executable {exec_name}", spec.name)
            })?;
            check_shape(spec, v)
                .with_context(|| format!("input `{}` of {exec_name}", spec.name))?;
            upload += (v.dims().iter().product::<usize>().max(1) * 4) as u64;
            let lit = v.to_literal(&spec.name)?;
            fresh.insert(
                idx,
                self.client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(xerr)?,
            );
            fresh_lits.push(lit);
        }
        let bufs: Vec<&xla::PjRtBuffer> = (0..exec.spec.inputs.len())
            .map(|idx| {
                fresh.get(&idx).unwrap_or_else(|| {
                    pinned
                        .expect("index neither fresh nor pinned")
                        .buffers
                        .get(&idx)
                        .expect("index neither fresh nor pinned")
                })
            })
            .collect();
        let t0 = std::time::Instant::now();
        let result = exec.exe.execute_b(&bufs).map_err(xerr)?;
        // blocks until execution (and hence input consumption) completes
        let tuple = result[0][0].to_literal_sync().map_err(xerr)?;
        drop(fresh_lits);
        let parts = tuple.to_tuple().map_err(xerr)?;
        {
            let mut s = lock(&self.stats);
            s.executions += 1;
            s.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
            s.upload_bytes += upload;
        }
        anyhow::ensure!(
            parts.len() == exec.spec.outputs.len(),
            "executable {exec_name}: {} outputs, manifest says {}",
            parts.len(),
            exec.spec.outputs.len()
        );
        let mut out = BTreeMap::new();
        for (spec, lit) in exec.spec.outputs.iter().zip(parts) {
            let data: Vec<f32> = match spec.dtype.as_str() {
                "float32" => lit.to_vec::<f32>().map_err(xerr)?,
                "int32" => lit
                    .to_vec::<i32>()
                    .map_err(xerr)?
                    .into_iter()
                    .map(|v| v as f32)
                    .collect(),
                d => bail!("unsupported output dtype {d}"),
            };
            out.insert(spec.name.clone(), Tensor::new(spec.shape.clone(), data));
        }
        Ok(out)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn warmup(&self, name: &str) -> Result<()> {
        self.load(name).map(|_| ())
    }

    fn pin(&self, exec_name: &str, values: &BTreeMap<String, Value>) -> Result<Pinned> {
        let exec = self.load(exec_name)?;
        let mut buffers = HashMap::new();
        let mut literals = Vec::new();
        for (idx, spec) in exec.spec.inputs.iter().enumerate() {
            if let Some(v) = values.get(&spec.name) {
                check_shape(spec, v)?;
                let lit = v.to_literal(&spec.name)?;
                let buf = self
                    .client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(xerr)?;
                buffers.insert(idx, buf);
                literals.push(lit); // keep alive: async host->device copy
            }
        }
        Ok(Pinned {
            exec_name: exec_name.to_string(),
            inner: PinnedInner::Pjrt(PjrtPinned { buffers, _literals: literals }),
        })
    }

    fn run(
        &self,
        exec_name: &str,
        values: &BTreeMap<String, Value>,
    ) -> Result<BTreeMap<String, Tensor>> {
        self.run_inner(exec_name, values, None)
    }

    fn run_pinned(
        &self,
        pinned: &Pinned,
        values: &BTreeMap<String, Value>,
    ) -> Result<BTreeMap<String, Tensor>> {
        match &pinned.inner {
            PinnedInner::Pjrt(p) => self.run_inner(&pinned.exec_name, values, Some(p)),
            PinnedInner::Native(_) => {
                bail!("pinned handle for executable {} belongs to the native backend", pinned.exec_name)
            }
        }
    }

    fn decode_step(
        &self,
        pinned: &Pinned,
        _h: &Tensor,
        _start: usize,
        _kv: &mut [SeqKv],
    ) -> Result<Tensor> {
        bail!(
            "decode_step is not supported on the pjrt backend: the AOT-compiled \
             executables are fixed-shape [batch, seq] graphs with no incremental \
             KV-cache entry point — run token generation with `--backend native` \
             (requested window executable: {})",
            pinned.exec_name
        )
    }

    fn stats(&self) -> RuntimeStats {
        lock(&self.stats).clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::kernels::QPanels;
    use crate::runtime::PackedValue;
    use std::sync::Arc;

    #[test]
    fn packed_to_literal_names_tensor_and_remediation() {
        // a packed value can never cross into PJRT; the error must say
        // *which* input and how to get unstuck
        let q = QPanels::pack(&[0, 1, -1, 2], 2, 2, 4, &[0.5, 0.5]);
        let v = Value::Packed(PackedValue::new(Arc::new(q)));
        let err = v.to_literal("blk3.attn.wq").unwrap_err().to_string();
        assert!(err.contains("input `blk3.attn.wq`"), "{err}");
        assert!(err.contains("--backend native"), "{err}");
        assert!(err.contains("--no-packed"), "{err}");
        assert!(err.contains("CBQ_PACKED=0"), "{err}");
    }
}
