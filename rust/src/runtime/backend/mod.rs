//! Execution backends: the seam between the coordinator and whatever
//! actually runs the manifest's executables.
//!
//! Everything above this module (coordinator, eval, serve, hessian) talks
//! to a [`Backend`] trait object: name-bound inputs in, name-bound f32
//! tensors out, with optional pinning of static inputs. Two
//! implementations exist:
//!
//! * [`PjrtBackend`] (`backend/pjrt.rs`) — compiles and executes the AOT
//!   HLO artifacts on a PJRT client (the original `Runtime`, semantics
//!   unchanged). Requires a real `xla` binding; the vendored stub errors at
//!   client construction.
//! * [`NativeBackend`] (`backend/native.rs`) — interprets the manifest's
//!   executable *semantics* directly on the host CPU (`backend/kernels.rs`),
//!   including the analytic STE gradients of the `win_grad_*` graphs, so
//!   the full CBQ pipeline runs on any machine with no artifacts compiled.
//!
//! Selection: [`BackendKind::select`] honours an explicit request
//! (`--backend` / `CBQ_BACKEND`), else auto-detects — PJRT when a real
//! client comes up, the native interpreter otherwise.

pub mod kernels;
pub mod native;
pub mod pjrt;
pub mod pool;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::manifest::{ExecSpec, Manifest, TensorSpec};
use super::{Artifacts, Value};
use crate::tensor::Tensor;

pub use kernels::{KvCache, SeqKv};
pub use native::NativeBackend;
pub use pjrt::PjrtBackend;

/// Lock a mutex, recovering from poisoning. Backend-internal state (stats,
/// compile/RoPE caches, pool queues) is plain data that stays structurally
/// valid across a panicking kernel task, and serving must keep running —
/// so poisoning is recovered, never propagated.
pub(crate) fn lock_or_recover<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Runtime statistics (coordinator overhead accounting for §Perf).
#[derive(Default, Debug, Clone)]
pub struct RuntimeStats {
    /// Executable invocations served.
    pub executions: u64,
    /// Milliseconds spent compiling (PJRT only; native reports 0).
    pub compile_ms: f64,
    /// Milliseconds spent executing.
    pub execute_ms: f64,
    /// Bytes uploaded to the device (PJRT only).
    pub upload_bytes: u64,
}

/// Pinned static inputs for one executable. The payload is backend-
/// specific: device buffers for PJRT, retained host tensors for native.
///
/// Dropping a `Pinned` releases its retention: on the native backend the
/// retained `Value`s drop their storage shares, so an evicted lazy-serving
/// window's owned buffers are freed the moment no dispatch still holds the
/// handle (see `ServeEngine`'s bounded window cache).
pub struct Pinned {
    /// The executable these inputs were validated against.
    pub exec_name: String,
    pub(crate) inner: PinnedInner,
}

pub(crate) enum PinnedInner {
    Pjrt(pjrt::PjrtPinned),
    Native(BTreeMap<String, Value>),
}

impl Pinned {
    /// Heap bytes retained by this pin on the host, with buffers shared
    /// *within* the pin counted once (dedup by base pointer). Mapped
    /// tensors contribute 0 — their pages belong to the file cache. PJRT
    /// pins retain device buffers, not host memory, and report 0.
    ///
    /// Dedup runs per owned *component*, not per value: a packed weight
    /// owns two buffers (codes + scales) and each is counted exactly once
    /// no matter how many values (or `Arc` clones across engines) share
    /// it — the old per-value dedup keyed on a single pointer and would
    /// have dropped the scale bytes of any value whose code buffer had
    /// already been seen.
    ///
    /// This is the [`crate::tensor::Storage`]-introspection the serving
    /// layer's residency accounting (and its tests) are built on.
    pub fn host_resident_bytes(&self) -> u64 {
        match &self.inner {
            PinnedInner::Native(m) => {
                let mut seen = std::collections::BTreeSet::new();
                let mut total = 0u64;
                for v in m.values() {
                    for (ptr, bytes) in v.heap_components() {
                        if bytes > 0 && seen.insert(ptr) {
                            total += bytes as u64;
                        }
                    }
                }
                total
            }
            PinnedInner::Pjrt(_) => 0,
        }
    }
}

/// An execution backend over the manifest's executables.
///
/// `Send + Sync` is part of the contract: the serving layer dispatches
/// independent window batches concurrently (`Batcher::with_dispatch`), so
/// implementations use interior locking for their mutable state (stats,
/// compile/RoPE caches) and must be shareable across threads. `run` /
/// `run_pinned` are reentrant.
pub trait Backend: Send + Sync {
    /// Short backend identifier ("pjrt" / "native").
    fn name(&self) -> &'static str;

    /// The manifest this backend serves.
    fn manifest(&self) -> &Manifest;

    /// Input/output contract of an executable.
    fn spec(&self, name: &str) -> Result<&ExecSpec> {
        self.manifest()
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown executable {name}"))
    }

    /// Eagerly prepare an executable (compile for PJRT, no-op for native).
    fn warmup(&self, name: &str) -> Result<()>;

    /// Pin a set of inputs (by name) for repeated execution.
    fn pin(&self, exec_name: &str, values: &BTreeMap<String, Value>) -> Result<Pinned>;

    /// Execute with every input bound by name from `values`.
    fn run(
        &self,
        exec_name: &str,
        values: &BTreeMap<String, Value>,
    ) -> Result<BTreeMap<String, Tensor>>;

    /// Execute with `pinned` supplying the static inputs and `values` the
    /// dynamic remainder.
    fn run_pinned(
        &self,
        pinned: &Pinned,
        values: &BTreeMap<String, Value>,
    ) -> Result<BTreeMap<String, Tensor>>;

    /// One autoregressive decode step through a pinned `win_fwd_*` window:
    /// `h` holds one new position per sequence (`[rows, 1, d_model]`),
    /// `start` is the absolute index of the window's first block, and
    /// `kv[r].blocks[start + j]` supplies (and is advanced by) the KV cache
    /// of sequence `r` at window-local block `j`. Returns the transformed
    /// hidden states, `[rows, 1, d_model]`.
    ///
    /// The window executables are fixed-shape `[batch, seq]` graphs, so
    /// this is a distinct entry point rather than a `run_pinned` shape:
    /// the native backend interprets the same block semantics with
    /// incremental attention ([`kernels::Attention::attend_one`]), bitwise-
    /// equal per position to a full prefill over the same prefix. Backends
    /// without an incremental path (PJRT executes only the AOT-compiled
    /// fixed shapes) return a clear unsupported error.
    fn decode_step(
        &self,
        pinned: &Pinned,
        h: &Tensor,
        start: usize,
        kv: &mut [SeqKv],
    ) -> Result<Tensor>;

    /// Cumulative execution statistics (snapshot of interior counters).
    fn stats(&self) -> RuntimeStats;
}

/// Which backend to construct.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// PJRT if a real client initializes, else native.
    #[default]
    Auto,
    /// The native CPU interpreter ([`NativeBackend`]).
    Native,
    /// The PJRT/HLO path ([`PjrtBackend`]).
    Pjrt,
}

impl BackendKind {
    /// Parse a `--backend` / `CBQ_BACKEND` value.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => Self::Auto,
            "native" => Self::Native,
            "pjrt" => Self::Pjrt,
            other => bail!("unknown backend `{other}` (expected native|pjrt|auto)"),
        })
    }

    /// Resolve a selection: explicit argument wins, then `CBQ_BACKEND`,
    /// then auto.
    pub fn select(explicit: Option<&str>) -> Result<Self> {
        if let Some(s) = explicit {
            return Self::parse(s);
        }
        if let Ok(env) = std::env::var("CBQ_BACKEND") {
            if !env.is_empty() {
                return Self::parse(&env);
            }
        }
        Ok(Self::Auto)
    }
}

/// Do the artifacts carry compiled HLO text the PJRT backend could load?
/// Synthetic artifacts (`cbq synth`) list placeholder file names and write
/// no HLO, and an interrupted `make artifacts` leaves holes — auto must
/// only commit to PJRT when *every* listed executable is actually present.
fn hlo_present(artifacts: &Artifacts) -> bool {
    !artifacts.manifest.executables.is_empty()
        && artifacts
            .manifest
            .executables
            .values()
            .all(|e| artifacts.dir.join(&e.file).exists())
}

/// Construct a backend over `artifacts`.
pub fn create(artifacts: &Artifacts, kind: BackendKind) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Pjrt => Ok(Box::new(PjrtBackend::new(artifacts)?)),
        BackendKind::Native => Ok(Box::new(NativeBackend::new(artifacts)?)),
        BackendKind::Auto => {
            if hlo_present(artifacts) {
                if let Ok(b) = PjrtBackend::new(artifacts) {
                    return Ok(Box::new(b));
                }
            }
            Ok(Box::new(NativeBackend::new(artifacts)?))
        }
    }
}

/// `create` with `--backend`/`CBQ_BACKEND`/auto resolution in one call.
pub fn create_selected(artifacts: &Artifacts, explicit: Option<&str>) -> Result<Box<dyn Backend>> {
    create(artifacts, BackendKind::select(explicit)?)
}

/// Shared input validation: shape and dtype against the manifest spec.
pub(crate) fn check_shape(spec: &TensorSpec, v: &Value) -> Result<()> {
    let want: &[usize] = &spec.shape;
    let got = v.dims();
    anyhow::ensure!(got == want, "shape mismatch: got {:?}, manifest wants {:?}", got, want);
    let is_i32 = matches!(v, Value::I32(_));
    let want_i32 = spec.dtype == "int32";
    anyhow::ensure!(
        is_i32 == want_i32,
        "dtype mismatch: got {}, manifest wants {}",
        if is_i32 { "int32" } else { "float32" },
        spec.dtype
    );
    Ok(())
}

/// The executable families the manifest names (aot.py's export set).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExecKind {
    /// `win_fwd_w{K}_{cfg}`: quantized window forward + reconstruction loss.
    WinFwd { w: usize },
    /// `win_grad_w{K}_{cfg}` / `win_grad_dense_w{K}_{cfg}`: value-and-grad
    /// wrt the learnable quant params.
    WinGrad { w: usize, dense: bool },
    /// `capture_{cfg}`: single-block forward + per-linear input capture.
    Capture,
    /// `lm_eval_{cfg}`: final-norm + LM-head masked NLL.
    LmEval,
}

impl ExecKind {
    /// Parse an executable name into `(kind, config name)`.
    pub fn parse(name: &str) -> Option<(ExecKind, &str)> {
        fn split_w(rest: &str) -> Option<(usize, &str)> {
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            let w: usize = digits.parse().ok()?;
            let tail = &rest[digits.len()..];
            let cfg = tail.strip_prefix('_')?;
            if cfg.is_empty() {
                return None;
            }
            Some((w, cfg))
        }
        if let Some(rest) = name.strip_prefix("win_fwd_w") {
            let (w, cfg) = split_w(rest)?;
            return Some((ExecKind::WinFwd { w }, cfg));
        }
        if let Some(rest) = name.strip_prefix("win_grad_dense_w") {
            let (w, cfg) = split_w(rest)?;
            return Some((ExecKind::WinGrad { w, dense: true }, cfg));
        }
        if let Some(rest) = name.strip_prefix("win_grad_w") {
            let (w, cfg) = split_w(rest)?;
            return Some((ExecKind::WinGrad { w, dense: false }, cfg));
        }
        if let Some(cfg) = name.strip_prefix("capture_") {
            if !cfg.is_empty() {
                return Some((ExecKind::Capture, cfg));
            }
        }
        if let Some(cfg) = name.strip_prefix("lm_eval_") {
            if !cfg.is_empty() {
                return Some((ExecKind::LmEval, cfg));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_name_parsing() {
        assert_eq!(ExecKind::parse("win_fwd_w2_t"), Some((ExecKind::WinFwd { w: 2 }, "t")));
        assert_eq!(
            ExecKind::parse("win_grad_w12_tiny"),
            Some((ExecKind::WinGrad { w: 12, dense: false }, "tiny"))
        );
        assert_eq!(
            ExecKind::parse("win_grad_dense_w2_s"),
            Some((ExecKind::WinGrad { w: 2, dense: true }, "s"))
        );
        assert_eq!(ExecKind::parse("capture_m"), Some((ExecKind::Capture, "m")));
        assert_eq!(ExecKind::parse("lm_eval_t"), Some((ExecKind::LmEval, "t")));
        assert_eq!(ExecKind::parse("lm_eval_"), None);
        assert_eq!(ExecKind::parse("win_fwd_w_t"), None);
        assert_eq!(ExecKind::parse("unrelated"), None);
    }

    #[test]
    fn backend_kind_parse_and_select() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert!(BackendKind::parse("gpu").is_err());
        assert_eq!(BackendKind::select(Some("native")).unwrap(), BackendKind::Native);
    }
}
